"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

This is deliverable (b)'s "real" driver. On a TPU slice it runs as-is with
--production-mesh; on this CPU container a full run takes a few hours, so the
default invocation trains a shorter schedule (pass --steps 300 for the full
few-hundred-step run).

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""

import argparse
import dataclasses
import sys

from repro.configs import granite_3_2b
from repro.launch import train as train_mod
from repro.models.config import ModelConfig

# ~103M params: granite-family, scaled
CONFIG_100M = ModelConfig(
    name="repro-100m", family="dense",
    n_layers=10, d_model=640, n_heads=10, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab=32768, mlp_type="swiglu", pos_emb="rope",
    tie_embeddings=True, remat="none",
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args(argv)

    n = CONFIG_100M.param_count()
    print(f"model: {CONFIG_100M.name} — {n/1e6:.1f}M params")

    # register the config so the generic driver can resolve it
    import repro.configs as cfgs

    mod = type(sys)("repro.configs.repro_100m")
    mod.CONFIG = CONFIG_100M
    mod.smoke = lambda: CONFIG_100M
    sys.modules["repro.configs.repro_100m"] = mod
    cfgs.ARCHS = tuple(cfgs.ARCHS) + ("repro_100m",)
    cfgs._ALIASES["repro-100m"] = "repro_100m"

    return train_mod.main([
        "--arch", "repro-100m",
        "--steps", str(args.steps),
        "--global-batch", str(args.global_batch),
        "--seq", str(args.seq),
        "--accum", "2",
        "--lr", "6e-4",
        "--optimizer", "adamw",
        "--ckpt", args.ckpt, "--ckpt-every", "50",
    ])


if __name__ == "__main__":
    main()
