"""Quickstart: configure an X-HEEP platform, train a small LM, serve it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.platform import Platform, XHeepConfig
from repro.core.power import PowerState
from repro.data.lm import LMDataConfig, LMPipeline
from repro.launch.mesh import make_host_mesh
from repro.models import registry
from repro.sharding import params as P
from repro.train import optim as optim_lib
from repro.train.trainer import TrainConfig, build_sharded_train


def main():
    # 1. Configure the platform (the paper's §III configurability axes):
    #    core choice = execution backend, bus topology = sharding rules.
    platform = Platform(XHeepConfig(core="cv32e40x", bus="fully_connected",
                                    addressing="contiguous", n_banks=8))
    mesh = make_host_mesh()
    rules = platform.rules(mesh)
    print("platform:", platform.config)
    print("rules preset:", rules.name)

    # 2. Pick an architecture (reduced config for CPU) and build training.
    cfg = configs.smoke("granite_3_2b")
    tc = TrainConfig(optimizer="adamw", lr=2e-3, accum=2)
    st = build_sharded_train(cfg, tc, mesh, rules, global_batch=8, seq=64)
    params = P.cast_tree(P.init_tree(registry.decls(cfg), jax.random.key(0)),
                         jnp.bfloat16)
    opt_state = optim_lib.get(tc.optimizer).init(params)
    data = LMPipeline(LMDataConfig(vocab=cfg.vocab, seq=64, global_batch=8,
                                   accum=2))

    # 3. Train a few steps.
    with mesh:
        for step in range(10):
            params, opt_state, metrics = st.step_fn(params, opt_state,
                                                    data.batch_at(step))
            print(f"step {step}: loss {float(metrics['loss']):.4f}")

    # 4. Power-gate what we are not using (the paper's §III-A5 mechanism).
    platform.power.set_state("bank7", PowerState.OFF)
    platform.power.set_state("bank6", PowerState.RETENTION)
    print("power states:", {k: v.value for k, v in platform.power.states.items()})

    # 5. Serve a few greedy tokens from the trained weights.
    cache = registry.cache_init(cfg, batch=2, max_len=32)
    tok = jnp.zeros((2, 1), jnp.int32)
    outs = []
    for _ in range(8):
        logits, cache = registry.decode_step(params, cfg, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(int(tok[0, 0]))
    print("greedy tokens:", outs)


if __name__ == "__main__":
    main()
