"""Write-your-own accelerator: the XAIF no-fork extension path.

Implements a toy "Keccak-ish" mixing accelerator (the paper's §II-A1 memory-
class example) as a Pallas kernel, registers it through XAIF with slave/
master ports + a power domain, and runs it through the platform dispatcher —
zero changes to platform or model code.

    PYTHONPATH=src python examples/accelerator_plugin.py
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.platform import Platform, XHeepConfig
from repro.core.power import PowerDomain, PowerState
from repro.core.xaif import AcceleratorSpec, PortSpec
from repro.sharding.params import Axes


# --- 1. the kernel (compute unit) -------------------------------------------

def _mix_kernel(x_ref, o_ref):
    x = x_ref[0].astype(jnp.uint32)
    # a few rounds of xor-rotate mixing (keccak-flavoured, not cryptographic)
    for r in range(4):
        rot = jnp.bitwise_or(jnp.left_shift(x, 7), jnp.right_shift(x, 25))
        x = jnp.bitwise_xor(x, rot) + jnp.uint32(0x9E3779B9 + r)
    o_ref[0] = x


def mix(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    b, n = x.shape
    return pl.pallas_call(
        _mix_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.uint32),
        interpret=interpret,
    )(x)


def mix_ref(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    for r in range(4):
        rot = jnp.bitwise_or(jnp.left_shift(x, 7), jnp.right_shift(x, 25))
        x = jnp.bitwise_xor(x, rot) + jnp.uint32(0x9E3779B9 + r)
    return x


# --- 2. the XAIF contract -----------------------------------------------------

SPEC = AcceleratorSpec(
    name="keccakish_mixer",
    op="mix",
    impl="pallas",
    fn=mix,
    slave_ports=(PortSpec("ctrl_status", Axes(), direction="slave",
                          dtype="int32"),
                 PortSpec("data_mem", Axes(None, None), direction="slave",
                          dtype="uint32")),
    master_ports=(PortSpec("dma_stream", Axes(None, None)),),
    power_domain=PowerDomain("keccak", leak_uw=4.0, active_dyn_uw_mhz=18.0),
    description="2-slave-port memory-class accelerator (paper §II-A1)",
)


def main():
    platform = Platform(XHeepConfig(core="cv32e20"))
    platform.attach(SPEC)   # <- the whole integration effort
    print("attached:", SPEC.name, "| power domains:",
          sorted(platform.power.domains))

    x = jnp.asarray(np.random.default_rng(0).integers(0, 2**32, (4, 128),
                                                      dtype=np.uint32))
    got = platform.dispatch("mix", x)
    want = mix_ref(x)
    assert (np.asarray(got) == np.asarray(want)).all()
    print("accelerator output matches host reference on",
          x.shape, "uint32 block")

    # interrupt + power-gate after completion, like the paper's CGRA flow
    platform.power.set_state("keccak", PowerState.OFF)
    print("keccak domain gated; platform leakage:",
          platform.power.leakage_uw(), "uW")


if __name__ == "__main__":
    main()
