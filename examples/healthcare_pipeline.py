"""HEEPocrates end-to-end: the paper's §IV/§V integration example.

Acquisition (biosignal stream -> SRAM banks, unused domains power-gated)
-> processing (heartbeat classifier on host; seizure CNN offloaded to the
CGRA accelerator through XAIF) -> energy accounting that reproduces the
paper's measured numbers, including the 4.9x CGRA benefit.

    PYTHONPATH=src python examples/healthcare_pipeline.py
"""

import numpy as np

import repro.kernels  # noqa: F401 -- registers the CGRA (conv1d) accelerator
from repro.apps import healthcare as H
from repro.core import energy as E
from repro.core.platform import Platform, XHeepConfig
from repro.core.power import PowerState
from repro.core.xaif import REGISTRY
from repro.data import biosignal


def main():
    # --- platform bring-up: HEEPocrates configuration (paper §IV-A1) -------
    platform = Platform(XHeepConfig(core="cv32e20", bus="fully_connected",
                                    addressing="contiguous", n_banks=8))
    cgra = REGISTRY.get("conv1d", "pallas")
    platform.attach(cgra)
    print(f"attached accelerator: {cgra.name} "
          f"({len(cgra.slave_ports)} slave + {len(cgra.master_ports)} master "
          f"ports = {cgra.bus_width_bits} bit/cycle)")

    # --- acquisition phase ---------------------------------------------------
    for spec in (biosignal.HEARTBEAT_ECG, biosignal.SEIZURE_EEG):
        sim = biosignal.AcquisitionSim(spec, n_banks=8)
        used = sim.bank_states()
        for i, u in enumerate(used):
            platform.power.set_state(f"bank{i}",
                                     PowerState.ON if u else PowerState.OFF)
        print(f"{spec.name}: window {spec.window_bytes / 1024:.1f} KiB -> "
              f"{sum(used)}/8 banks on; acquisition power "
              f"{E.power_acquisition(2):.0f} uW (paper: 286 uW)")

    # --- processing phase -----------------------------------------------------
    flags, macs_hb = H.run_heartbeat(0)
    print(f"heartbeat classifier: {int(flags.sum())} abnormal beats "
          f"({macs_hb} MACs on host CPU @ {E.power_processing(True) / 1000:.2f} mW)")

    logits_host, macs_sz = H.run_seizure(0, impl="host")
    logits_cgra, _ = H.run_seizure(0, impl="cgra")
    assert np.allclose(logits_host, logits_cgra, atol=1e-4)
    verdict = "SEIZURE" if logits_cgra[1] > logits_cgra[0] else "normal"
    print(f"seizure CNN ({macs_sz} MACs): host == CGRA, verdict: {verdict}")

    # --- energy story (paper Fig. 6) --------------------------------------------
    e_cpu = E.conv_energy_uj(on_cgra=False)
    e_cgra = E.conv_energy_uj(on_cgra=True)
    print(f"16x16 conv(3x3): host {e_cpu:.3f} uJ vs CGRA {e_cgra:.3f} uJ -> "
          f"{e_cpu / e_cgra:.1f}x benefit (paper: 4.9x)")

    # race-to-sleep: everything off after processing
    for name in list(platform.power.states):
        if name != "host":
            platform.power.set_state(name, PowerState.OFF)
    print("post-processing leakage:",
          platform.power.leakage_uw(), "uW (accelerators power-gated)")


if __name__ == "__main__":
    main()
