"""Continuous-batching serving on a configured X-HEEP platform.

Requests arrive on a schedule, get admitted into free decode slots without
stopping in-flight decodes, completion is signaled through the XAIF
interrupt fabric while idle memory banks are clock-gated — and requests
sharing a prompt prefix (a common system prompt) admit straight onto
shared, refcounted cache pages instead of re-running prefill.

    PYTHONPATH=src python examples/serve_continuous.py
"""

import jax

from repro import configs
from repro.core.platform import Platform, XHeepConfig
from repro.models import registry
from repro.serve.engine import (COMPLETE_LINE, ContinuousBatchingEngine,
                                Request)
from repro.serve.sim import FakeClock, Simulator, staggered_trace
from repro.sharding import params as P


def main():
    # 1. Platform: 4 memory banks so the gating pattern is easy to watch.
    platform = Platform(XHeepConfig(core="cv32e40x", n_banks=4))

    # 2. Tiny model + engine: 4 decode slots (one cache lane each), chunked
    #    prefill (4 prompt tokens per slot per step) and a paged prefix
    #    cache (8-token pages shared across requests).
    cfg = configs.smoke("granite_3_2b")
    params = P.init_tree(registry.decls(cfg), jax.random.key(0))
    clock = FakeClock()
    engine = ContinuousBatchingEngine(cfg, params, slots=4, max_len=64,
                                      platform=platform, clock=clock,
                                      prefill_chunk=4, page_size=8,
                                      async_dispatch=True)

    # 3. Completion interrupts, exactly like an accelerator's end-of-
    #    computation line: the host handler runs when a request finishes.
    platform.interrupts.connect(
        COMPLETE_LINE,
        lambda req: print(f"  [irq t={clock():5.1f}] {req.id} done -> "
                          f"{req.tokens}"))

    # 4. A scripted trace of staggered arrivals (heavier than the slots).
    #    Every prompt opens with the same 16-token "system prompt"; only
    #    the first requests to touch it pay for its prefill.
    system_prompt = [(5 * j) % 97 + 1 for j in range(16)]
    requests = [Request(id=f"user{i}",
                        prompt=system_prompt + [1 + i, 2 + i, 3 + i],
                        max_new_tokens=6) for i in range(8)]
    report = Simulator(engine, staggered_trace(requests, gap=1.5),
                       clock).run()

    print(f"\nserved {len(report.completed)} requests, "
          f"{report.tokens_generated} tokens in {report.elapsed:.1f} sim-s "
          f"({report.throughput:.2f} tok/sim-s over {report.steps} steps)")
    print("prefix cache:", engine.stats()["pages"])
    print("power states:",
          {n: s.value for n, s in platform.power.states.items()
           if n.startswith("bank")})
    print("interrupt counts:", platform.interrupts.counts)
    assert engine.prompt_tokens_reused > 0, "warm prefixes must be reused"


if __name__ == "__main__":
    main()
