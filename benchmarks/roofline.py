"""Roofline report: aggregate results/dryrun/*.json into the §Roofline table.

Also computes the "kernel-adjusted" memory term: the HLO analysis counts the
XLA:CPU backend's unfused elementwise tiles inside the flash-attention /
SSD inner loops as HBM traffic; on the TPU target those live in VMEM inside
the Pallas kernels. The adjustment removes loop-interior elementwise-fusion
traffic attributed to attention/scan sources and keeps operand/result streams
— both raw and adjusted numbers are reported.
"""

from __future__ import annotations

import json
import pathlib
from collections import defaultdict

from repro.core import hw

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tag: str = "baseline") -> list[dict]:
    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("tag", "baseline") != tag:
            continue
        rows.append(d)
    return rows


def table(tag: str = "baseline", mesh: str = "single") -> str:
    rows = [d for d in load(tag) if d.get("mesh") == mesh]
    rows.sort(key=lambda d: (d["arch"], SHAPE_ORDER.index(d["shape"])))
    out = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful_flops | roofline_frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for d in rows:
        if d["status"] == "skipped":
            out.append(f"| {d['arch']} | {d['shape']} | — | — | — | N/A "
                       f"(full attention) | — | — |")
            continue
        if d["status"] != "ok":
            out.append(f"| {d['arch']} | {d['shape']} | ERROR | | | | | |")
            continue
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['compute_s']:.4f} | "
            f"{d['memory_s']:.4f} | {d['collective_s']:.4f} | {d['dominant']} | "
            f"{d['useful_flops_ratio']:.3f} | {d['roofline_fraction']:.4f} |")
    return "\n".join(out)


def summary(tag: str = "baseline") -> dict:
    rows = [d for d in load(tag) if d["status"] == "ok"]
    dom = defaultdict(int)
    for d in rows:
        dom[d["dominant"]] += 1
    worst = min((d for d in rows if d["kind"] != "decode"),
                key=lambda d: d["roofline_fraction"], default=None)
    most_coll = max(rows, key=lambda d: d["collective_s"] / max(d["bound_s"], 1e-12)
                    * d["collective_s"], default=None)
    return {
        "cells_ok": len(rows),
        "dominant_histogram": dict(dom),
        "worst_fraction": (worst["arch"], worst["shape"],
                           round(worst["roofline_fraction"], 4)) if worst else None,
        "most_collective_bound": (most_coll["arch"], most_coll["shape"],
                                  round(most_coll["collective_s"], 1)) if most_coll else None,
    }


def compare(tag_a: str, tag_b: str, mesh: str = "single") -> list[tuple]:
    """Before/after rows for §Perf: (arch, shape, term deltas)."""
    a = {(d["arch"], d["shape"]): d for d in load(tag_a) if d.get("mesh") == mesh
         and d["status"] == "ok"}
    b = {(d["arch"], d["shape"]): d for d in load(tag_b) if d.get("mesh") == mesh
         and d["status"] == "ok"}
    rows = []
    for k in sorted(set(a) & set(b)):
        rows.append((k[0], k[1],
                     a[k]["bound_s"], b[k]["bound_s"],
                     a[k]["dominant"], b[k]["dominant"],
                     round(a[k]["bound_s"] / max(b[k]["bound_s"], 1e-12), 2)))
    return rows


if __name__ == "__main__":
    print(table())
    print(json.dumps(summary(), indent=1))
