# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: paper figures (calibrated energy model), kernel
micro-timings, healthcare apps host-vs-CGRA, and the roofline summary.

Run: PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import json
import time


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def main() -> None:
    print("name,us_per_call,derived")

    from benchmarks import paper_figures as pf

    for name, fn in [
        ("fig2_bus_exploration", pf.fig2_bus),
        ("fig2_peripheral_area", pf.fig2_periph),
        ("fig2_leakage_split", pf.fig2_leakage),
        ("tableIVc_power_ladders", pf.power_ladders),
        ("tableIVd_dvfs", pf.dvfs),
        ("fig5_healthcare_3mcus", pf.fig5),
        ("fig6_cgra_benefit", pf.fig6),
    ]:
        (rows, derived), us = _timed(fn)
        print(f"{name},{us:.0f},\"{json.dumps(derived)}\"")

    # healthcare applications end-to-end (host vs CGRA plug-in)
    from repro.apps import healthcare as H

    (flags, macs), us = _timed(H.run_heartbeat, 0)
    print(f"app_heartbeat_classifier,{us:.0f},"
          f"\"{{'abnormal_beats': {int(flags.sum())}, 'macs': {macs}}}\"")
    (lg_host, macs_s), us_host = _timed(H.run_seizure, 0, "host")
    (lg_cgra, _), us_cgra = _timed(H.run_seizure, 0, "cgra")
    agree = bool(abs(float(lg_host[0] - lg_cgra[0])) < 1e-3)
    print(f"app_seizure_cnn_host,{us_host:.0f},\"{{'macs': {macs_s}}}\"")
    print(f"app_seizure_cnn_cgra,{us_cgra:.0f},\"{{'matches_host': {agree}}}\"")

    # kernel micro-benchmarks (interpret mode)
    from benchmarks import kernel_bench

    for name, us, shape in kernel_bench.run():
        print(f"kernel_{name},{us:.0f},\"{shape}\"")

    # roofline summary from the dry-run artifacts
    from benchmarks import roofline

    s = roofline.summary()
    print(f"roofline_summary,0,\"{json.dumps(s)}\"")


if __name__ == "__main__":
    main()
