"""Kernel micro-benchmarks: us_per_call of each Pallas kernel (interpret mode
on CPU — correctness-path timing, NOT TPU performance) vs its jnp oracle."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rng = np.random.default_rng(0)

    def t(*s, dtype=jnp.float32, scale=1.0):
        return jnp.asarray(rng.normal(size=s) * scale, dtype)

    rows = []

    from repro.kernels.attention import ops as aops, ref as aref

    q, k, v = t(2, 128, 4, 64), t(2, 128, 2, 64), t(2, 128, 2, 64)
    rows.append(("attention_pallas_interp",
                 _time(lambda *a: aops.flash_attention(*a, causal=True), q, k, v),
                 "B2xS128xH4xD64"))
    rows.append(("attention_ref",
                 _time(lambda *a: aref.attention(*a, causal=True), q, k, v), ""))

    from repro.kernels.ssd import ops as sops, ref as sref

    x, dA = t(2, 128, 4, 32, scale=0.5), -jnp.abs(t(2, 128, 4, scale=0.1))
    B_, C_ = t(2, 128, 4, 32, scale=0.3), t(2, 128, 4, 32, scale=0.3)
    rows.append(("ssd_pallas_interp",
                 _time(lambda *a: sops.ssd(*a, chunk=32), x, dA, B_, C_),
                 "B2xS128xH4xP32xN32"))
    rows.append(("ssd_ref", _time(sref.ssd, x, dA, B_, C_), ""))

    from repro.kernels.rglru import ops as rops, ref as rref

    a = jnp.clip(jnp.abs(t(2, 256, 128, scale=0.3)), 0, 0.95)
    b = t(2, 256, 128, scale=0.5)
    rows.append(("rglru_pallas_interp", _time(rops.rglru, a, b), "B2xS256xW128"))
    rows.append(("rglru_ref", _time(rref.rglru, a, b), ""))

    from repro.kernels.moe import ops as mops, ref as mref

    xg = t(8, 64, 64, scale=0.4)
    p = {"w_gate": t(8, 64, 128, scale=0.1), "w_up": t(8, 64, 128, scale=0.1),
         "w_down": t(8, 128, 64, scale=0.1)}
    rows.append(("moe_ffn_pallas_interp", _time(mops.moe_ffn, xg, p),
                 "E8xC64xD64xF128"))
    rows.append(("moe_ffn_ref", _time(mref.moe_ffn, xg, p), ""))

    from repro.kernels.conv1d import ops as cops, ref as cref

    xc, wc = t(2, 512, 128), t(4, 128, scale=0.4)
    rows.append(("conv1d_cgra_interp", _time(cops.conv1d, xc, wc), "B2xS512xD128"))
    rows.append(("conv1d_ref", _time(cref.conv1d, xc, wc), ""))
    return rows
