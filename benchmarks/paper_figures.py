"""Reproductions of the paper's figures/tables from the calibrated models.

Each function returns (rows, derived) where rows are CSV-able tuples and
`derived` is the headline claim being validated.
"""

from __future__ import annotations

import numpy as np

from repro.core import energy as E
from repro.core.power import PowerManager, PowerDomain


# -- Fig. 2(a,b): bus topology exploration -----------------------------------

# Synthesis-calibrated area model (TSMC65LP kGE): a one-at-a-time bus grows
# linearly in ports; a fully-connected crossbar grows ~quadratically.
_OAT_BASE_KGE, _OAT_PER_PORT = 6.0, 1.1
_FC_BASE_KGE, _FC_PER_PAIR = 8.0, 2.9


def fig2_bus(max_pairs: int = 8):
    rows = []
    for pairs in range(0, max_pairs + 1):
        oat_area = _OAT_BASE_KGE + _OAT_PER_PORT * pairs
        fc_area = _FC_BASE_KGE + _FC_PER_PAIR * pairs * (pairs + 2)
        oat_bw = 32                      # one master at a time: flat
        fc_bw = 32 * (1 + pairs)         # linear in ports
        rows.append((pairs, round(oat_area, 1), round(fc_area, 1), oat_bw, fc_bw))
    pairs = max_pairs
    area_saving = 1 - rows[-1][1] / rows[-1][2]
    # paper: one-at-a-time saves >85 % area at the same port count;
    # fully-connected bandwidth scales linearly, one-at-a-time stays flat.
    assert area_saving > 0.85, area_saving
    assert rows[-1][4] == 32 * (1 + max_pairs) and rows[-1][3] == 32
    return rows, {"area_saving_at_8_pairs": round(area_saving, 3)}


def fig2_bus_measured_on_pod():
    """The same trade-off measured on the pod from lowered collective bytes:
    one_at_a_time rules vs fully_connected rules for a small sharded matmul
    (see tests/test_dryrun_meta.py for the full-model version)."""
    from repro.launch.dryrun import RESULTS
    import json

    out = {}
    for tag, name in (("baseline", "fully_connected"),):
        f = RESULTS / "granite-3-2b__train_4k__single.json"
        if f.exists():
            d = json.loads(f.read_text())
            out[name] = d.get("wire_bytes_per_device")
    return out


# -- Fig. 2(c): peripheral domain area ----------------------------------------

_PERIPH_AREA_KGE = {"plic": 11.0, "timer": 2.5, "gpio": 1.8, "i2c": 5.2,
                    "spi": 7.9}


def fig2_periph():
    rows = sorted(_PERIPH_AREA_KGE.items(), key=lambda kv: -kv[1])
    return rows, {"total_kge": round(sum(_PERIPH_AREA_KGE.values()), 1)}


# -- Fig. 2(d): leakage split --------------------------------------------------

def fig2_leakage():
    pm = E.build_heepocrates_pm()
    rows = [(n, round(d.leak_uw, 2)) for n, d in pm.domains.items()]
    ess = pm.domains["ao_essential"].leak_uw
    gp = pm.domains["ao_gp_periph"].leak_uw
    split = ess / (ess + gp)
    assert abs(split - 0.35) < 0.02     # paper: 35 % essential / 65 % GP
    return rows, {"ao_essential_fraction": round(split, 3)}


# -- §IV-C power ladders ---------------------------------------------------------

def power_ladders():
    rows = [
        ("sleep_32khz", E.power_sleep_32khz(), 270.0),
        ("acquisition_all_on", E.power_acquisition(0), 384.0),
        ("acquisition_gated", E.power_acquisition(1), 310.0),
        ("acquisition_cpu_off", E.power_acquisition(2), 286.0),
        ("processing_all_on", E.power_processing(False), 8170.0),
        ("processing_gated", E.power_processing(True), 7680.0),
        ("cgra_cnn", E.power_cgra_cnn(), 4010.0),
        ("max_470mhz_1v2", E.power_max_470mhz_1v2(), 48000.0),
    ]
    worst = max(abs(m - t) / t for _, m, t in rows)
    assert worst < 0.025, worst
    return [(n, round(m, 1), t) for n, m, t in rows], \
        {"worst_rel_err": round(worst, 4)}


# -- §IV-D DVFS -----------------------------------------------------------------

def dvfs():
    power, perf, en = E.dvfs_ratios()
    rows = [("power_ratio", round(power, 2), 5.9),
            ("perf_ratio", round(perf, 2), 2.8),
            ("energy_ratio", round(en, 2), 2.1)]
    return rows, {"energy_ratio": round(en, 2)}


# -- Fig. 5: healthcare benchmark on 3 MCUs ---------------------------------------

def fig5():
    rows = []
    for app in (E.HEARTBEAT, E.SEIZURE):
        for name, m in E.mcu_models().items():
            e_acq, e_proc = m.app_energy_mj(app)
            rows.append((app.name, name, round(e_acq, 2), round(e_proc, 2),
                         round(e_acq + e_proc, 2)))
    hb = {r[1]: r[4] for r in rows if r[0] == "heartbeat"}
    sz = {r[1]: r[4] for r in rows if r[0] == "seizure"}
    assert hb["apollo3_blue"] < hb["heepocrates"] < hb["gap9"]
    assert sz["gap9"] < sz["heepocrates"] < sz["apollo3_blue"]
    return rows, {
        "heartbeat_order": "apollo<heep<gap9",
        "seizure_order": "gap9<heep<apollo",
        "gp_trim_saving_heartbeat": round(E.gp_trim_saving(E.HEARTBEAT), 3),
        "gp_trim_saving_seizure": round(E.gp_trim_saving(E.SEIZURE), 3),
    }


# -- Fig. 6: CGRA 4.9x ------------------------------------------------------------

def fig6():
    e_cpu = E.conv_energy_uj(on_cgra=False)
    e_cgra = E.conv_energy_uj(on_cgra=True)
    benefit = e_cpu / e_cgra
    assert abs(benefit - 4.9) < 0.1, benefit
    rows = [("conv16x16_3x3_cpu_uJ", round(e_cpu, 3)),
            ("conv16x16_3x3_cgra_uJ", round(e_cgra, 3))]
    return rows, {"cgra_energy_benefit": round(benefit, 2)}
