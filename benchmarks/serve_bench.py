"""Arrivals-trace serving benchmark: paged decode + async dispatch vs PR 2.

Replays a deterministic trace of staggered request arrivals through the
continuous-batching engine and reports tokens/s on the simulation clock
plus wall-clock step latency. The sim cost model charges ``--dispatch-time``
of host scheduling plus ``--step-time`` of device compute per engine step;
a synchronous engine pays them serially, the async double-buffered engine
overlaps them (see :class:`repro.serve.sim.Simulator`). Modes:

* default — the new engine (paged KV pool + async dispatch) vs the PR 2
  engine (per-slot cache lanes, synchronous dispatch) vs one-request-at-a-
  time serving, all on the same trace. Outputs are asserted bit-identical
  across all three before any number is reported.
* ``--shared-prefix [N]`` — every request's prompt shares an N-token
  prefix; paged sharing (block-table adoption, mid-flight re-match, cold-
  prefill dedup) is compared against the same engine with sharing off and
  against the PR 2 sharing engine.
* ``--sliding-window [W]`` — a sliding-window config on the paged backend
  (ring block tables): the windowed paged engine vs the PR 2-style lane
  ring cache on a trace whose requests run well past the window. Outputs
  are asserted bit-identical (including the ring recycling), and the
  report carries the memory story: table entries per slot
  (``ceil(window/page_size) + 1`` vs the unwindowed ``ceil(max_len/
  page_size)``), the pool pages provisioned, and pages recycled.
* ``--kernel-bench`` — microbenchmark of the fused paged-attention Pallas
  kernel (interpret mode on CPU) against its pure-jax reference.
* ``--tp [N]`` — mesh-sharded serving: single device vs N-way
  tensor-parallel paged decode (pool arenas and attention heads sharded
  over a ``("model",)`` mesh under ``shard_map``) vs a 2-replica group of
  N-way members on disjoint device slices. Bit-identity is asserted
  across all three, and the report shows the arenas *split* (1/N of the
  single-device bytes per device), not duplicated. Needs forced host
  devices: ``XLA_FLAGS=--xla_force_host_platform_device_count=2N``.
* ``--open-loop [N]`` — N lazily generated open-loop arrivals (seeded
  bursty/Poisson/diurnal process, default 10⁵) at an offered load far
  above cluster capacity: SLO-aware scheduling (DRR over ``step_cost`` +
  TTFT shedding + deadline preemption) vs flat WRR, compared on goodput,
  p50/p99 TTFT, per-token latency, and SLO attainment. Two same-seed SLO
  runs are asserted bit-identical before any number is reported.
* ``--sampling [N]`` — deterministic stochastic sampling: N open-loop
  arrivals (default 2000) whose tenants carry per-request seeded
  :class:`~repro.serve.sampling.SamplingParams` through the full
  SLO-aware policy (shedding + preempt-and-requeue). Two same-seed
  sampled runs are asserted bit-identical, sampled streams must diverge
  from a greedy drive of the byte-identical arrivals, and the greedy
  control tenant's streams must not.
* ``--chaos [N]`` — chaos-tolerant serving: the sampling topology driven
  fault-free, then under a seeded
  :class:`~repro.serve.chaos.FaultPlan` (device-step failures, corrupted
  tokens, NaN logits, allocation failures, engine crashes, bank
  power-faults, prefix drops), then under the same plan again. Built-in
  assertions: completed requests are bit-identical to the fault-free
  run, no request is lost or double-completed, and the two same-seed
  chaos runs agree end to end. Reports recovery overhead and goodput
  retention under faults.
* ``--multi-model`` — the PR 4 cluster workload: two models / three
  engines (two replicas of one model sharing a namespace, plus a second
  model) on one ``ServeCluster`` — one shared ``PagePool``/``PageTable``
  — against the same three engines serving the same traffic isolated
  (private pools/tables). Outputs are asserted bit-identical per engine
  before any number is reported; the report carries cross-engine page
  reuse and the consolidated pool high-water vs the isolated pools.

``--json`` prints the report as JSON; ``--bench-json`` additionally merges
it into ``BENCH_serve.json`` at the repo root (``make bench-json`` runs
every mode), so the perf trajectory is tracked across PRs —
``tools/bench_table.py`` regenerates the README benchmark table from that
file and ``tools/docs_check.py`` fails the build when quoted numbers go
stale.

  PYTHONPATH=src python benchmarks/serve_bench.py --arch granite-3-2b \
      --requests 16 --slots 4 --gap 2.0 --new-tokens 8
  PYTHONPATH=src python benchmarks/serve_bench.py --shared-prefix \
      --requests 8 --prefill-chunk 4
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import pathlib
import time
from typing import Any

import jax

from repro import configs
from repro.models import registry
from repro.serve.engine import ContinuousBatchingEngine, Request
from repro.serve.sim import (FakeClock, Simulator, shared_prefix_requests,
                             staggered_trace)
from repro.sharding import params as P

REPO = pathlib.Path(__file__).resolve().parent.parent
RESULTS = REPO / "results" / "serve"
BENCH_JSON = REPO / "BENCH_serve.json"


def build_requests(n: int, prompt_len: int, new_tokens: int) -> list[Request]:
    return [
        Request(id=f"req{i}",
                prompt=[(11 * i + j) % 241 + 1 for j in range(prompt_len)],
                max_new_tokens=new_tokens)
        for i in range(n)
    ]


def run_once(cfg, params, args, *, mode: str, sequential: bool = False,
             requests=None, max_len=None, **engine_kwargs) -> tuple[dict, Any]:
    clock = FakeClock()
    eng = ContinuousBatchingEngine(cfg, params, slots=args.slots,
                                   max_len=max_len or args.max_len,
                                   clock=clock,
                                   prefill_chunk=args.prefill_chunk,
                                   **engine_kwargs)
    if requests is None:
        requests = build_requests(args.requests, args.prompt_len,
                                  args.new_tokens)
    trace = staggered_trace(requests, gap=args.gap)
    sim = Simulator(eng, trace, clock, step_time=args.step_time,
                    dispatch_time=args.dispatch_time, sequential=sequential)
    w0 = time.perf_counter()
    report = sim.run()
    wall = time.perf_counter() - w0
    lat = [r.finish_time - r.arrival_time for r in report.completed]
    return {
        "mode": mode,
        "backend": eng.stats()["backend"],
        "async_dispatch": eng.async_dispatch,
        "elapsed_sim": report.elapsed,
        "engine_steps": report.steps,
        "tokens": report.tokens_generated,
        "throughput_tok_per_sim_s": round(report.throughput, 4),
        "mean_latency_sim": round(sum(lat) / len(lat), 3),
        # nearest-rank p99: for n <= 100 this is the max (the tail straggler
        # must be visible, not floored away)
        "p99_latency_sim": round(
            sorted(lat)[max(0, math.ceil(0.99 * len(lat)) - 1)], 3),
        "wall_s": round(wall, 3),
        "wall_tok_per_s": round(report.tokens_generated / wall, 1),
    }, eng


def _tokens(eng) -> dict:
    return {r.id: tuple(r.tokens) for r in eng.completed}


def _assert_identical(named_engines) -> None:
    """The perf claim is only valid if the outputs are the same outputs."""
    (base_name, base), *rest = named_engines
    want = _tokens(base)
    for name, eng in rest:
        got = _tokens(eng)
        if got != want:
            raise AssertionError(
                f"outputs diverged: {name} != {base_name} — the engines "
                f"must be bit-identical before throughput is comparable")


def _print_mode(mode: dict) -> None:
    tag = "async" if mode["async_dispatch"] else "sync"
    print(f"{mode['mode']:>12} [{mode['backend']}/{tag}]: "
          f"{mode['tokens']} tokens in {mode['elapsed_sim']:.1f} sim-s "
          f"({mode['throughput_tok_per_sim_s']:.3f} tok/sim-s), "
          f"mean latency {mode['mean_latency_sim']:.2f} sim-s, "
          f"wall {mode['wall_s']:.2f}s")


def run_default(cfg, params, args) -> tuple[dict, float]:
    """New engine (paged + async double-buffered dispatch) vs the PR 2
    engine (cache lanes, synchronous) vs sequential, same trace."""
    new, eng_new = run_once(cfg, params, args, mode="async-paged",
                            async_dispatch=True)
    pr2, eng_pr2 = run_once(cfg, params, args, mode="pr2-sync", paged=False)
    seq, eng_seq = run_once(cfg, params, args, mode="sequential",
                            paged=False, sequential=True)
    _assert_identical([("pr2-sync", eng_pr2), ("async-paged", eng_new),
                       ("sequential", eng_seq)])
    async_speedup = (new["throughput_tok_per_sim_s"]
                     / pr2["throughput_tok_per_sim_s"])
    seq_speedup = (new["throughput_tok_per_sim_s"]
                   / seq["throughput_tok_per_sim_s"])
    out = {"arch": cfg.name, "requests": args.requests, "slots": args.slots,
           "gap": args.gap, "dispatch_time": args.dispatch_time,
           "step_time": args.step_time,
           "async_paged": new, "pr2_sync": pr2, "sequential": seq,
           "async_speedup_vs_pr2": round(async_speedup, 3),
           "speedup_vs_sequential": round(seq_speedup, 3)}
    if not args.json:
        for mode in (new, pr2, seq):
            _print_mode(mode)
        print(f"async paged dispatch vs PR 2 engine: {async_speedup:.2f}x "
              f"(vs sequential: {seq_speedup:.2f}x); outputs bit-identical")
    return out, async_speedup


def run_shared_prefix(cfg, params, args) -> tuple[dict, float]:
    """Same shared-prefix trace with paged sharing on/off and through the
    PR 2 sharing engine; the speedups isolate page reuse and async+paged."""
    prefix_len = args.shared_prefix
    make = lambda: shared_prefix_requests(
        args.requests, prefix_len=prefix_len, tail_len=args.tail_len,
        new_tokens=args.new_tokens)
    need = prefix_len + args.tail_len + args.new_tokens + 1
    max_len = max(args.max_len, need)
    shared, eng = run_once(cfg, params, args, mode="sharing",
                           requests=make(), max_len=max_len,
                           page_size=args.page_size, async_dispatch=True)
    plain, eng_plain = run_once(cfg, params, args, mode="no-sharing",
                                requests=make(), max_len=max_len,
                                async_dispatch=True)
    pr2, eng_pr2 = run_once(cfg, params, args, mode="pr2-sharing",
                            requests=make(), max_len=max_len,
                            page_size=args.page_size, paged=False)
    _assert_identical([("pr2-sharing", eng_pr2), ("sharing", eng),
                       ("no-sharing", eng_plain)])
    sharing_speedup = (shared["throughput_tok_per_sim_s"]
                       / plain["throughput_tok_per_sim_s"])
    vs_pr2 = (shared["throughput_tok_per_sim_s"]
              / pr2["throughput_tok_per_sim_s"])
    stats = eng.stats()
    pages = stats["pages"]
    out = {"arch": cfg.name, "requests": args.requests, "slots": args.slots,
           "gap": args.gap, "shared_prefix": prefix_len,
           "page_size": args.page_size, "prefill_chunk": args.prefill_chunk,
           "dispatch_time": args.dispatch_time, "step_time": args.step_time,
           "sharing": shared, "no_sharing": plain, "pr2_sharing": pr2,
           "pages": pages, "pool": stats.get("pool"),
           "stalls": stats["stalls"], "rematches": stats["rematches"],
           "sharing_speedup": round(sharing_speedup, 3),
           "async_speedup_vs_pr2": round(vs_pr2, 3)}
    if not args.json:
        for mode in (shared, plain, pr2):
            _print_mode(mode)
        print(f"pages: {pages['hits']} hits / {pages['misses']} misses, "
              f"{pages['tokens_reused']} prompt tokens reused, "
              f"{stats['rematches']} mid-flight re-matches, "
              f"{stats['stalls']} dedup stalls, "
              f"{pages['resident']} resident")
        print(f"prefix sharing speedup: {sharing_speedup:.2f}x; "
              f"vs PR 2 sharing engine: {vs_pr2:.2f}x; outputs bit-identical")
    return out, vs_pr2


def run_multi_model(args) -> tuple[dict, float]:
    """Multi-model cluster vs the same engines isolated.

    Three engines, two models: ``rep-a``/``rep-b`` serve ``--arch`` as
    replicas under one namespace (their shared-prefix traffic aliases
    *across* engines on the cluster), ``alt`` serves ``--arch-b`` in its
    own namespace (isolated prefixes, shared pool budget). ``rep-b`` is an
    elastic scale-out replica: its traffic starts after ``rep-a`` has
    absorbed the first wave — on the cluster it finds the shared prefix
    pages already resident (admitted pre-consumed, zero prefill for the
    hot prefix), while the isolated baseline pays the cold prefill again.
    The isolated baseline runs each engine on its own pool/table and own
    clock; since isolated engines run concurrently in real deployments,
    its aggregate throughput is total tokens over the slowest engine's
    span.
    """
    from repro.serve.cluster import ServeCluster
    from repro.serve.sim import ClusterSimulator, tag_engine

    cfg_a = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg_b = (configs.smoke(args.arch_b) if args.smoke
             else configs.get(args.arch_b))
    params_a = P.init_tree(registry.decls(cfg_a), jax.random.key(args.seed))
    params_b = P.init_tree(registry.decls(cfg_b),
                           jax.random.key(args.seed + 1))

    n = max(2, args.requests // 2)
    prefix_len, ps = args.shared_prefix or 16, args.page_size
    need = prefix_len + args.tail_len + args.new_tokens + 1
    max_len = max(args.max_len, need)
    alt_prefix = [(19 * j) % 239 + 2 for j in range(prefix_len)]
    make = {
        "rep-a": lambda: shared_prefix_requests(
            n, prefix_len=prefix_len, tail_len=args.tail_len,
            new_tokens=args.new_tokens, id_prefix="ga"),
        "rep-b": lambda: shared_prefix_requests(
            n, prefix_len=prefix_len, tail_len=args.tail_len,
            new_tokens=args.new_tokens, id_prefix="gb"),
        "alt": lambda: shared_prefix_requests(
            n, prefix_len=prefix_len, tail_len=args.tail_len,
            new_tokens=args.new_tokens, prefix=alt_prefix, id_prefix="sl"),
    }
    members = [("rep-a", cfg_a, params_a, cfg_a.name),
               ("rep-b", cfg_a, params_a, cfg_a.name),
               ("alt", cfg_b, params_b, cfg_b.name)]
    np_max = -(-max_len // ps)
    pool_pages = 3 * args.slots * np_max + 16
    # rep-b scales out mid-run: its trace starts once rep-a's first wave
    # is underway, so the shared prefix is resident on the cluster
    starts = {"rep-a": 0.0, "rep-b": n * args.gap, "alt": 0.0}

    def isolated(name, cfg, params):
        clock = FakeClock()
        eng = ContinuousBatchingEngine(
            cfg, params, slots=args.slots, max_len=max_len, clock=clock,
            prefill_chunk=args.prefill_chunk, page_size=ps)
        sim = Simulator(eng, staggered_trace(make[name](), gap=args.gap,
                                             start=starts[name]),
                        clock, step_time=args.step_time,
                        dispatch_time=args.dispatch_time)
        return eng, sim.run()

    w0 = time.perf_counter()
    iso = {name: isolated(name, cfg, params)
           for name, cfg, params, _ in members}
    iso_wall = time.perf_counter() - w0

    clock = FakeClock()
    cluster = ServeCluster(pool_pages=pool_pages, page_size=ps, clock=clock)
    for name, cfg, params, ns in members:
        cluster.add_engine(cfg, params, name=name, namespace=ns,
                           slots=args.slots, max_len=max_len,
                           prefill_chunk=args.prefill_chunk)
    trace = [a for name, _, _, _ in members
             for a in tag_engine(staggered_trace(make[name](), gap=args.gap,
                                                 start=starts[name]), name)]
    w0 = time.perf_counter()
    rep = ClusterSimulator(cluster, trace, clock, step_time=args.step_time,
                           dispatch_time=args.dispatch_time).run()
    wall = time.perf_counter() - w0

    # the perf claim is only valid if the outputs are the same outputs
    for name, _, _, _ in members:
        _assert_identical([(f"isolated:{name}", iso[name][0]),
                           (f"cluster:{name}", cluster.engines[name])])

    iso_tokens = sum(r.tokens_generated for _, r in iso.values())
    iso_elapsed = max(r.elapsed for _, r in iso.values())
    iso_tp = iso_tokens / iso_elapsed
    speedup = rep.throughput / iso_tp
    engines = {name: {
        "arch": eng.cfg.name,
        "namespace": eng.namespace,
        "prompt_tokens_reused": eng.prompt_tokens_reused,
        "prompt_tokens_processed": eng.prompt_tokens_processed,
        "rematches": eng.rematches,
    } for name, eng in cluster.engines.items()}
    cstats = cluster.stats()
    out = {"arch": cfg_a.name, "arch_b": cfg_b.name,
           "requests_per_engine": n, "slots": args.slots, "gap": args.gap,
           "shared_prefix": prefix_len, "page_size": ps,
           "prefill_chunk": args.prefill_chunk,
           "dispatch_time": args.dispatch_time, "step_time": args.step_time,
           "cluster": {
               "elapsed_sim": rep.elapsed, "steps": rep.steps,
               "tokens": rep.tokens_generated,
               "throughput_tok_per_sim_s": round(rep.throughput, 4),
               "wall_s": round(wall, 3),
               "pool_pages": pool_pages,
               "pool_device_pages": cluster.pool.device_pages,
               "pool_high_water": cstats["pool"]["high_water"],
               "table_resident_by_ns": cstats["table"]["by_namespace"],
               "engines": engines,
           },
           "isolated": {
               "elapsed_sim": iso_elapsed, "tokens": iso_tokens,
               "throughput_tok_per_sim_s": round(iso_tp, 4),
               "wall_s": round(iso_wall, 3),
               "pool_pages_total": sum(e._pool.n_pages
                                       for e, _ in iso.values()),
               "pool_device_pages_total": sum(e._pool.device_pages
                                              for e, _ in iso.values()),
               "pool_high_water_total": sum(e._pool.stats["high_water"]
                                            for e, _ in iso.values()),
           },
           "cluster_speedup_vs_isolated": round(speedup, 3)}
    if not args.json:
        print(f"cluster [3 engines, 2 models, one {pool_pages}-id pool, "
              f"{cluster.pool.device_pages} device pages across "
              f"{len(cluster.pool._arenas)} arenas]: "
              f"{rep.tokens_generated} tokens in {rep.elapsed:.1f} "
              f"sim-s ({rep.throughput:.3f} tok/sim-s), pool high-water "
              f"{cstats['pool']['high_water']}")
        print(f"isolated [3 engines, private pools, "
              f"{out['isolated']['pool_device_pages_total']} device pages "
              f"total]: {iso_tokens} tokens in {iso_elapsed:.1f} sim-s "
              f"({iso_tp:.3f} tok/sim-s), pool high-water "
              f"{out['isolated']['pool_high_water_total']}")
        for name, st in engines.items():
            print(f"  {name} [{st['arch']} ns={st['namespace']}]: "
                  f"{st['prompt_tokens_reused']} prompt tokens reused")
        print(f"cluster vs isolated: {speedup:.2f}x aggregate tokens/s; "
              f"outputs bit-identical per engine")
    return out, speedup


def run_sliding_window(args) -> tuple[dict, float]:
    """Sliding-window serving on the paged backend vs the lane ring cache.

    The config is ``--arch``'s smoke model with ``sliding_window`` set to
    the flag's value; prompts and generations run well past the window so
    every slot recycles ring pages. Three engines on the same trace:
    the windowed paged engine with async dispatch (the new path), the same
    backend synchronous, and the lane ring cache (the fallback this PR
    retires) — bit-identity asserted across all three before any number
    is reported. The memory claim is structural: a windowed slot's block
    table has ``ceil(window/page_size) + 1`` entries, so the engine
    provisions O(window) pool pages per slot instead of O(max_len).
    """
    base = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    w = args.sliding_window
    cfg = dataclasses.replace(base, name=f"{base.name}-swa{w}",
                              sliding_window=w)
    params = P.init_tree(registry.decls(cfg), jax.random.key(args.seed))
    prompt_len = max(args.prompt_len, w + args.page_size)
    max_len = max(args.max_len, prompt_len + args.new_tokens + 1)
    make = lambda: build_requests(args.requests, prompt_len, args.new_tokens)

    paged, eng = run_once(cfg, params, args, mode="swa-paged-async",
                          requests=make(), max_len=max_len,
                          page_size=args.page_size, async_dispatch=True)
    sync, eng_sync = run_once(cfg, params, args, mode="swa-paged-sync",
                              requests=make(), max_len=max_len,
                              page_size=args.page_size)
    lanes, eng_lanes = run_once(cfg, params, args, mode="swa-lane-ring",
                                requests=make(), max_len=max_len,
                                page_size=args.page_size, paged=False)
    _assert_identical([("swa-lane-ring", eng_lanes),
                       ("swa-paged-sync", eng_sync),
                       ("swa-paged-async", eng)])
    assert eng.stats()["backend"] == "paged", "SWA must run the paged backend"
    speedup = (paged["throughput_tok_per_sim_s"]
               / lanes["throughput_tok_per_sim_s"])
    stats = eng.stats()
    np_unwindowed = -(-max_len // args.page_size)
    out = {"arch": cfg.name, "window": w, "requests": args.requests,
           "slots": args.slots, "gap": args.gap, "prompt_len": prompt_len,
           "new_tokens": args.new_tokens, "max_len": max_len,
           "page_size": args.page_size, "prefill_chunk": args.prefill_chunk,
           "dispatch_time": args.dispatch_time, "step_time": args.step_time,
           "paged_async": paged, "paged_sync": sync, "lane_ring": lanes,
           "table_entries_per_slot": stats["table_entries_per_slot"],
           "unwindowed_pages_per_slot": np_unwindowed,
           "pages_recycled": stats["pages_recycled"],
           "pool": stats["pool"],
           "paged_speedup_vs_lane_ring": round(speedup, 3)}
    if not args.json:
        for mode in (paged, sync, lanes):
            _print_mode(mode)
        print(f"ring block tables: {stats['table_entries_per_slot']} "
              f"entries/slot (window {w} / page {args.page_size}) vs "
              f"{np_unwindowed} unwindowed; pool "
              f"{stats['pool']['pages']} pages, high-water "
              f"{stats['pool']['high_water']}, "
              f"{stats['pages_recycled']} pages recycled")
        print(f"windowed paged (async) vs lane ring cache: {speedup:.2f}x "
              f"tokens/s; outputs bit-identical")
    return out, speedup


def run_open_loop(args) -> tuple[dict, float]:
    """Open-loop traffic at 10⁵-request scale: SLO-aware vs flat WRR.

    A lazily generated bursty arrival trace (``repro.serve.loadgen``) is
    driven through a 3-engine cluster at an offered load far above
    capacity — arrivals never wait for the system, so queues build,
    backpressure rejects, and the question becomes *goodput*: tokens
    delivered inside each request's SLO. Two scheduling policies serve
    the byte-identical trace:

    * ``slo_sched`` — deficit-weighted round-robin over ``step_cost()``
      plus latency-SLO admission control (shed queue heads that already
      blew their TTFT budget) plus preempt-and-requeue of decoding
      requests past their deadline.
    * ``flat_wrr`` — the PR 4 scheduler: fixed grants, FIFO heads, no
      shedding. Under overload it serves a stale backlog, so most of its
      completions bust their TTFT target.

    Determinism is asserted, not assumed: the SLO run executes twice from
    two independently constructed clusters and generators, and the
    reports, metric summaries, and every request's token stream must be
    bit-identical. Requests completed by both policies must also produce
    identical tokens (scheduling may reorder work, never change it).
    """
    from repro.serve.cluster import SchedPolicy, ServeCluster
    from repro.serve.loadgen import TenantSpec, open_loop_trace
    from repro.serve.metrics import SLO, ServeMetrics
    from repro.serve.sim import ClusterSimulator

    n, rate = args.open_loop, args.open_loop_rate
    cfg_a = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg_b = (configs.smoke(args.arch_b) if args.smoke
             else configs.get(args.arch_b))
    params_a = P.init_tree(registry.decls(cfg_a), jax.random.key(args.seed))
    params_b = P.init_tree(registry.decls(cfg_b),
                           jax.random.key(args.seed + 1))

    ttft_cap, tpot_rep, tpot_alt = 25.0, 4.0, 1.0
    # two replicas of one model (shared namespace + prefix_seed: their
    # bursts exercise cross-engine cold-prefill dedup) and one long-output
    # tenant whose tight per-token budget makes its tails preemptable
    tenants = [
        TenantSpec(engine="rep-a", share=1.0, prompt_len=(6, 18),
                   new_tokens=(4, 10), prefix_len=8, prefix_seed=7,
                   slo=SLO(ttft=ttft_cap, tpot=tpot_rep)),
        TenantSpec(engine="rep-b", share=1.0, prompt_len=(6, 18),
                   new_tokens=(4, 10), prefix_len=8, prefix_seed=7,
                   slo=SLO(ttft=ttft_cap, tpot=tpot_rep)),
        TenantSpec(engine="alt", share=0.5, prompt_len=(4, 12),
                   new_tokens=(16, 28), prefix_len=6, prefix_seed=3,
                   slo=SLO(ttft=ttft_cap, tpot=tpot_alt)),
    ]
    max_len = {"rep-a": 32, "rep-b": 32, "alt": 48}
    ps = 8
    pool_pages = sum(args.slots * -(-m // ps) for m in max_len.values()) + 24

    def drive(policy):
        clock = FakeClock()
        cluster = ServeCluster(pool_pages=pool_pages, page_size=ps,
                               clock=clock, policy=policy)
        for name, cfg, params, ns in (
                ("rep-a", cfg_a, params_a, cfg_a.name),
                ("rep-b", cfg_a, params_a, cfg_a.name),
                ("alt", cfg_b, params_b, cfg_b.name)):
            cluster.add_engine(cfg, params, name=name, namespace=ns,
                               slots=args.slots, max_len=max_len[name],
                               prefill_chunk=args.prefill_chunk,
                               queue_capacity=args.queue_capacity)
        trace = open_loop_trace(tenants, n_requests=n, rate=rate,
                                seed=args.seed,
                                process=args.open_loop_process)
        sim = ClusterSimulator(cluster, trace, clock,
                               step_time=args.step_time,
                               dispatch_time=args.dispatch_time)
        w0 = time.perf_counter()
        report = sim.run(max_steps=5_000_000)
        wall = time.perf_counter() - w0
        metrics = ServeMetrics()
        tokens = {}
        for eng in cluster.engines.values():
            metrics.observe_all(eng.completed)
            tokens.update((r.id, tuple(r.tokens)) for r in eng.completed)
        return report, metrics.summary(elapsed=report.elapsed), tokens, \
            cluster, wall

    def digest(report, summary, tokens):
        return (report.elapsed, report.steps, report.tokens_generated,
                report.rejected, report.shed,
                {k: [r.id for r in v] for k, v in report.completed.items()},
                summary, tokens)

    slo_policy = SchedPolicy(scheduler="drr", shed_busted=True,
                             preempt_busted=True)
    rep1, sum1, tok1, cl1, wall1 = drive(slo_policy)
    rep2, sum2, tok2, cl2, _ = drive(slo_policy)
    if digest(rep1, sum1, tok1) != digest(rep2, sum2, tok2):
        raise AssertionError(
            "open-loop run is not deterministic: two same-seed runs "
            "diverged — the trace/scheduler must be bit-reproducible")
    compare = not args.open_loop_skip_flat
    if compare:
        flat, sumf, tokf, clf, wallf = drive(SchedPolicy())
        common = tok1.keys() & tokf.keys()
        diverged = [i for i in common if tok1[i] != tokf[i]]
        if diverged:
            raise AssertionError(
                f"{len(diverged)} requests produced different tokens under "
                "the two schedulers (e.g. "
                f"{sorted(diverged)[:3]}) — scheduling must never change "
                "outputs")
        gain = (sum1["goodput"] / sumf["goodput"]
                if sumf.get("goodput") else float("inf"))
    else:
        gain = 1.0

    def mode(tag, report, summary, cluster, wall):
        return {
            "policy": tag, "elapsed_sim": report.elapsed,
            "rounds": report.steps, "tokens": report.tokens_generated,
            "served": summary["completed"], "rejected": report.rejected,
            "shed": report.shed, "slo_preempts": cluster.slo_preempts,
            "ttft_p50": round(summary["ttft_p50"], 3),
            "ttft_p99": round(summary["ttft_p99"], 3),
            "tpot_p50": round(summary["tpot_p50"], 3),
            "tpot_p99": round(summary["tpot_p99"], 3),
            "slo_attainment": round(summary["slo_attainment"], 4),
            "goodput_tok_per_sim_s": round(summary["goodput"], 4),
            "throughput_tok_per_sim_s": round(report.throughput, 4),
            "wall_s": round(wall, 3),
        }

    out = {"arch": cfg_a.name, "arch_b": cfg_b.name, "requests": n,
           "rate": rate, "process": args.open_loop_process, "engines": 3,
           "slots": args.slots, "queue_capacity": args.queue_capacity,
           "page_size": ps, "prefill_chunk": args.prefill_chunk,
           "dispatch_time": args.dispatch_time, "step_time": args.step_time,
           "slo": {"ttft": ttft_cap, "tpot_rep": tpot_rep,
                   "tpot_alt": tpot_alt},
           "slo_sched": mode("drr+shed+preempt", rep1, sum1, cl1, wall1),
           "deterministic": True}
    if compare:
        out["flat_wrr"] = mode("wrr", flat, sumf, clf, wallf)
        out["goodput_gain"] = round(gain, 3)
    if n >= 10_000:
        # at bench scale the SLO machinery must demonstrably engage and win
        assert rep1.shed > 0, "no SLO-busted heads were shed"
        assert cl1.slo_preempts > 0, "no SLO-busting tails were preempted"
        if compare:
            assert gain > 1.0, (
                f"SLO-aware scheduling must beat flat WRR on goodput "
                f"(got {gain:.3f}x)")
    if not args.json:
        for m in ([out["slo_sched"], out["flat_wrr"]] if compare
                  else [out["slo_sched"]]):
            print(f"{m['policy']:>16}: {m['served']} served / "
                  f"{m['rejected']} rejected / {m['shed']} shed of {n} "
                  f"arrivals in {m['elapsed_sim']:.0f} sim-s; TTFT p50/p99 "
                  f"{m['ttft_p50']:.1f}/{m['ttft_p99']:.1f}, TPOT p99 "
                  f"{m['tpot_p99']:.2f}, attainment "
                  f"{m['slo_attainment']:.1%}, goodput "
                  f"{m['goodput_tok_per_sim_s']:.3f} tok/sim-s")
        if compare:
            print(f"SLO-aware vs flat WRR goodput: {gain:.2f}x; two "
                  f"same-seed runs bit-identical ({n} open-loop arrivals)")
        else:
            print(f"two same-seed runs bit-identical ({n} open-loop "
                  f"arrivals; flat-WRR comparison skipped)")
    return out, gain


def run_sampling(args) -> tuple[dict, float]:
    """Deterministic stochastic sampling at open-loop scale.

    One engine under the full SLO-aware cluster policy serves a bursty
    open-loop mix of three tenants — one hot (temperature + top-k +
    top-p), one nucleus-only, one greedy control — at an offered load
    far above capacity, so the run exercises sampling through queue
    buildup, shedding, and SLO preempt-and-requeue. Three drives over the
    *byte-identical* arrival sequence (materialised once, fresh Request
    objects per drive):

    * sampled, twice: the per-request journaled PRNG chains must make the
      two runs bit-identical — reports, metric summaries, every token.
    * greedy (the same requests with ``sampling`` stripped): the sampled
      tenants' streams must actually diverge from greedy decode, and the
      greedy control tenant's streams must be bit-identical across the
      sampled and stripped drives (a neighbour's PRNG never leaks).

    Run with ``--open-loop-rate 40`` (the ``make bench-json`` line): still
    far above the three engines' capacity, but admitting enough of the
    tight-TPOT tenant's long decodes that deadline preempt-and-requeue
    demonstrably engages — at rate 100 nearly everything is rejected at
    the queue and nothing lives long enough to be demoted.
    """
    from repro.serve.cluster import SchedPolicy, ServeCluster
    from repro.serve.loadgen import TenantSpec, open_loop_trace
    from repro.serve.metrics import SLO, ServeMetrics
    from repro.serve.sampling import SamplingParams
    from repro.serve.sim import Arrival, ClusterSimulator

    n, rate = args.sampling, args.open_loop_rate
    cfg_a = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg_b = (configs.smoke(args.arch_b) if args.smoke
             else configs.get(args.arch_b))
    params_a = P.init_tree(registry.decls(cfg_a), jax.random.key(args.seed))
    params_b = P.init_tree(registry.decls(cfg_b),
                           jax.random.key(args.seed + 1))

    # the run_open_loop topology — two replicas plus a preemptable
    # long-output tenant — with sampling attached: hot sampling on rep-a,
    # greedy control on rep-b (same model, same namespace), nucleus
    # sampling on the tight-TPOT tenant whose tails get demoted
    hot = SamplingParams(temperature=0.8, top_k=40, top_p=0.95)
    nucleus = SamplingParams(temperature=1.0, top_p=0.9)
    tenants = [
        TenantSpec(engine="rep-a", share=1.0, prompt_len=(6, 18),
                   new_tokens=(4, 10), prefix_len=8, prefix_seed=7,
                   slo=SLO(ttft=25.0, tpot=4.0), sampling=hot),
        TenantSpec(engine="rep-b", share=1.0, prompt_len=(6, 18),
                   new_tokens=(4, 10), prefix_len=8, prefix_seed=7,
                   slo=SLO(ttft=25.0, tpot=4.0)),
        TenantSpec(engine="alt", share=0.5, prompt_len=(4, 12),
                   new_tokens=(16, 28), slo=SLO(ttft=25.0, tpot=1.0),
                   sampling=nucleus),
    ]
    max_len = {"rep-a": 32, "rep-b": 32, "alt": 48}
    ps = 8
    pool_pages = sum(args.slots * -(-m // ps) for m in max_len.values()) + 24
    # materialise the arrival sequence once; every drive rebuilds fresh
    # Request objects from it (requests are engine-mutated, and the greedy
    # drive must see the very same prompts with only `sampling` stripped)
    base = [(a.time, a.request.id, tuple(a.request.prompt),
             a.request.max_new_tokens, a.request.slo, a.request.sampling,
             a.engine)
            for a in open_loop_trace(tenants, n_requests=n, rate=rate,
                                     seed=args.seed,
                                     process=args.open_loop_process)]
    sampled_ids = {rid for _, rid, _, _, _, sp, _ in base if sp is not None}

    def drive(strip):
        clock = FakeClock()
        cluster = ServeCluster(pool_pages=pool_pages, page_size=ps,
                               clock=clock,
                               policy=SchedPolicy(scheduler="drr",
                                                  shed_busted=True,
                                                  preempt_busted=True))
        for name, cfg, params, ns in (
                ("rep-a", cfg_a, params_a, cfg_a.name),
                ("rep-b", cfg_a, params_a, cfg_a.name),
                ("alt", cfg_b, params_b, cfg_b.name)):
            cluster.add_engine(cfg, params, name=name, namespace=ns,
                               slots=args.slots, max_len=max_len[name],
                               prefill_chunk=args.prefill_chunk,
                               queue_capacity=args.queue_capacity)
        trace = (Arrival(t, Request(id=rid, prompt=list(p),
                                    max_new_tokens=m, slo=slo,
                                    sampling=None if strip else sp), e)
                 for t, rid, p, m, slo, sp, e in base)
        sim = ClusterSimulator(cluster, trace, clock,
                               step_time=args.step_time,
                               dispatch_time=args.dispatch_time)
        w0 = time.perf_counter()
        report = sim.run(max_steps=5_000_000)
        wall = time.perf_counter() - w0
        metrics = ServeMetrics()
        tokens, sampled_served = {}, 0
        for eng in cluster.engines.values():
            metrics.observe_all(eng.completed)
            tokens.update((r.id, tuple(r.tokens)) for r in eng.completed)
            sampled_served += eng.sampled_requests
        return (report, metrics.summary(elapsed=report.elapsed),
                tokens, cluster, sampled_served, wall)

    def digest(report, summary, tokens):
        return (report.elapsed, report.steps, report.tokens_generated,
                report.rejected, report.shed, summary, tokens)

    rep1, sum1, tok1, cl1, samp1, wall1 = drive(strip=False)
    rep2, sum2, tok2, _, _, _ = drive(strip=False)
    if digest(rep1, sum1, tok1) != digest(rep2, sum2, tok2):
        raise AssertionError(
            "sampled open-loop run is not deterministic: two same-seed "
            "runs diverged — the journaled per-request PRNG chains must "
            "make sampling bit-reproducible")
    repg, sumg, tokg, clg, sampg, wallg = drive(strip=True)

    common = tok1.keys() & tokg.keys()
    greedy_ctl = [i for i in common if i not in sampled_ids]
    leaked = [i for i in greedy_ctl if tok1[i] != tokg[i]]
    if leaked:
        raise AssertionError(
            f"{len(leaked)} greedy-tenant requests changed tokens when "
            f"their neighbours sampled (e.g. {sorted(leaked)[:3]}) — "
            "per-lane PRNG state must not leak across slots")
    sampled_common = [i for i in common if i in sampled_ids]
    diverged = [i for i in sampled_common if tok1[i] != tokg[i]]
    frac = len(diverged) / len(sampled_common) if sampled_common else 0.0
    if sampled_common and frac <= 0.5:
        raise AssertionError(
            f"only {len(diverged)}/{len(sampled_common)} sampled requests "
            "diverged from greedy decode — sampling is not actually "
            "engaging")

    def mode(tag, report, summary, cluster, sampled_served, wall):
        return {
            "mode": tag, "elapsed_sim": report.elapsed,
            "tokens": report.tokens_generated,
            "served": summary["completed"], "rejected": report.rejected,
            "shed": report.shed, "slo_preempts": cluster.slo_preempts,
            "sampled_requests": sampled_served,
            "slo_attainment": round(summary["slo_attainment"], 4),
            "goodput_tok_per_sim_s": round(summary["goodput"], 4),
            "throughput_tok_per_sim_s": round(report.throughput, 4),
            "wall_s": round(wall, 3),
        }

    out = {"arch": cfg_a.name, "arch_b": cfg_b.name, "requests": n,
           "rate": rate, "process": args.open_loop_process, "engines": 3,
           "slots": args.slots,
           "queue_capacity": args.queue_capacity, "page_size": ps,
           "prefill_chunk": args.prefill_chunk,
           "dispatch_time": args.dispatch_time, "step_time": args.step_time,
           "tenants": {"hot": {"temperature": hot.temperature,
                               "top_k": hot.top_k, "top_p": hot.top_p},
                       "nucleus": {"temperature": nucleus.temperature,
                                   "top_p": nucleus.top_p},
                       "greedy_share": 0.5},
           "sampled": mode("sampled", rep1, sum1, cl1, samp1, wall1),
           "greedy": mode("greedy", repg, sumg, clg, sampg, wallg),
           "divergence": {
               "common_served": len(common),
               "sampled_common": len(sampled_common),
               "diverged_vs_greedy": len(diverged),
               "diverged_frac": round(frac, 4),
               "greedy_tenant_identical": True,
           },
           "deterministic": True}
    if n >= 2_000 and rate <= 50.0:
        # at bench scale the replay machinery must demonstrably engage
        assert cl1.slo_preempts > 0, "no sampled decode was SLO-preempted"
        assert rep1.shed > 0, "no SLO-busted heads were shed"
    if not args.json:
        for m in (out["sampled"], out["greedy"]):
            print(f"{m['mode']:>8}: {m['served']} served / "
                  f"{m['rejected']} rejected / {m['shed']} shed of {n}; "
                  f"{m['tokens']} tokens in {m['elapsed_sim']:.0f} sim-s, "
                  f"{m['slo_preempts']} SLO preempts, "
                  f"{m['sampled_requests']} sampled admissions")
        print(f"two same-seed sampled runs bit-identical; "
              f"{len(diverged)}/{len(sampled_common)} sampled streams "
              f"diverged from greedy ({frac:.1%}); greedy tenant untouched")
    return out, frac


def run_chaos(args) -> tuple[dict, float]:
    """Chaos-tolerant serving at open-loop scale.

    The ``run_sampling`` topology (three engines, hot/nucleus/greedy
    tenants, full SLO-aware policy) is driven three times over the
    byte-identical arrival sequence:

    * fault-free — the reference run;
    * chaos — a seeded :class:`~repro.serve.chaos.FaultPlan` injects
      device-step failures, corrupted tokens, NaN logits, page-allocation
      failures, engine crashes, bank power-faults, and prefix-match drops
      while the cluster recovers (retry-with-backoff, corruption
      quarantine + journal replay, watchdog-gated crash rebuild);
    * chaos again, same seed — the determinism control.

    Built-in assertions (the tentpole invariant): every request completed
    by both the fault-free and the chaos run has bit-identical tokens;
    within each run every submitted request is accounted exactly once
    (completed + shed + rejected, no duplicates); and the two same-seed
    chaos runs are bit-identical end to end, fault schedule included.
    Reported: injections by kind, recovery counters (retries, replays,
    rebuilds), recovery overhead (extra sim-time under faults), and
    goodput retention (chaos goodput / fault-free goodput).
    """
    from repro.runtime.ft import FTConfig
    from repro.serve.chaos import FaultPlan, FaultSpec
    from repro.serve.cluster import SchedPolicy, ServeCluster
    from repro.serve.loadgen import TenantSpec, open_loop_trace
    from repro.serve.metrics import SLO, ServeMetrics
    from repro.serve.sampling import SamplingParams
    from repro.serve.sim import Arrival, ClusterSimulator

    n, rate = args.chaos, args.open_loop_rate
    cfg_a = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg_b = (configs.smoke(args.arch_b) if args.smoke
             else configs.get(args.arch_b))
    params_a = P.init_tree(registry.decls(cfg_a), jax.random.key(args.seed))
    params_b = P.init_tree(registry.decls(cfg_b),
                           jax.random.key(args.seed + 1))

    hot = SamplingParams(temperature=0.8, top_k=40, top_p=0.95)
    tenants = [
        TenantSpec(engine="rep-a", share=1.0, prompt_len=(6, 18),
                   new_tokens=(4, 10), prefix_len=8, prefix_seed=7,
                   slo=SLO(ttft=25.0, tpot=4.0), sampling=hot),
        TenantSpec(engine="rep-b", share=1.0, prompt_len=(6, 18),
                   new_tokens=(4, 10), prefix_len=8, prefix_seed=7,
                   slo=SLO(ttft=25.0, tpot=4.0)),
        TenantSpec(engine="alt", share=0.5, prompt_len=(4, 12),
                   new_tokens=(16, 28), slo=SLO(ttft=25.0, tpot=1.0)),
    ]
    max_len = {"rep-a": 32, "rep-b": 32, "alt": 48}
    ps = 8
    pool_pages = sum(args.slots * -(-m // ps) for m in max_len.values()) + 24
    base = [(a.time, a.request.id, tuple(a.request.prompt),
             a.request.max_new_tokens, a.request.slo, a.request.sampling,
             a.engine)
            for a in open_loop_trace(tenants, n_requests=n, rate=rate,
                                     seed=args.seed,
                                     process=args.open_loop_process)]
    # modest per-point rates; crashes are budgeted (each one costs an
    # exponentially growing restart backoff, so an unbounded crash count
    # would spend the whole run waiting out restarts)
    spec = FaultSpec(step_fail=0.01, token_corrupt=0.01, nan_logits=0.01,
                     alloc_fail=0.002, engine_crash=0.002, bank_fault=0.004,
                     prefix_drop=0.05)
    fault_budget = {"engine_crash": 4, "bank_fault": 12}

    def drive(plan):
        clock = FakeClock()
        cluster = ServeCluster(
            pool_pages=pool_pages, page_size=ps, clock=clock,
            policy=SchedPolicy(scheduler="drr", shed_busted=True,
                               preempt_busted=True),
            chaos=plan,
            watchdog=(FTConfig(max_restarts=64, backoff_base_s=1.0)
                      if plan is not None else None))
        for name, cfg, params, ns in (
                ("rep-a", cfg_a, params_a, cfg_a.name),
                ("rep-b", cfg_a, params_a, cfg_a.name),
                ("alt", cfg_b, params_b, cfg_b.name)):
            cluster.add_engine(cfg, params, name=name, namespace=ns,
                               slots=args.slots, max_len=max_len[name],
                               prefill_chunk=args.prefill_chunk,
                               queue_capacity=args.queue_capacity)
        trace = (Arrival(t, Request(id=rid, prompt=list(p),
                                    max_new_tokens=m, slo=slo, sampling=sp),
                         e)
                 for t, rid, p, m, slo, sp, e in base)
        sim = ClusterSimulator(cluster, trace, clock,
                               step_time=args.step_time,
                               dispatch_time=args.dispatch_time)
        w0 = time.perf_counter()
        report = sim.run(max_steps=5_000_000)
        wall = time.perf_counter() - w0
        metrics = ServeMetrics()
        tokens = {}
        for eng in cluster.engines.values():
            metrics.observe_all(eng.completed)
            tokens.update((r.id, tuple(r.tokens)) for r in eng.completed)
        # accounting: every submitted request lands in exactly one bucket
        done = sum(len(e.completed) for e in cluster.engines.values())
        dup = done - len(tokens)
        if dup:
            raise AssertionError(
                f"{dup} requests completed more than once under faults — "
                "crash re-admission must never duplicate finished work")
        total = done + report.rejected + report.shed
        if total != n:
            raise AssertionError(
                f"request accounting broke under faults: {done} completed "
                f"+ {report.rejected} rejected + {report.shed} shed = "
                f"{total} != {n} submitted — work was lost")
        return (report, metrics.summary(elapsed=report.elapsed),
                tokens, cluster, wall)

    rep0, sum0, tok0, cl0, wall0 = drive(None)
    plan1 = FaultPlan(args.seed, spec, budget=dict(fault_budget))
    rep1, sum1, tok1, cl1, wall1 = drive(plan1)

    def digest(report, summary, tokens, cluster):
        return (report.elapsed, report.steps, report.tokens_generated,
                report.rejected, report.shed, summary, tokens,
                cluster.stats()["faults"])

    if not args.chaos_skip_twin:
        plan2 = FaultPlan(args.seed, spec, budget=dict(fault_budget))
        rep2, sum2, tok2, cl2, _ = drive(plan2)
        if digest(rep1, sum1, tok1, cl1) != digest(rep2, sum2, tok2, cl2):
            raise AssertionError(
                "chaos run is not deterministic: two same-seed fault "
                "schedules diverged — every injection draw and every "
                "recovery must be seeded")
    common = tok0.keys() & tok1.keys()
    diverged = [i for i in sorted(common) if tok0[i] != tok1[i]]
    if diverged:
        raise AssertionError(
            f"{len(diverged)} requests completed with different tokens "
            f"under faults (e.g. {diverged[:3]}) — recovery must replay "
            "bit-identically")
    faults = cl1.stats()["faults"]
    if n >= 1_000:
        quiet = [k for k, c in plan1.counts.items() if c == 0]
        assert not quiet, f"fault kinds never injected at scale: {quiet}"
        assert faults["replays"] > 0, "no corruption quarantine replayed"
        assert faults["rebuilds"] > 0, "no crash rebuild engaged"

    goodput_retention = (sum1["goodput"] / sum0["goodput"]
                         if sum0["goodput"] else 0.0)
    overhead = ((rep1.elapsed - rep0.elapsed) / rep0.elapsed
                if rep0.elapsed else 0.0)

    def mode(tag, report, summary, cluster, wall):
        return {
            "mode": tag, "elapsed_sim": report.elapsed,
            "tokens": report.tokens_generated,
            "served": summary["completed"], "rejected": report.rejected,
            "shed": report.shed,
            "slo_attainment": round(summary["slo_attainment"], 4),
            "goodput_tok_per_sim_s": round(summary["goodput"], 4),
            "throughput_tok_per_sim_s": round(report.throughput, 4),
            "wall_s": round(wall, 3),
        }

    out = {"arch": cfg_a.name, "arch_b": cfg_b.name, "requests": n,
           "rate": rate, "process": args.open_loop_process, "engines": 3,
           "slots": args.slots, "queue_capacity": args.queue_capacity,
           "page_size": ps, "prefill_chunk": args.prefill_chunk,
           "dispatch_time": args.dispatch_time, "step_time": args.step_time,
           "fault_spec": dataclasses.asdict(spec),
           "fault_budget": fault_budget,
           "injected": dict(plan1.counts),
           "recovery": {k: faults[k] for k in
                        ("step_faults", "alloc_faults", "token_faults",
                         "replays", "retries", "crashes", "bank_faults",
                         "rebuilds")},
           "fault_free": mode("fault_free", rep0, sum0, cl0, wall0),
           "chaos": mode("chaos", rep1, sum1, cl1, wall1),
           "bit_identity": {
               "common_served": len(common),
               "diverged_vs_fault_free": 0,
               "duplicated": 0, "lost": 0,
           },
           "recovery_overhead_frac": round(overhead, 4),
           "goodput_retention": round(goodput_retention, 4),
           "deterministic": not args.chaos_skip_twin}
    if not args.json:
        for m in (out["fault_free"], out["chaos"]):
            print(f"{m['mode']:>10}: {m['served']} served / "
                  f"{m['rejected']} rejected / {m['shed']} shed of {n}; "
                  f"{m['tokens']} tokens in {m['elapsed_sim']:.0f} sim-s")
        inj = ", ".join(f"{k}={c}" for k, c in sorted(plan1.counts.items())
                        if c)
        print(f"injected: {inj}")
        print(f"recovery: {faults['retries']} retries, "
              f"{faults['replays']} replays, {faults['rebuilds']} rebuilds; "
              f"{len(common)} common requests bit-identical; "
              f"overhead {overhead:+.1%} sim-time, "
              f"goodput retention {goodput_retention:.1%}")
    return out, goodput_retention


def run_tp(args) -> tuple[dict, float]:
    """Mesh-sharded serving: single device vs tensor-parallel vs replicas.

    Three drives over one materialised open-loop arrival sequence (a
    shared-prefix greedy tenant plus a seeded sampled tenant):

    * ``single`` — one engine, no mesh (the PR 8 serving path);
    * ``tp`` — the same engine on a ``--tp``-device ``("model",)`` mesh:
      pool arenas and attention projections shard over the KV-head axis
      under ``shard_map``, and the decode all-gathers exactly once per
      step, before the output projection (``repro.serve.paged``);
    * ``replicas`` — a ``ServeCluster.add_replica_group`` of two
      tp-sharded members on disjoint device slices behind one group name,
      routed with prefix affinity (skipped by ``--tp-skip-replicas``).

    Every drive must produce bit-identical tokens per request — sharding
    and replication are memory/latency moves, never numerical ones. The
    report pairs aggregate tokens/s with the structural proof that the
    tp arenas *split* rather than duplicate: per-device arena bytes sum
    to the single-device footprint, ``1/tp`` of it on each device.
    """
    from repro.launch.mesh import replica_meshes, serve_tp_mesh
    from repro.serve.cluster import ServeCluster
    from repro.serve.loadgen import TenantSpec, open_loop_trace
    from repro.serve.sampling import SamplingParams
    from repro.serve.sim import Arrival, ClusterSimulator

    tp, n, rate = args.tp, args.tp_requests, args.open_loop_rate
    replicas = 0 if args.tp_skip_replicas else 2
    need = max(tp, replicas * tp)
    if len(jax.devices()) < need:
        raise SystemExit(
            f"--tp {tp} needs {need} devices, have {len(jax.devices())} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} (see `make tp-smoke`)")
    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    params = P.init_tree(registry.decls(cfg), jax.random.key(args.seed))

    tenants = [
        TenantSpec(engine="pool", share=1.0, prompt_len=(6, 18),
                   new_tokens=(4, 10), prefix_len=8, prefix_seed=7),
        TenantSpec(engine="pool", share=0.5, prompt_len=(4, 12),
                   new_tokens=(4, 10),
                   sampling=SamplingParams(temperature=0.8, top_k=40)),
    ]
    max_len, ps = 32, 8
    base = [(a.time, a.request.id, tuple(a.request.prompt),
             a.request.max_new_tokens, a.request.sampling)
            for a in open_loop_trace(tenants, n_requests=n, rate=rate,
                                     seed=args.seed,
                                     process=args.open_loop_process)]

    def arrivals(engine=None):
        # fresh Request objects per drive: engines mutate their requests
        return (Arrival(t, Request(id=rid, prompt=list(p), max_new_tokens=m,
                                   sampling=sp), engine)
                for t, rid, p, m, sp in base)

    def drive_engine(mesh, tag, devices):
        clock = FakeClock()
        eng = ContinuousBatchingEngine(cfg, params, slots=args.slots,
                                       max_len=max_len, clock=clock,
                                       prefill_chunk=args.prefill_chunk,
                                       page_size=ps, mesh=mesh,
                                       queue_capacity=args.queue_capacity)
        sim = Simulator(eng, arrivals(), clock, step_time=args.step_time,
                        dispatch_time=args.dispatch_time)
        w0 = time.perf_counter()
        report = sim.run(max_steps=5_000_000)
        wall = time.perf_counter() - w0
        by_dev = eng._pool.bytes_by_device()
        return {"mode": tag, "devices": devices,
                "tokens": report.tokens_generated,
                "served": len(report.completed),
                "elapsed_sim": report.elapsed,
                "throughput_tok_per_sim_s": round(report.throughput, 4),
                "wall_s": round(wall, 3),
                "arena_bytes_by_device": by_dev}, _tokens(eng)

    single, tok_single = drive_engine(None, "single", 1)
    sharded, tok_tp = drive_engine(serve_tp_mesh(tp), f"tp{tp}", tp)
    if tok_tp != tok_single:
        raise AssertionError(
            "tensor-parallel decode diverged from single-device — the "
            "head-sharded step must be bit-identical")
    bytes_single = sum(single["arena_bytes_by_device"].values())
    by_dev = sharded["arena_bytes_by_device"]
    if len(by_dev) != tp or len(set(by_dev.values())) != 1:
        raise AssertionError(f"tp arena not evenly sharded: {by_dev}")
    if sum(by_dev.values()) != bytes_single:
        raise AssertionError(
            f"tp arenas duplicated instead of split: {sum(by_dev.values())} "
            f"bytes across {tp} devices vs {bytes_single} on one")

    out = {"arch": cfg.name, "tp": tp, "replicas": replicas, "requests": n,
           "rate": rate, "process": args.open_loop_process,
           "slots": args.slots, "max_len": max_len, "page_size": ps,
           "prefill_chunk": args.prefill_chunk,
           "dispatch_time": args.dispatch_time, "step_time": args.step_time,
           "single": single, "tp_sharded": sharded,
           "arena_bytes_single": bytes_single,
           "arena_bytes_per_device_tp": next(iter(by_dev.values())),
           "bit_identical": True}
    speedup = 1.0
    if replicas:
        clock = FakeClock()
        np_slot = -(-max_len // ps)
        cluster = ServeCluster(
            pool_pages=replicas * args.slots * np_slot + 16, page_size=ps,
            clock=clock)
        members = cluster.add_replica_group(
            cfg, params, name="pool", slots=args.slots, max_len=max_len,
            meshes=replica_meshes(replicas, tp),
            prefill_chunk=args.prefill_chunk,
            queue_capacity=args.queue_capacity)
        sim = ClusterSimulator(cluster, arrivals("pool"), clock,
                               step_time=args.step_time,
                               dispatch_time=args.dispatch_time)
        w0 = time.perf_counter()
        rep = sim.run(max_steps=5_000_000)
        wall = time.perf_counter() - w0
        tok_rep, per_member = {}, {}
        for m in members:
            tok_rep.update(_tokens(cluster.engines[m]))
            per_member[m] = len(cluster.engines[m].completed)
        # under queue_capacity overload two replica queues reject a
        # different subset than one single-engine queue, so compare the
        # requests both drives actually served — those must match exactly
        common = set(tok_rep) & set(tok_single)
        if not common:
            raise AssertionError("replica group served nothing in common "
                                 "with the single-device drive")
        if any(tok_rep[k] != tok_single[k] for k in common):
            raise AssertionError(
                "replica-group serving diverged from single-device — "
                "routing must never change a request's tokens")
        if not all(per_member.values()):
            raise AssertionError(f"router starved a replica: {per_member}")
        speedup = rep.throughput / single["throughput_tok_per_sim_s"]
        out["replica_group"] = {
            "mode": f"{replicas}x tp{tp}", "devices": replicas * tp,
            "members": per_member,
            "tokens": rep.tokens_generated,
            "served": sum(per_member.values()),
            "elapsed_sim": rep.elapsed, "rounds": rep.steps,
            "throughput_tok_per_sim_s": round(rep.throughput, 4),
            "wall_s": round(wall, 3),
            "arena_bytes_by_device": cluster.pool.bytes_by_device(),
        }
        out["replica_speedup_vs_single"] = round(speedup, 3)
    if not args.json:
        for m in [single, sharded] + ([out["replica_group"]] if replicas
                                      else []):
            print(f"{m['mode']:>8} [{m['devices']} device(s)]: "
                  f"{m['tokens']} tokens in {m['elapsed_sim']:.0f} sim-s "
                  f"({m['throughput_tok_per_sim_s']:.3f} tok/sim-s), "
                  f"wall {m['wall_s']:.2f}s")
        print(f"tp={tp} arenas: {out['arena_bytes_per_device_tp']} bytes on "
              f"each of {tp} devices vs {bytes_single} on one "
              f"(split, not duplicated); outputs bit-identical")
        if replicas:
            print(f"replica group vs single device: {speedup:.2f}x "
                  f"aggregate tokens/s over {replicas * tp} devices")
    return out, speedup


def run_kernel_bench(cfg, args) -> tuple[dict, float]:
    """Microbenchmark the fused paged-attention kernel vs its reference.

    On CPU the Pallas kernel runs in interpret mode, so the wall numbers
    track functional cost only — the artifact records both so a TPU run
    slots into the same JSON shape.
    """
    import numpy as np

    from repro.kernels.paged_attention import ops

    rng = np.random.default_rng(args.seed)
    h, kh, d = max(cfg.n_heads, 1), max(cfg.n_kv_heads, 1), cfg.resolved_head_dim
    b, ps = args.slots, args.page_size
    np_slot = -(-args.max_len // ps)
    pool_pages = b * np_slot + 1
    q = jax.numpy.asarray(rng.normal(size=(b, h, d)), jax.numpy.float32)
    kp = jax.numpy.asarray(rng.normal(size=(pool_pages, ps, kh, d)),
                           jax.numpy.float32)
    vp = jax.numpy.asarray(rng.normal(size=(pool_pages, ps, kh, d)),
                           jax.numpy.float32)
    tables = jax.numpy.asarray(
        rng.permutation(pool_pages - 1)[:b * np_slot].reshape(b, np_slot),
        jax.numpy.int32)
    lengths = jax.numpy.asarray(
        rng.integers(1, args.max_len, size=(b,)), jax.numpy.int32)

    def timed(impl):
        fn = jax.jit(lambda q, kp, vp: ops.paged_attention(
            q, kp, vp, tables, lengths, impl=impl))
        out = fn(q, kp, vp)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(args.kernel_iters):
            out = fn(q, kp, vp)
        jax.block_until_ready(out)
        return out, (time.perf_counter() - t0) / args.kernel_iters

    o_ref, t_ref = timed("ref")
    o_pal, t_pal = timed("pallas")
    err = float(jax.numpy.abs(o_ref - o_pal).max())
    out = {"arch": cfg.name, "slots": b, "heads": h, "kv_heads": kh,
           "head_dim": d, "page_size": ps, "pool_pages": pool_pages,
           "iters": args.kernel_iters, "max_abs_err": err,
           "ref_ms": round(t_ref * 1e3, 3),
           "pallas_interpret_ms": round(t_pal * 1e3, 3)}
    if not args.json:
        print(f"paged_attention ({b} slots, {pool_pages} pages, ps={ps}): "
              f"ref {out['ref_ms']}ms, pallas(interpret) "
              f"{out['pallas_interpret_ms']}ms, max |err| {err:.2e}")
    assert err < 1e-4, f"kernel diverged from reference: {err}"
    return out, 1.0


def run_energy(cfg, args) -> tuple[dict, float]:
    """Tokens/joule across power-management policies, one identical trace.

    Four same-seed drives of the async paged engine: an unmetered control,
    a metered engine with idle-bank clock gating *off* (the host-only
    baseline — idle banks burn full ON duty-0 power), the default metered
    engine (idle banks fall to gated leakage), and a DVFS-throttled engine
    pinned at the ``nominal`` operating point (lower voltage/frequency, the
    paper's §IV-D tradeoff). Outputs are asserted bit-identical across all
    four — metering and throttling change *when* energy is charged, never
    *what* the engine computes — and each metered drive's conservation
    invariant (total == attributed + overhead == Σ per-request µJ +
    overhead) is checked before any number is reported.
    """
    params = P.init_tree(registry.decls(cfg), jax.random.key(args.seed))
    n = args.energy

    def drive(mode, **engine_kwargs):
        reqs = build_requests(n, args.prompt_len, args.new_tokens)
        clock = FakeClock()
        eng = ContinuousBatchingEngine(cfg, params, slots=args.slots,
                                       max_len=args.max_len, clock=clock,
                                       prefill_chunk=args.prefill_chunk,
                                       async_dispatch=True, **engine_kwargs)
        sim = Simulator(eng, staggered_trace(reqs, gap=args.gap), clock,
                        step_time=args.step_time,
                        dispatch_time=args.dispatch_time)
        report = sim.run()
        entry = {"mode": mode, "tokens": report.tokens_generated,
                 "completed": len(report.completed)}
        if eng._meter is not None:
            st = eng.stats()["energy"]
            attributed = sum(r.energy_uj for r in report.completed)
            if not math.isclose(st["attributed_uj"], attributed,
                                rel_tol=1e-9):
                raise AssertionError(
                    f"{mode}: attributed energy {st['attributed_uj']} != "
                    f"Σ Request.energy_uj {attributed}")
            if not math.isclose(st["total_uj"],
                                st["attributed_uj"] + st["overhead_uj"],
                                rel_tol=1e-12):
                raise AssertionError(f"{mode}: energy conservation violated")
            entry.update(
                point=st["point"],
                total_uj=round(report.energy_uj, 3),
                uj_per_token=round(report.energy_uj
                                   / report.tokens_generated, 4),
                tokens_per_joule=round(report.tokens_per_joule, 1))
        return entry, eng

    control, eng_control = drive("control", metered=False)
    host_only, eng_host = drive("host-only", gate_idle_banks=False)
    gated, eng_gated = drive("clock-gated")
    dvfs, eng_dvfs = drive("dvfs-throttled", operating_point="nominal")
    _assert_identical([("control", eng_control), ("host-only", eng_host),
                       ("clock-gated", eng_gated),
                       ("dvfs-throttled", eng_dvfs)])

    gating_gain = gated["tokens_per_joule"] / host_only["tokens_per_joule"]
    dvfs_gain = dvfs["tokens_per_joule"] / gated["tokens_per_joule"]
    out = {"arch": cfg.name, "requests": n, "slots": args.slots,
           "gap": args.gap, "prompt_len": args.prompt_len,
           "new_tokens": args.new_tokens, "max_len": args.max_len,
           "prefill_chunk": args.prefill_chunk,
           "dispatch_time": args.dispatch_time, "step_time": args.step_time,
           "control": control, "host_only": host_only,
           "clock_gated": gated, "dvfs_throttled": dvfs,
           "gating_gain_tokens_per_joule": round(gating_gain, 3),
           "dvfs_gain_tokens_per_joule": round(dvfs_gain, 3)}
    if not args.json:
        for entry in (host_only, gated, dvfs):
            print(f"{entry['mode']:>15} [{entry['point']}]: "
                  f"{entry['tokens']} tokens, "
                  f"{entry['total_uj'] / 1e6:.4f} J, "
                  f"{entry['tokens_per_joule']:.1f} tokens/J "
                  f"({entry['uj_per_token']:.2f} uJ/token)")
        print(f"clock gating vs host-only: {gating_gain:.2f}x tokens/J; "
              f"DVFS nominal vs max: {dvfs_gain:.2f}x tokens/J; "
              f"outputs bit-identical across all four drives")
    return out, gated["tokens_per_joule"]


def _merge_bench_json(key: str, payload: dict) -> None:
    data = {}
    if BENCH_JSON.exists():
        data = json.loads(BENCH_JSON.read_text())
    data[key] = payload
    BENCH_JSON.write_text(json.dumps(data, indent=1, sort_keys=True) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--gap", type=float, default=2.0)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt tokens fed per slot per step")
    ap.add_argument("--step-time", type=float, default=1.0,
                    help="sim cost of one batched device step")
    ap.add_argument("--dispatch-time", type=float, default=1.0,
                    help="sim cost of host scheduling per step (a sync "
                         "engine pays it serially; async overlaps it)")
    ap.add_argument("--shared-prefix", type=int, nargs="?", const=64,
                    default=0, metavar="LEN",
                    help="shared-prefix workload: compare the paged prefix "
                         "cache against no-sharing and the PR 2 engine")
    ap.add_argument("--tail-len", type=int, default=4,
                    help="distinct prompt tokens after the shared prefix")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per shared-prefix page")
    ap.add_argument("--sliding-window", type=int, nargs="?", const=16,
                    default=0, metavar="W",
                    help="sliding-window workload: the windowed paged "
                         "backend (ring block tables) vs the lane ring "
                         "cache")
    ap.add_argument("--open-loop", type=int, nargs="?", const=100_000,
                    default=0, metavar="N",
                    help="open-loop workload: N lazily generated arrivals "
                         "(SLO-aware scheduling vs flat WRR on goodput)")
    ap.add_argument("--open-loop-rate", type=float, default=100.0,
                    help="mean arrival rate (requests per sim-s) of the "
                         "open-loop trace")
    ap.add_argument("--open-loop-process", default="bursty",
                    choices=("poisson", "bursty", "diurnal"),
                    help="arrival process of the open-loop trace")
    ap.add_argument("--queue-capacity", type=int, default=48,
                    help="per-engine queue bound of the open-loop cluster "
                         "(beyond it, arrivals are rejected)")
    ap.add_argument("--open-loop-skip-flat", action="store_true",
                    help="skip the flat-WRR comparison run (smoke tier: "
                         "determinism pair only)")
    ap.add_argument("--sampling", type=int, nargs="?", const=2000,
                    default=0, metavar="N",
                    help="sampling workload: N open-loop arrivals with "
                         "stochastic tenants — two same-seed runs must be "
                         "bit-identical, sampled streams must diverge from "
                         "greedy, greedy neighbours must not")
    ap.add_argument("--chaos", type=int, nargs="?", const=2000,
                    default=0, metavar="N",
                    help="chaos workload: N open-loop arrivals served "
                         "fault-free, under a seeded fault plan, and under "
                         "the same plan again — bit-identity, single "
                         "accounting, and schedule determinism are "
                         "asserted before any number is reported")
    ap.add_argument("--chaos-skip-twin", action="store_true",
                    help="skip the same-seed determinism twin drive "
                         "(smoke tier: fault-free vs chaos bit-identity "
                         "only)")
    ap.add_argument("--energy", type=int, nargs="?", const=1000, default=0,
                    metavar="N",
                    help="energy workload: N staggered requests driven "
                         "through four power-management variants (unmetered "
                         "control, host-only, clock-gated, DVFS-throttled) "
                         "— bit-identity and per-request joule conservation "
                         "are asserted, tokens/joule reported per variant")
    ap.add_argument("--tp", type=int, nargs="?", const=2, default=0,
                    metavar="N",
                    help="sharded workload: single device vs N-way "
                         "head-sharded tensor parallelism vs a 2-replica "
                         "group of N-way members — bit-identity asserted, "
                         "per-device arena bytes reported (needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--tp-requests", type=int, default=600,
                    help="open-loop arrivals of the --tp workload")
    ap.add_argument("--tp-skip-replicas", action="store_true",
                    help="skip the replica-group drive (smoke tier: "
                         "single vs tp only, needs just N devices)")
    ap.add_argument("--kernel-bench", action="store_true",
                    help="microbenchmark the paged-attention kernel vs ref")
    ap.add_argument("--kernel-iters", type=int, default=20)
    ap.add_argument("--multi-model", action="store_true",
                    help="multi-model cluster workload: two models / three "
                         "engines on one shared pool vs isolated engines")
    ap.add_argument("--arch-b", default="stablelm-3b",
                    help="second model of the --multi-model cluster")
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--bench-json", action="store_true",
                    help="merge this run's report into BENCH_serve.json")
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)

    if args.kernel_bench:
        out, speedup = run_kernel_bench(cfg, args)
        tag, key = "__kernel", "kernel"
    elif args.tp:
        out, speedup = run_tp(args)
        tag, key = "__tp", "sharded"
    elif args.chaos:
        out, speedup = run_chaos(args)
        tag, key = "__chaos", "chaos"
    elif args.energy:
        out, speedup = run_energy(cfg, args)
        tag, key = "__energy", "energy"
    elif args.sampling:
        out, speedup = run_sampling(args)
        tag, key = "__sampling", "sampling"
    elif args.open_loop:
        out, speedup = run_open_loop(args)
        tag, key = "__open_loop", "open_loop"
    elif args.multi_model:
        out, speedup = run_multi_model(args)
        tag, key = "__multi_model", "multi_model"
    elif args.sliding_window:
        out, speedup = run_sliding_window(args)
        tag, key = "__sliding_window", "sliding_window"
    else:
        params = P.init_tree(registry.decls(cfg), jax.random.key(args.seed))
        if args.shared_prefix:
            out, speedup = run_shared_prefix(cfg, params, args)
            tag, key = "__shared_prefix", "shared_prefix"
        else:
            out, speedup = run_default(cfg, params, args)
            tag, key = "__trace", "default"
    if args.json:
        print(json.dumps(out, indent=1))
    if args.bench_json:
        _merge_bench_json(key, out)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{cfg.name}{tag}.json").write_text(json.dumps(out, indent=1))
    return speedup


if __name__ == "__main__":
    main()
