"""Arrivals-trace serving benchmark: continuous batching vs sequential.

Replays a deterministic trace of staggered request arrivals through the
continuous-batching engine twice — once with the engine's native slot
scheduler, once serving one request at a time — and reports tokens/s on
the simulation clock plus (optionally) wall-clock step latency.

  PYTHONPATH=src python benchmarks/serve_bench.py --arch granite-3-2b \
      --requests 16 --slots 4 --gap 2.0 --new-tokens 8
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import time

import jax

from repro import configs
from repro.models import registry
from repro.serve.engine import ContinuousBatchingEngine, Request
from repro.serve.sim import FakeClock, Simulator, staggered_trace
from repro.sharding import params as P

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "serve"


def build_requests(n: int, prompt_len: int, new_tokens: int) -> list[Request]:
    return [
        Request(id=f"req{i}",
                prompt=[(11 * i + j) % 241 + 1 for j in range(prompt_len)],
                max_new_tokens=new_tokens)
        for i in range(n)
    ]


def run_once(cfg, params, args, *, sequential: bool) -> dict:
    clock = FakeClock()
    eng = ContinuousBatchingEngine(cfg, params, slots=args.slots,
                                   max_len=args.max_len, clock=clock)
    trace = staggered_trace(
        build_requests(args.requests, args.prompt_len, args.new_tokens),
        gap=args.gap)
    sim = Simulator(eng, trace, clock, sequential=sequential)
    w0 = time.perf_counter()
    report = sim.run()
    wall = time.perf_counter() - w0
    lat = [r.finish_time - r.arrival_time for r in report.completed]
    return {
        "mode": "sequential" if sequential else "continuous",
        "elapsed_sim": report.elapsed,
        "engine_steps": report.steps,
        "tokens": report.tokens_generated,
        "throughput_tok_per_sim_s": round(report.throughput, 4),
        "mean_latency_sim": round(sum(lat) / len(lat), 3),
        # nearest-rank p99: for n <= 100 this is the max (the tail straggler
        # must be visible, not floored away)
        "p99_latency_sim": round(
            sorted(lat)[max(0, math.ceil(0.99 * len(lat)) - 1)], 3),
        "wall_s": round(wall, 3),
        "wall_tok_per_s": round(report.tokens_generated / wall, 1),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--gap", type=float, default=2.0)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    params = P.init_tree(registry.decls(cfg), jax.random.key(args.seed))

    cont = run_once(cfg, params, args, sequential=False)
    seq = run_once(cfg, params, args, sequential=True)
    speedup = cont["throughput_tok_per_sim_s"] / seq["throughput_tok_per_sim_s"]
    out = {"arch": cfg.name, "requests": args.requests, "slots": args.slots,
           "gap": args.gap, "continuous": cont, "sequential": seq,
           "sim_speedup": round(speedup, 3)}
    if args.json:
        print(json.dumps(out, indent=1))
    else:
        for mode in (cont, seq):
            print(f"{mode['mode']:>11}: {mode['tokens']} tokens in "
                  f"{mode['elapsed_sim']:.1f} sim-s "
                  f"({mode['throughput_tok_per_sim_s']:.3f} tok/sim-s), "
                  f"mean latency {mode['mean_latency_sim']:.2f} sim-s, "
                  f"wall {mode['wall_s']:.2f}s")
        print(f"continuous batching speedup: {speedup:.2f}x")
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{cfg.name}__trace.json").write_text(json.dumps(out, indent=1))
    return speedup


if __name__ == "__main__":
    main()
