"""Arrivals-trace serving benchmark: continuous batching, prefix sharing.

Replays a deterministic trace of staggered request arrivals through the
continuous-batching engine and reports tokens/s on the simulation clock
plus wall-clock step latency. Two modes:

* default — continuous batching vs one-request-at-a-time serving (the
  PR 1 headline comparison).
* ``--shared-prefix [N]`` — every request's prompt shares an N-token
  prefix (default 64); the engine with the paged prefix cache enabled is
  compared against the same engine with no sharing. Combine with
  ``--prefill-chunk`` / ``--page-size`` to explore the schedule.

  PYTHONPATH=src python benchmarks/serve_bench.py --arch granite-3-2b \
      --requests 16 --slots 4 --gap 2.0 --new-tokens 8
  PYTHONPATH=src python benchmarks/serve_bench.py --shared-prefix \
      --requests 8 --prefill-chunk 4
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import time
from typing import Any

import jax

from repro import configs
from repro.models import registry
from repro.serve.engine import ContinuousBatchingEngine, Request
from repro.serve.sim import (FakeClock, Simulator, shared_prefix_requests,
                             staggered_trace)
from repro.sharding import params as P

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "serve"


def build_requests(n: int, prompt_len: int, new_tokens: int) -> list[Request]:
    return [
        Request(id=f"req{i}",
                prompt=[(11 * i + j) % 241 + 1 for j in range(prompt_len)],
                max_new_tokens=new_tokens)
        for i in range(n)
    ]


def run_once(cfg, params, args, *, mode: str, sequential: bool = False,
             requests=None, max_len=None, **engine_kwargs) -> tuple[dict, Any]:
    clock = FakeClock()
    eng = ContinuousBatchingEngine(cfg, params, slots=args.slots,
                                   max_len=max_len or args.max_len,
                                   clock=clock,
                                   prefill_chunk=args.prefill_chunk,
                                   **engine_kwargs)
    if requests is None:
        requests = build_requests(args.requests, args.prompt_len,
                                  args.new_tokens)
    trace = staggered_trace(requests, gap=args.gap)
    sim = Simulator(eng, trace, clock, sequential=sequential)
    w0 = time.perf_counter()
    report = sim.run()
    wall = time.perf_counter() - w0
    lat = [r.finish_time - r.arrival_time for r in report.completed]
    return {
        "mode": mode,
        "elapsed_sim": report.elapsed,
        "engine_steps": report.steps,
        "tokens": report.tokens_generated,
        "throughput_tok_per_sim_s": round(report.throughput, 4),
        "mean_latency_sim": round(sum(lat) / len(lat), 3),
        # nearest-rank p99: for n <= 100 this is the max (the tail straggler
        # must be visible, not floored away)
        "p99_latency_sim": round(
            sorted(lat)[max(0, math.ceil(0.99 * len(lat)) - 1)], 3),
        "wall_s": round(wall, 3),
        "wall_tok_per_s": round(report.tokens_generated / wall, 1),
    }, eng


def _print_mode(mode: dict) -> None:
    print(f"{mode['mode']:>11}: {mode['tokens']} tokens in "
          f"{mode['elapsed_sim']:.1f} sim-s "
          f"({mode['throughput_tok_per_sim_s']:.3f} tok/sim-s), "
          f"mean latency {mode['mean_latency_sim']:.2f} sim-s, "
          f"wall {mode['wall_s']:.2f}s")


def run_default(cfg, params, args) -> tuple[dict, float]:
    cont, _ = run_once(cfg, params, args, mode="continuous")
    seq, _ = run_once(cfg, params, args, mode="sequential", sequential=True)
    speedup = cont["throughput_tok_per_sim_s"] / seq["throughput_tok_per_sim_s"]
    out = {"arch": cfg.name, "requests": args.requests, "slots": args.slots,
           "gap": args.gap, "continuous": cont, "sequential": seq,
           "sim_speedup": round(speedup, 3)}
    if not args.json:
        for mode in (cont, seq):
            _print_mode(mode)
        print(f"continuous batching speedup: {speedup:.2f}x")
    return out, speedup


def run_shared_prefix(cfg, params, args) -> tuple[dict, float]:
    """Same shared-prefix trace through the engine with and without the
    paged prefix cache; the speedup isolates what page reuse buys."""
    prefix_len = args.shared_prefix
    make = lambda: shared_prefix_requests(
        args.requests, prefix_len=prefix_len, tail_len=args.tail_len,
        new_tokens=args.new_tokens)
    need = prefix_len + args.tail_len + args.new_tokens + 1
    max_len = max(args.max_len, need)
    shared, eng = run_once(cfg, params, args, mode="sharing",
                           requests=make(), max_len=max_len,
                           page_size=args.page_size)
    plain, _ = run_once(cfg, params, args, mode="no-sharing",
                        requests=make(), max_len=max_len)
    speedup = (shared["throughput_tok_per_sim_s"]
               / plain["throughput_tok_per_sim_s"])
    pages = eng.stats()["pages"]
    out = {"arch": cfg.name, "requests": args.requests, "slots": args.slots,
           "gap": args.gap, "shared_prefix": prefix_len,
           "page_size": args.page_size, "prefill_chunk": args.prefill_chunk,
           "sharing": shared, "no_sharing": plain, "pages": pages,
           "sharing_speedup": round(speedup, 3)}
    if not args.json:
        for mode in (shared, plain):
            _print_mode(mode)
        print(f"pages: {pages['hits']} hits / {pages['misses']} misses, "
              f"{pages['tokens_reused']} prompt tokens reused, "
              f"{pages['cow_copies']} CoW copies, "
              f"{pages['resident']} resident")
        print(f"prefix sharing speedup: {speedup:.2f}x")
    return out, speedup


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--gap", type=float, default=2.0)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt tokens fed per slot per step")
    ap.add_argument("--shared-prefix", type=int, nargs="?", const=64,
                    default=0, metavar="LEN",
                    help="shared-prefix workload: compare the paged prefix "
                         "cache against the no-sharing engine")
    ap.add_argument("--tail-len", type=int, default=4,
                    help="distinct prompt tokens after the shared prefix")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per shared-prefix page")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    params = P.init_tree(registry.decls(cfg), jax.random.key(args.seed))

    if args.shared_prefix:
        out, speedup = run_shared_prefix(cfg, params, args)
        tag = "__shared_prefix"
    else:
        out, speedup = run_default(cfg, params, args)
        tag = "__trace"
    if args.json:
        print(json.dumps(out, indent=1))
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{cfg.name}{tag}.json").write_text(json.dumps(out, indent=1))
    return speedup


if __name__ == "__main__":
    main()
