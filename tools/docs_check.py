"""Docs gate: links resolve, serving API documented, bench numbers fresh.

Run as ``make docs-check`` (also a prerequisite of ``make test-fast``).
Checks, failing the build with a listing of every violation:

1. Every relative markdown link in README.md and docs/**/*.md points at a
   file or directory that exists (anchors and external URLs are skipped;
   ``path#fragment`` is checked for the ``path`` part).
2. Every public class and function defined in the ``repro.serve.*``
   modules **and** the paged-attention kernel package
   (``repro.kernels.paged_attention.*``) carries a docstring — the serving
   engine and its decode kernel are the repo's primary user-facing API and
   must stay self-describing.
3. The README benchmark table (the ``bench-table`` marker block) matches
   what ``tools/bench_table.py`` renders from the committed
   ``BENCH_serve.json`` — a fresh ``make bench-json`` without ``make
   bench-table`` fails here instead of shipping stale numbers.
4. Every exact benchmark figure quoted in README/docs prose matches the
   committed ``BENCH_serve.json``:

   * two-decimal speedups (``1.84×`` / ``2.82x``) must equal some numeric
     leaf of the JSON rounded the same way — approximations written with
     one decimal (``~1.8×``) are deliberately exempt;
   * ``A vs B`` integer pairs on lines mentioning pages or arenas (the
     device-page savings and sharded-arena-split quotes) must both be
     integer leaves of the JSON;
   * attainment percentages (``68.2%``) on lines mentioning attainment
     must equal a fractional leaf of the JSON scaled to percent, and
     decimal figures on lines mentioning TTFT, goodput, or joules
     (``98.0``, ``2.62``) must equal a leaf rounded to the quoted
     precision — the open-loop SLO and tokens/joule numbers stay as
     fresh as the speedups.

   The numeric sweep walks every leaf of the JSON generically, so new
   bench sections (e.g. the ``sampling`` determinism report) are covered
   the moment ``make bench-json`` commits them — no per-key plumbing.
"""

from __future__ import annotations

import importlib
import inspect
import json
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))
sys.path.insert(0, str(REPO / "tools"))

# [text](target) — excluding images handled identically, so one pattern
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")

DOC_MODULES = (
    "repro.serve.chaos", "repro.serve.cluster", "repro.serve.energy_meter",
    "repro.serve.engine",
    "repro.serve.loadgen", "repro.serve.metrics", "repro.serve.paged",
    "repro.serve.pages", "repro.serve.sampling", "repro.serve.sim",
    "repro.kernels.paged_attention.kernel",
    "repro.kernels.paged_attention.ops",
    "repro.kernels.paged_attention.ref",
)

BENCH_JSON = REPO / "BENCH_serve.json"
# exact two-decimal speedup quote: "1.84×" / "2.82x" (one-decimal
# approximations like "~1.8×" are prose, not artifact numbers)
_SPEEDUP = re.compile(r"(?<![\d.])(\d+\.\d{2})[×x]")
_VS_PAIR = re.compile(r"\b(\d+) vs (\d+)\b")
# "68.2%" on attainment lines; "98.0" / "2.62" on TTFT/goodput lines —
# quoted at whatever precision, checked against the JSON leaf rounded the
# same way (decimal quotes only: bare integers are prose, not artifacts)
_PCT = re.compile(r"(?<![\d.])(\d+\.\d+)%")
_DEC = re.compile(r"(?<![\d.])(\d+\.\d+)(?![\d.×x%])")


def _doc_files() -> list[pathlib.Path]:
    files = [REPO / "README.md"]
    docs = REPO / "docs"
    if docs.is_dir():
        files.extend(sorted(docs.rglob("*.md")))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    errors = []
    for md in _doc_files():
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in _LINK.findall(line):
                if target.startswith(_EXTERNAL) or target.startswith("#"):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    rel = md.relative_to(REPO)
                    errors.append(f"{rel}:{lineno}: broken link -> {target}")
    return errors


def check_docstrings() -> list[str]:
    errors = []
    for modname in DOC_MODULES:
        mod = importlib.import_module(modname)
        if not (mod.__doc__ or "").strip():
            errors.append(f"{modname}: missing module docstring")
        for name, obj in vars(mod).items():
            if name.startswith("_"):
                continue
            if not (inspect.isclass(obj) or inspect.isfunction(obj)):
                continue
            if getattr(obj, "__module__", None) != modname:
                continue        # re-export; documented where it is defined
            if not (obj.__doc__ or "").strip():
                errors.append(f"{modname}.{name}: missing docstring")
            if inspect.isclass(obj):
                for mname, meth in vars(obj).items():
                    if mname.startswith("_") or not callable(meth):
                        continue
                    if not (getattr(meth, "__doc__", None) or "").strip():
                        errors.append(
                            f"{modname}.{name}.{mname}: missing docstring")
    return errors


def _numeric_leaves(node, out: set) -> set:
    if isinstance(node, bool):
        return out
    if isinstance(node, (int, float)):
        out.add(float(node))
    elif isinstance(node, dict):
        for v in node.values():
            _numeric_leaves(v, out)
    elif isinstance(node, list):
        for v in node:
            _numeric_leaves(v, out)
    return out


def check_bench_numbers() -> list[str]:
    """Exact figures quoted in prose must match BENCH_serve.json, and the
    README's generated table must match what the JSON renders to."""
    errors = []
    if not BENCH_JSON.exists():
        return [f"{BENCH_JSON.name}: missing (run `make bench-json`)"]
    data = json.loads(BENCH_JSON.read_text())
    leaves = _numeric_leaves(data, set())
    rounded = {round(v, 2) for v in leaves}
    ints = {int(v) for v in leaves if float(v).is_integer()}
    for md in _doc_files():
        rel = md.relative_to(REPO)
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for quote in _SPEEDUP.findall(line):
                if float(quote) not in rounded:
                    errors.append(
                        f"{rel}:{lineno}: quoted speedup {quote}× not in "
                        f"BENCH_serve.json (stale number? run `make "
                        f"bench-json` + `make bench-table`)")
            low = line.lower()
            if "page" in low or "arena" in low:
                for a, b in _VS_PAIR.findall(line):
                    for n in (int(a), int(b)):
                        if n not in ints:
                            errors.append(
                                f"{rel}:{lineno}: page/arena count {n} (in "
                                f"'{a} vs {b}') not in BENCH_serve.json")
            if "attainment" in low:
                for q in _PCT.findall(line):
                    nd = len(q.split(".")[1])
                    if float(q) not in {round(v * 100, nd) for v in leaves
                                        if 0 <= v <= 1}:
                        errors.append(
                            f"{rel}:{lineno}: attainment {q}% not in "
                            f"BENCH_serve.json (stale number? run `make "
                            f"bench-json`)")
            if "ttft" in low or "goodput" in low or "joule" in low:
                for q in _DEC.findall(line):
                    nd = len(q.split(".")[1])
                    if float(q) not in {round(v, nd) for v in leaves}:
                        errors.append(
                            f"{rel}:{lineno}: TTFT/goodput/joule figure "
                            f"{q} not in BENCH_serve.json (stale number? "
                            f"run `make bench-json`)")

    import bench_table

    readme = (REPO / "README.md").read_text()
    have = bench_table.current_block(readme)
    try:
        want = bench_table.rendered_block(data)
    except KeyError as e:
        # a partial bench-json run (one mode, or interrupted) leaves the
        # file missing whole sections — report it, don't traceback
        return errors + [f"{BENCH_JSON.name}: missing section {e} "
                         f"(run the full `make bench-json`)"]
    if have is None:
        errors.append("README.md: bench-table marker block missing")
    elif have != want:
        errors.append("README.md: benchmark table stale vs BENCH_serve.json "
                      "— run `make bench-table`")
    return errors


def main() -> int:
    errors = check_links() + check_docstrings() + check_bench_numbers()
    if errors:
        print(f"docs-check: {len(errors)} problem(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    n_files = len(_doc_files())
    print(f"docs-check: OK ({n_files} doc file(s), "
          f"{len(DOC_MODULES)} documented modules, bench numbers fresh)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
