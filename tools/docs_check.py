"""Docs gate: intra-repo links must resolve, public serve API documented.

Run as ``make docs-check`` (also a prerequisite of ``make test-fast``).
Checks, failing the build with a listing of every violation:

1. Every relative markdown link in README.md and docs/**/*.md points at a
   file or directory that exists (anchors and external URLs are skipped;
   ``path#fragment`` is checked for the ``path`` part).
2. Every public class and function defined in the ``repro.serve.*``
   modules carries a docstring — the serving engine is the repo's primary
   user-facing API and must stay self-describing.
"""

from __future__ import annotations

import importlib
import inspect
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

# [text](target) — excluding images handled identically, so one pattern
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")

SERVE_MODULES = ("repro.serve.cluster", "repro.serve.engine",
                 "repro.serve.paged", "repro.serve.pages", "repro.serve.sim")


def _doc_files() -> list[pathlib.Path]:
    files = [REPO / "README.md"]
    docs = REPO / "docs"
    if docs.is_dir():
        files.extend(sorted(docs.rglob("*.md")))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    errors = []
    for md in _doc_files():
        for lineno, line in enumerate(md.read_text().splitlines(), 1):
            for target in _LINK.findall(line):
                if target.startswith(_EXTERNAL) or target.startswith("#"):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    rel = md.relative_to(REPO)
                    errors.append(f"{rel}:{lineno}: broken link -> {target}")
    return errors


def check_serve_docstrings() -> list[str]:
    errors = []
    for modname in SERVE_MODULES:
        mod = importlib.import_module(modname)
        if not (mod.__doc__ or "").strip():
            errors.append(f"{modname}: missing module docstring")
        for name, obj in vars(mod).items():
            if name.startswith("_"):
                continue
            if not (inspect.isclass(obj) or inspect.isfunction(obj)):
                continue
            if getattr(obj, "__module__", None) != modname:
                continue        # re-export; documented where it is defined
            if not (obj.__doc__ or "").strip():
                errors.append(f"{modname}.{name}: missing docstring")
            if inspect.isclass(obj):
                for mname, meth in vars(obj).items():
                    if mname.startswith("_") or not callable(meth):
                        continue
                    if not (getattr(meth, "__doc__", None) or "").strip():
                        errors.append(
                            f"{modname}.{name}.{mname}: missing docstring")
    return errors


def main() -> int:
    errors = check_links() + check_serve_docstrings()
    if errors:
        print(f"docs-check: {len(errors)} problem(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    n_files = len(_doc_files())
    print(f"docs-check: OK ({n_files} doc file(s), "
          f"{len(SERVE_MODULES)} serve modules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
