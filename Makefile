# Developer entry points. All targets run on CPU with the in-repo sources.
#
#   make test-fast    fast tier (tier-1 gate candidates, < 1 min): -m "not slow"
#   make test-all     full suite including subprocess multi-device + sweeps
#   make bench-serve  arrivals-trace serving benchmark (continuous vs sequential)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test-fast test-all bench-serve

test-fast:
	$(PY) -m pytest -q -m "not slow"

test-all:
	$(PY) -m pytest -x -q

bench-serve:
	$(PY) benchmarks/serve_bench.py --requests 16 --slots 4 --gap 2.0 --new-tokens 8
