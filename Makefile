# Developer entry points. All targets run on CPU with the in-repo sources.
#
#   make test-fast    fast tier (tier-1 gate candidates, < 1 min): -m "not slow"
#                     (runs docs-check first)
#   make test-all     full suite including subprocess multi-device + sweeps
#   make bench-serve  arrivals-trace serving benchmark (continuous vs sequential)
#   make sim-smoke    fast open-loop smoke: seeded 1k-request trace, < 10 s
#   make chaos-smoke  fast fault-injection smoke: seeded 1k-request trace
#                     under a nonzero fault rate, bit-identity asserted, < 10 s
#   make tp-smoke     fast sharding smoke: seeded 1k-request trace on 2 forced
#                     host devices, tp=2 asserted bit-identical to 1 device, < 15 s
#   make energy-smoke fast metering smoke: one seeded trace through four
#                     power-policy variants, conservation + bit-identity
#                     asserted, < 10 s
#   make docs-check   intra-repo links in README/docs + serve/* docstrings
#
# bench-serve forwards extra flags given after `--` (and anything in
# BENCH_ARGS, for flags that take values):
#
#   make bench-serve -- --shared-prefix
#   make bench-serve -- --shared-prefix BENCH_ARGS="--prefill-chunk 4"

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

BENCH_PASSTHRU = $(filter-out bench-serve,$(MAKECMDGOALS))

.PHONY: test-fast test-all bench-serve bench-json bench-table docs-check \
	sim-smoke chaos-smoke tp-smoke energy-smoke

# Fast tier compiles at XLA opt level 0: the suite is compile-bound (tiny
# smoke models, hundreds of small programs) and every correctness assertion
# is backend-consistent (bit-identity is always engine-vs-engine within one
# process; kernel parity uses tolerances). The full tier-1 gate (test-all)
# keeps full optimization fidelity.
# -p no:cacheprovider: no .pytest_cache — stale last-failed state on CI
# runners is a flakiness source, and the suite never uses the cache
test-fast: docs-check
	XLA_FLAGS="--xla_backend_optimization_level=0 $$XLA_FLAGS" \
		$(PY) -m pytest -q -p no:cacheprovider -m "not slow"

test-all:
	$(PY) -m pytest -x -q -p no:cacheprovider

bench-serve:
	$(PY) benchmarks/serve_bench.py --requests 16 --slots 4 --gap 2.0 \
		--new-tokens 8 $(BENCH_PASSTHRU) $(BENCH_ARGS)

# BENCH_serve.json artifact: default trace + shared-prefix trace +
# multi-model cluster trace + sliding-window trace + paged kernel
# microbench, merged into one JSON tracked across PRs (every trace asserts
# bit-identical outputs before its numbers are reported). `make
# bench-table` then rewrites the README table from it.
bench-json:
	$(PY) benchmarks/serve_bench.py --requests 16 --slots 4 --gap 2.0 \
		--new-tokens 8 --json --bench-json
	$(PY) benchmarks/serve_bench.py --requests 16 --slots 4 --gap 2.0 \
		--new-tokens 8 --shared-prefix --json --bench-json
	$(PY) benchmarks/serve_bench.py --requests 16 --slots 4 --gap 2.0 \
		--new-tokens 8 --multi-model --json --bench-json
	$(PY) benchmarks/serve_bench.py --requests 16 --slots 4 --gap 2.0 \
		--new-tokens 16 --sliding-window --json --bench-json
	$(PY) benchmarks/serve_bench.py --slots 4 --kernel-bench --json --bench-json
	$(PY) benchmarks/serve_bench.py --slots 4 --prefill-chunk 4 \
		--open-loop --json --bench-json
	$(PY) benchmarks/serve_bench.py --slots 4 --prefill-chunk 4 \
		--open-loop-rate 40 --sampling --json --bench-json
	$(PY) benchmarks/serve_bench.py --slots 4 --prefill-chunk 4 \
		--open-loop-rate 40 --chaos --json --bench-json
	XLA_FLAGS="--xla_force_host_platform_device_count=4 $$XLA_FLAGS" \
		$(PY) benchmarks/serve_bench.py --slots 4 --prefill-chunk 4 \
		--tp 2 --tp-requests 600 --json --bench-json
	$(PY) benchmarks/serve_bench.py --slots 4 --prefill-chunk 4 \
		--energy --json --bench-json

# fast-tier open-loop smoke: a seeded 1k-request trace through the full
# SLO-aware pipeline (loadgen -> cluster -> metrics), < 10 s on CPU
sim-smoke:
	XLA_FLAGS="--xla_backend_optimization_level=0 $$XLA_FLAGS" \
		$(PY) benchmarks/serve_bench.py --slots 4 --prefill-chunk 4 \
		--open-loop 1000 --open-loop-skip-flat --json > /dev/null
	@echo "sim-smoke: 1k-request open-loop trace OK"

# fast-tier chaos smoke: the same seeded 1k-request trace served under a
# nonzero fault rate (all 7 kinds) — completed outputs are asserted
# bit-identical to the fault-free run, nothing lost or double-completed
chaos-smoke:
	XLA_FLAGS="--xla_backend_optimization_level=0 $$XLA_FLAGS" \
		$(PY) benchmarks/serve_bench.py --slots 4 --prefill-chunk 4 \
		--chaos 1000 --chaos-skip-twin --json > /dev/null
	@echo "chaos-smoke: 1k-request faulted trace bit-identical OK"

# fast-tier sharding smoke: the same seeded 1k-request open-loop trace
# decoded once on 1 device and once head-sharded over tp=2 forced host
# devices — tokens asserted bit-identical, arenas asserted split (per-device
# bytes sum to the single-device footprint). Replica drive is skipped: it
# needs 4 devices and belongs to `make bench-json`.
tp-smoke:
	XLA_FLAGS="--xla_backend_optimization_level=0 \
		--xla_force_host_platform_device_count=2 $$XLA_FLAGS" \
		$(PY) benchmarks/serve_bench.py --slots 4 --prefill-chunk 4 \
		--tp 2 --tp-requests 1000 --tp-skip-replicas --json > /dev/null
	@echo "tp-smoke: 1k-request tp=2 trace bit-identical, arenas split OK"

# fast-tier energy smoke: one seeded staggered trace through the four
# power-policy drives (unmetered control, host-only, clock-gated,
# DVFS-throttled) — per-request joule conservation and token bit-identity
# are asserted inside the benchmark before it prints anything
energy-smoke:
	XLA_FLAGS="--xla_backend_optimization_level=0 $$XLA_FLAGS" \
		$(PY) benchmarks/serve_bench.py --slots 4 --prefill-chunk 4 \
		--energy 100 --json > /dev/null
	@echo "energy-smoke: metered trace conserved + bit-identical OK"

# regenerate the README benchmark table from the committed BENCH_serve.json
# (docs-check fails when the two drift, so PRs stop hand-editing numbers)
bench-table:
	$(PY) tools/bench_table.py --write

docs-check:
	$(PY) tools/docs_check.py

# swallow pass-through flags handed over as extra goals (see bench-serve)
--%:
	@:
