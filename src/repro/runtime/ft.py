"""Fault-tolerance runtime: heartbeats, stragglers, elastic rescale, restart.

This is the pod-scale rendition of the paper's power manager + interrupt
fabric: workers (≙ power domains) report liveness (≙ XAIF interrupts); dead
domains are switched off (elastic downscale) and the platform keeps running.

The controller is deliberately transport-agnostic (tick-driven state machine
fed by ``report_heartbeat``/``report_step_time``) so it can be driven by a
real coordinator service on a cluster or by a simulator in tests. Recovery
composes with :mod:`repro.ckpt.checkpoint` (elastic restore) and the
step-indexed data pipeline (bit-identical replay).

The second half of the module is the serving engine's durability layer,
:class:`RequestJournal`, whose invariants are:

* **Replay determinism** — decode is deterministic even when stochastic:
  greedy replay is argmax, and sampled requests journal their
  ``SamplingParams`` tuple (temperature/top-k/top-p/seed) at first open so
  a replay re-seeds the identical per-request PRNG chain. Either way a
  replay from the journaled prompt reproduces the original tokens
  bit-for-bit; ``record_token`` cross-checks every replayed token against
  the pre-preemption run and raises on divergence rather than serving
  silently different output.
* **FIFO order survives preemption** — ``arrival_seq`` is assigned once at
  first admission and never reassigned, so ``incomplete()`` always returns
  the original admission order.
* **Page-table state is journaled** — ``note_prefix`` records each
  admission's shared-prefix reuse (token count + pinned page keys); reuse
  is an optimisation only and must never change the emitted tokens.
* **In-flight records are never evicted** — ``evict`` refuses to drop a
  record whose request has not completed (that would lose replay state).

A multi-model cluster keeps one :class:`ClusterJournal`: a per-engine
:class:`RequestJournal` under each engine name, so every engine's replay
determinism is checked independently (sequence numbers and divergence
cross-checks never mix across models) while the cluster still has a
single durable root to enumerate in-flight work from.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
import math
import statistics
import time
from typing import Callable


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    STRAGGLING = "straggling"
    DEAD = "dead"


@dataclasses.dataclass
class WorkerInfo:
    last_heartbeat: float = 0.0
    state: WorkerState = WorkerState.HEALTHY
    step_times: list = dataclasses.field(default_factory=list)
    slow_streak: int = 0


@dataclasses.dataclass(frozen=True)
class FTConfig:
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 1.5      # slower than median x this => slow
    straggler_streak: int = 3          # consecutive slow steps => flagged
    max_restarts: int = 5
    backoff_base_s: float = 2.0
    window: int = 20                   # step-time history window


class FTController:
    def __init__(self, n_workers: int, cfg: FTConfig = FTConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.workers = {i: WorkerInfo(last_heartbeat=clock())
                        for i in range(n_workers)}
        self.restarts = 0
        self.events: list[tuple[float, str]] = []

    # -- membership ------------------------------------------------------
    def add_worker(self) -> int:
        """Register one more worker and return its id — dynamic
        registration for controllers built before their workers exist
        (e.g. a serving cluster adding engines after construction)."""
        wid = max(self.workers, default=-1) + 1
        self.workers[wid] = WorkerInfo(last_heartbeat=self.clock())
        return wid

    # -- reporting -------------------------------------------------------
    def report_heartbeat(self, worker: int):
        w = self.workers[worker]
        w.last_heartbeat = self.clock()
        if w.state is WorkerState.DEAD:
            w.state = WorkerState.HEALTHY   # rejoin (elastic upscale)
            self._log(f"worker {worker} rejoined")

    def report_step_time(self, worker: int, seconds: float):
        w = self.workers[worker]
        w.step_times.append(seconds)
        if len(w.step_times) > self.cfg.window:
            w.step_times.pop(0)

    def report_failure(self, worker: int, reason: str = "fault"):
        """Coordinator-observed failure: declare ``worker`` dead now,
        without waiting out the heartbeat timeout (e.g. an engine crash
        the serving cluster detected synchronously). A later heartbeat
        rejoins it, exactly like a timeout death."""
        w = self.workers[worker]
        if w.state is not WorkerState.DEAD:
            w.state = WorkerState.DEAD
            w.slow_streak = 0
            self._log(f"worker {worker} declared dead ({reason})")

    # -- detection --------------------------------------------------------
    def tick(self) -> dict:
        """Run detection; returns {'dead': [...], 'stragglers': [...]}"""
        now = self.clock()
        dead, stragglers = [], []
        alive_times = [t for w in self.workers.values()
                       if w.state is not WorkerState.DEAD
                       for t in w.step_times[-1:]]
        median = statistics.median(alive_times) if alive_times else None
        for wid, w in self.workers.items():
            if w.state is WorkerState.DEAD:
                continue
            if now - w.last_heartbeat > self.cfg.heartbeat_timeout_s:
                w.state = WorkerState.DEAD
                dead.append(wid)
                self._log(f"worker {wid} declared dead (heartbeat timeout)")
                continue
            if median and w.step_times:
                if w.step_times[-1] > self.cfg.straggler_factor * median:
                    w.slow_streak += 1
                else:
                    w.slow_streak = 0
                if w.slow_streak >= self.cfg.straggler_streak:
                    if w.state is not WorkerState.STRAGGLING:
                        self._log(f"worker {wid} flagged as straggler")
                    w.state = WorkerState.STRAGGLING
                    stragglers.append(wid)
                elif w.state is WorkerState.STRAGGLING:
                    w.state = WorkerState.HEALTHY
        return {"dead": dead, "stragglers": stragglers}

    # -- mitigation --------------------------------------------------------
    def healthy_workers(self) -> list[int]:
        return [i for i, w in self.workers.items()
                if w.state is not WorkerState.DEAD]

    def rescale_plan(self, mesh_shape: tuple[int, ...],
                     axis: int = 0) -> tuple[int, ...] | None:
        """Largest valid mesh after losing workers: shrink ``axis`` to the
        biggest power-of-two of healthy workers (keeps divisibility for
        checkpoint resharding). None if unchanged."""
        alive = len(self.healthy_workers())
        total = math.prod(mesh_shape)
        if alive >= total:
            return None
        per_other = total // mesh_shape[axis]
        new_axis = 1
        while new_axis * 2 * per_other <= alive:
            new_axis *= 2
        new = list(mesh_shape)
        new[axis] = new_axis
        return tuple(new)

    def microbatch_shares(self, n_microbatches: int) -> dict[int, int]:
        """Straggler mitigation: stragglers get half-weight shares of the
        next step's microbatches (work rerouted to healthy peers)."""
        weights = {}
        for wid, w in self.workers.items():
            if w.state is WorkerState.DEAD:
                continue
            weights[wid] = 0.5 if w.state is WorkerState.STRAGGLING else 1.0
        total_w = sum(weights.values())
        shares = {wid: int(n_microbatches * wt / total_w)
                  for wid, wt in weights.items()}
        # distribute remainder to healthiest workers
        rem = n_microbatches - sum(shares.values())
        for wid in sorted(weights, key=lambda i: -weights[i]):
            if rem <= 0:
                break
            shares[wid] += 1
            rem -= 1
        return shares

    def restart_delay(self) -> float | None:
        """Exponential-backoff restart policy; None when budget exhausted."""
        if self.restarts >= self.cfg.max_restarts:
            return None
        delay = self.cfg.backoff_base_s * (2 ** self.restarts)
        self.restarts += 1
        return delay

    def _log(self, msg: str):
        self.events.append((self.clock(), msg))


# ---------------------------------------------------------------------------
# Preemption-safe slot state for the serving engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SlotRecord:
    """Durable record of one in-flight request (what replay needs).

    ``prefix_reused``/``page_keys`` journal the page-table decision taken
    at (re-)admission: how many prompt tokens were admitted pre-consumed
    from shared prefix pages, and which pages were pinned. Reuse never
    changes the emitted tokens (greedy decode from a correct prefix state
    is bit-identical to re-running the prefill), so replay stays
    bit-identical whether or not the replayed admission finds the same
    pages resident — the fields make every run auditable, and
    ``record_token`` enforces the invariant.
    """

    request_id: str
    prompt: tuple                  # token ids, immutable for safety
    max_new_tokens: int
    arrival_seq: int               # FIFO position — preserved across preemption
    generated: list = dataclasses.field(default_factory=list)
    prior: list = dataclasses.field(default_factory=list)  # pre-preemption run
    completed: bool = False
    prefix_reused: int = 0         # prompt tokens pre-consumed at admission
    page_keys: tuple = ()          # page-table chain pinned at admission
    rematched: int = 0             # prompt tokens adopted mid-flight (re-match)
    recycled: int = 0              # ring pages recycled out of the window
    slo_preempts: int = 0          # scheduler preempt-and-requeue demotions
    # stochastic decode: (temperature, top_k, top_p, seed) or None for
    # greedy. Set at first open and immutable for the record's lifetime —
    # replay re-seeds the request's PRNG chain from this, so changing it
    # mid-flight would silently break the divergence cross-check
    sampling: tuple | None = None


class RequestJournal:
    """Write-ahead record of every admitted request.

    The continuous-batching engine journals each request when it is admitted
    to a slot and each token as it is emitted. If the engine is preempted
    (worker loss, elastic rescale — the FTController events above), the
    journal is the source of truth: ``incomplete()`` returns the in-flight
    requests in their original FIFO order so the engine can re-queue and
    replay them. Greedy decoding is deterministic, so a replay from the
    prompt reproduces the original tokens bit-for-bit; ``record_token``
    cross-checks this whenever a replayed slot overlaps its pre-preemption
    progress.

    ``horizon`` bounds memory in long open-loop runs: when more than
    ``horizon`` *completed* records are retained, the oldest-completed
    ones are auto-evicted (in-flight records are never evicted — the
    replay-state invariant holds unconditionally). ``None`` retains
    everything, the pre-horizon behaviour.
    """

    def __init__(self, horizon: int | None = None):
        if horizon is not None and horizon < 0:
            raise ValueError("horizon must be >= 0 (None = unbounded)")
        self.horizon = horizon
        self._records: dict[str, SlotRecord] = {}
        self._seq = 0
        # completion order, for horizon eviction (may hold ids already
        # dropped by an explicit evict(); the auto-evict loop skips those)
        self._done_order: collections.deque[str] = collections.deque()
        self.auto_evicted = 0

    def open(self, request_id: str, prompt, max_new_tokens: int,
             sampling: tuple | None = None) -> SlotRecord:
        """Open (or re-open, on replay) the record for one admission.

        ``sampling`` is the request's ``(temperature, top_k, top_p,
        seed)`` tuple (None for greedy), journaled at first open; a
        re-open with *different* sampling params raises — the replayed
        PRNG chain would not reproduce the prior run's tokens, so the
        conflict must fail at admission, not as a later divergence.
        """
        if request_id in self._records:
            rec = self._records[request_id]
            if rec.completed:
                raise ValueError(f"request {request_id!r} already completed")
            if rec.sampling != sampling:
                raise ValueError(
                    f"request {request_id!r} re-opened with sampling params "
                    f"{sampling!r} != journaled {rec.sampling!r}: replay "
                    "must re-seed the original chain")
            # replay restarts emission from scratch; keep the longest run
            # observed so far so record_token can cross-check determinism
            # even after a preemption that interrupts an earlier replay
            if len(rec.generated) > len(rec.prior):
                rec.prior = list(rec.generated)
            rec.generated = []
            return rec
        rec = SlotRecord(request_id, tuple(int(t) for t in prompt),
                         max_new_tokens, self._seq, sampling=sampling)
        self._seq += 1
        self._records[request_id] = rec
        return rec

    def note_prefix(self, request_id: str, tokens_reused: int,
                    page_keys) -> None:
        """Journal the page-table state of an admission: how much of the
        prompt came pre-consumed from shared pages. Recorded per admission
        (a replay may find more, fewer, or no pages resident — the tokens
        must come out identical either way)."""
        rec = self._records[request_id]
        rec.prefix_reused = int(tokens_reused)
        rec.page_keys = tuple(tuple(k) for k in page_keys)
        rec.rematched = 0              # fresh admission restarts the count
        rec.recycled = 0

    def note_rematch(self, request_id: str, tokens_adopted: int) -> None:
        """Journal a mid-flight prefix re-match: at a page boundary during
        chunked prefill the slot adopted a sibling's freshly published pages
        instead of recomputing them. Like ``note_prefix``, this is an audit
        field — adoption is an optimisation only and must never change the
        emitted tokens (``record_token`` enforces that on replay)."""
        self._records[request_id].rematched += int(tokens_adopted)

    def note_recycle(self, request_id: str, n_pages: int) -> None:
        """Journal a sliding-window ring recycle: ``n_pages`` of the slot's
        block table were released (or disowned, for adopted shared pages)
        because their positions fell wholly outside the window. Like the
        other page-table fields this is an audit record — recycling frees
        memory the attention window can no longer see, so it must never
        change the emitted tokens, and replay after ``preempt()`` stays
        bit-identical whatever recycling the replayed run performs
        (``record_token`` enforces that)."""
        self._records[request_id].recycled += int(n_pages)

    def note_slo_preempt(self, request_id: str) -> None:
        """Journal a scheduler-driven preempt-and-requeue (an SLO-busting
        request demoted to the back of its engine's queue). A lifetime
        count — unlike the per-admission page fields it survives
        re-admission, so replay audits how often the scheduler bounced a
        request. The demotion changes *when* the tokens re-emerge, never
        what they are: replay after an SLO preemption runs through the
        same ``open`` → ``record_token`` path as a full ``preempt()``,
        and the divergence cross-check holds as usual."""
        self._records[request_id].slo_preempts += 1

    def record_token(self, request_id: str, token: int) -> None:
        rec = self._records[request_id]
        idx, token = len(rec.generated), int(token)
        if idx < len(rec.prior) and rec.prior[idx] != token:
            raise RuntimeError(
                f"replay divergence for request {request_id!r} at token "
                f"{idx}: original {rec.prior[idx]}, replay {token} — decode "
                f"is expected to be deterministic")
        rec.generated.append(token)

    def complete(self, request_id: str) -> None:
        rec = self._records[request_id]
        if not rec.completed:
            rec.completed = True
            self._done_order.append(request_id)
            if self.horizon is not None:
                self._trim()

    def _trim(self) -> None:
        live = sum(1 for rid in self._done_order
                   if self._records.get(rid) is not None
                   and self._records[rid].completed)
        while live > self.horizon and self._done_order:
            rid = self._done_order.popleft()
            rec = self._records.get(rid)
            if rec is None or not rec.completed:
                continue               # explicitly evicted, or re-opened
            del self._records[rid]
            self.auto_evicted += 1
            live -= 1

    def get(self, request_id: str) -> SlotRecord:
        return self._records[request_id]

    def has(self, request_id: str) -> bool:
        """True when a record exists for ``request_id`` — i.e. the request
        has been admitted at least once. Schedulers use this to exempt
        replayed work (preempted, crash-recovered, or corruption-
        quarantined) from admission-control shedding: a request holding
        journal state must finish, or its record would sit in-flight
        forever and resurrect at the next crash rebuild."""
        return request_id in self._records

    def evict(self, request_id: str) -> None:
        """Drop a completed record (post-acknowledgement cleanup). Evicting
        an in-flight record would lose replay state, so that is an error;
        an id the horizon already auto-evicted is silently gone."""
        rec = self._records.get(request_id)
        if rec is None:
            return                     # horizon got there first
        if not rec.completed:
            raise ValueError(f"request {request_id!r} is still in flight")
        del self._records[request_id]

    # -- live migration (replica drain) -----------------------------------

    def transfer(self, request_id: str) -> SlotRecord:
        """Remove and return an *in-flight* record so a sibling journal can
        :meth:`adopt` it — the handoff half of live slot migration (a
        replica draining its work onto its peers). Transferring a
        completed record is an error (finished work is acknowledged where
        it ran, never migrated)."""
        rec = self._records[request_id]
        if rec.completed:
            raise ValueError(
                f"request {request_id!r} already completed — completed "
                "work is acknowledged in place, not migrated")
        del self._records[request_id]
        return rec

    def adopt(self, rec: SlotRecord) -> SlotRecord:
        """Adopt a record transferred from a sibling journal.

        The source engine's emitted tokens ride along as the ``prior``
        run, so when the adopting engine replays the request its
        ``record_token`` cross-checks every token against the source's
        output — migration is held to the same bit-identity bar as
        preemption replay. ``arrival_seq`` is reassigned in adoption
        order (the one exception to never-reassigned: the sequence is
        journal-local, and the drain hands records over in the source's
        FIFO order, so relative order is preserved on the sibling)."""
        if rec.request_id in self._records:
            raise ValueError(
                f"request {rec.request_id!r} already journaled here — two "
                "engines cannot both own an in-flight record")
        if rec.completed:
            raise ValueError(f"request {rec.request_id!r} is completed")
        if len(rec.generated) > len(rec.prior):
            rec.prior = list(rec.generated)
        rec.generated = []
        rec.arrival_seq = self._seq
        self._seq += 1
        self._records[rec.request_id] = rec
        return rec

    def size(self) -> dict:
        """Retention counters for ``engine.stats()``: live record and
        token counts, an order-of-magnitude byte estimate, and how many
        completed records the horizon auto-evicted."""
        tokens = sum(len(r.prompt) + len(r.generated) + len(r.prior)
                     for r in self._records.values())
        return {
            "records": len(self._records),
            "in_flight": sum(1 for r in self._records.values()
                             if not r.completed),
            "tokens": tokens,
            # ints in CPython are ~28 bytes; the record object + dict
            # slot overhead lands around 400 — a sizing signal, not an
            # exact accounting
            "approx_bytes": 400 * len(self._records) + 28 * tokens,
            "auto_evicted": self.auto_evicted,
            "horizon": self.horizon,
        }

    def incomplete(self) -> list[SlotRecord]:
        """In-flight records, oldest first — the replay queue."""
        return sorted((r for r in self._records.values() if not r.completed),
                      key=lambda r: r.arrival_seq)

    def completed(self) -> list[SlotRecord]:
        return sorted((r for r in self._records.values() if r.completed),
                      key=lambda r: r.arrival_seq)


class ClusterJournal:
    """One durable root over per-engine :class:`RequestJournal` instances.

    Each engine of a :class:`~repro.serve.cluster.ServeCluster` journals
    into its own ``RequestJournal`` (obtained via :meth:`journal`), keeping
    FIFO sequence numbers and replay cross-checks engine-local — a replay
    of model A must never be validated against model B's tokens. The
    cluster-level views (:meth:`incomplete` / :meth:`completed`) aggregate
    per engine name, which is what a coordinator restarts from after a
    cluster-wide preemption. ``horizon`` is handed to every per-engine
    journal (completed-record retention bound, see
    :class:`RequestJournal`).
    """

    def __init__(self, horizon: int | None = None):
        self.horizon = horizon
        self._journals: dict[str, RequestJournal] = {}

    def journal(self, engine: str) -> RequestJournal:
        """The (created-on-first-use) journal for ``engine``."""
        if engine not in self._journals:
            self._journals[engine] = RequestJournal(horizon=self.horizon)
        return self._journals[engine]

    def engines(self) -> list[str]:
        """Engine names with a journal, in registration order."""
        return list(self._journals)

    def incomplete(self) -> dict[str, list[SlotRecord]]:
        """Engine name -> in-flight records (each list oldest-first) —
        the cluster-wide replay set after a preemption."""
        return {name: j.incomplete() for name, j in self._journals.items()
                if j.incomplete()}

    def completed(self) -> dict[str, list[SlotRecord]]:
        """Engine name -> completed records, per-engine arrival order."""
        return {name: j.completed() for name, j in self._journals.items()
                if j.completed()}
