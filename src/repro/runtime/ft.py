"""Fault-tolerance runtime: heartbeats, stragglers, elastic rescale, restart.

This is the pod-scale rendition of the paper's power manager + interrupt
fabric: workers (≙ power domains) report liveness (≙ XAIF interrupts); dead
domains are switched off (elastic downscale) and the platform keeps running.

The controller is deliberately transport-agnostic (tick-driven state machine
fed by ``report_heartbeat``/``report_step_time``) so it can be driven by a
real coordinator service on a cluster or by a simulator in tests. Recovery
composes with :mod:`repro.ckpt.checkpoint` (elastic restore) and the
step-indexed data pipeline (bit-identical replay).
"""

from __future__ import annotations

import dataclasses
import enum
import math
import statistics
import time
from typing import Callable


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    STRAGGLING = "straggling"
    DEAD = "dead"


@dataclasses.dataclass
class WorkerInfo:
    last_heartbeat: float = 0.0
    state: WorkerState = WorkerState.HEALTHY
    step_times: list = dataclasses.field(default_factory=list)
    slow_streak: int = 0


@dataclasses.dataclass(frozen=True)
class FTConfig:
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 1.5      # slower than median x this => slow
    straggler_streak: int = 3          # consecutive slow steps => flagged
    max_restarts: int = 5
    backoff_base_s: float = 2.0
    window: int = 20                   # step-time history window


class FTController:
    def __init__(self, n_workers: int, cfg: FTConfig = FTConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.workers = {i: WorkerInfo(last_heartbeat=clock())
                        for i in range(n_workers)}
        self.restarts = 0
        self.events: list[tuple[float, str]] = []

    # -- reporting -------------------------------------------------------
    def report_heartbeat(self, worker: int):
        w = self.workers[worker]
        w.last_heartbeat = self.clock()
        if w.state is WorkerState.DEAD:
            w.state = WorkerState.HEALTHY   # rejoin (elastic upscale)
            self._log(f"worker {worker} rejoined")

    def report_step_time(self, worker: int, seconds: float):
        w = self.workers[worker]
        w.step_times.append(seconds)
        if len(w.step_times) > self.cfg.window:
            w.step_times.pop(0)

    # -- detection --------------------------------------------------------
    def tick(self) -> dict:
        """Run detection; returns {'dead': [...], 'stragglers': [...]}"""
        now = self.clock()
        dead, stragglers = [], []
        alive_times = [t for w in self.workers.values()
                       if w.state is not WorkerState.DEAD
                       for t in w.step_times[-1:]]
        median = statistics.median(alive_times) if alive_times else None
        for wid, w in self.workers.items():
            if w.state is WorkerState.DEAD:
                continue
            if now - w.last_heartbeat > self.cfg.heartbeat_timeout_s:
                w.state = WorkerState.DEAD
                dead.append(wid)
                self._log(f"worker {wid} declared dead (heartbeat timeout)")
                continue
            if median and w.step_times:
                if w.step_times[-1] > self.cfg.straggler_factor * median:
                    w.slow_streak += 1
                else:
                    w.slow_streak = 0
                if w.slow_streak >= self.cfg.straggler_streak:
                    if w.state is not WorkerState.STRAGGLING:
                        self._log(f"worker {wid} flagged as straggler")
                    w.state = WorkerState.STRAGGLING
                    stragglers.append(wid)
                elif w.state is WorkerState.STRAGGLING:
                    w.state = WorkerState.HEALTHY
        return {"dead": dead, "stragglers": stragglers}

    # -- mitigation --------------------------------------------------------
    def healthy_workers(self) -> list[int]:
        return [i for i, w in self.workers.items()
                if w.state is not WorkerState.DEAD]

    def rescale_plan(self, mesh_shape: tuple[int, ...],
                     axis: int = 0) -> tuple[int, ...] | None:
        """Largest valid mesh after losing workers: shrink ``axis`` to the
        biggest power-of-two of healthy workers (keeps divisibility for
        checkpoint resharding). None if unchanged."""
        alive = len(self.healthy_workers())
        total = math.prod(mesh_shape)
        if alive >= total:
            return None
        per_other = total // mesh_shape[axis]
        new_axis = 1
        while new_axis * 2 * per_other <= alive:
            new_axis *= 2
        new = list(mesh_shape)
        new[axis] = new_axis
        return tuple(new)

    def microbatch_shares(self, n_microbatches: int) -> dict[int, int]:
        """Straggler mitigation: stragglers get half-weight shares of the
        next step's microbatches (work rerouted to healthy peers)."""
        weights = {}
        for wid, w in self.workers.items():
            if w.state is WorkerState.DEAD:
                continue
            weights[wid] = 0.5 if w.state is WorkerState.STRAGGLING else 1.0
        total_w = sum(weights.values())
        shares = {wid: int(n_microbatches * wt / total_w)
                  for wid, wt in weights.items()}
        # distribute remainder to healthiest workers
        rem = n_microbatches - sum(shares.values())
        for wid in sorted(weights, key=lambda i: -weights[i]):
            if rem <= 0:
                break
            shares[wid] += 1
            rem -= 1
        return shares

    def restart_delay(self) -> float | None:
        """Exponential-backoff restart policy; None when budget exhausted."""
        if self.restarts >= self.cfg.max_restarts:
            return None
        delay = self.cfg.backoff_base_s * (2 ** self.restarts)
        self.restarts += 1
        return delay

    def _log(self, msg: str):
        self.events.append((self.clock(), msg))
