"""grok-1-314b [moe]: 8 experts, top-2 routing. [hf:xai-org/grok-1;
unverified] — 64L d_model=6144 48H (kv=8) d_ff=32768 vocab=131072.
Expert count (8) < model-axis size (16), so the rule engine automatically
falls back to tensor-parallel expert FFNs (d_ff over `model`) with experts
replicated — recorded in DESIGN.md. Full attention: long_500k skipped."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131072, mlp_type="swiglu", pos_emb="rope",
    moe_experts=8, moe_top_k=2, moe_interleave=1,
    moe_capacity_factor=1.25,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="grok-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, mlp_type="swiglu",
        moe_experts=4, moe_top_k=2, q_block=8, kv_block=8, remat="none",
    )
