"""nemotron-4-15b [dense]: GQA + squared-ReLU MLP + 256k vocabulary
(vocab-sharding stress case). [arXiv:2402.16819; unverified] —
32L d_model=6144 48H (kv=8) d_ff=24576 vocab=256000.
Full attention: long_500k skipped."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=256000, mlp_type="squared_relu", pos_emb="rope",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab=512, mlp_type="squared_relu",
        q_block=8, kv_block=8, remat="none",
    )
