"""internvl2-76b [vlm]: InternViT + InternLM2 — BACKBONE ONLY per the brief;
the vision tower is a STUB (input_specs() provides precomputed patch
embeddings). [arXiv:2404.16821; unverified] — 80L d_model=8192 64H (kv=8)
d_ff=28672 vocab=128256. Full attention: long_500k skipped."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256, mlp_type="swiglu", pos_emb="rope",
    embed_inputs=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, mlp_type="swiglu", embed_inputs=False,
        q_block=8, kv_block=8, remat="none",
    )
