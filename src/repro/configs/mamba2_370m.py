"""mamba2-370m [ssm]: SSD (state-space duality). [arXiv:2405.21060;
unverified] — 48L d_model=1024, ssm_state=128, head_dim=64, expand=2,
vocab=50280, tied embeddings. Attention-free: long_500k RUNS."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    ssm_chunk=256, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=64, vocab=256,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_conv_width=4,
        ssm_chunk=8, tie_embeddings=True, remat="none",
    )
