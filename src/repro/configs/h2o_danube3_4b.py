"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000. SWA makes it long_500k-eligible (window 4096)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab=32000, mlp_type="swiglu", pos_emb="rope",
    rope_theta=10_000.0, sliding_window=4096,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube3-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, mlp_type="swiglu", sliding_window=16,
        q_block=8, kv_block=8, remat="none",
    )
