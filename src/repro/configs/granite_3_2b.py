"""granite-3-2b [dense]: GQA. [hf:ibm-granite/granite-3.0-2b-base; hf] —
40L d_model=2048 32H (kv=8) d_ff=8192 vocab=49155, tied embeddings.
Full attention: long_500k skipped."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab=49155, mlp_type="swiglu", pos_emb="rope",
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", family="dense",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, mlp_type="swiglu", tie_embeddings=True,
        q_block=8, kv_block=8, remat="none",
    )
