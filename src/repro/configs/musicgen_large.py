"""musicgen-large [audio]: decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf] — 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.
The EnCodec frontend is a STUB: input_specs() provides precomputed frame
embeddings (brief requirement). Full attention: long_500k skipped."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, mlp_type="gelu", pos_emb="sinusoidal",
    embed_inputs=False,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=64, mlp_type="gelu", pos_emb="sinusoidal",
        embed_inputs=False, q_block=8, kv_block=8, remat="none",
    )
