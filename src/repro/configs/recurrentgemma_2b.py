"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, pattern
(rec, rec, attn). [arXiv:2402.19427; hf] — 26L d_model=2560 10H (MQA kv=1)
d_ff=7680 vocab=256000, rnn_width=2560, window=2048, tied embeddings.
Sub-quadratic: long_500k RUNS."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000, mlp_type="geglu", pos_emb="rope",
    rnn_width=2560, attn_window=2048, block_pattern=("rec", "rec", "attn"),
    ssm_conv_width=4, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256, mlp_type="geglu", rnn_width=64, attn_window=16,
        block_pattern=("rec", "rec", "attn"), ssm_conv_width=4,
        tie_embeddings=True, q_block=8, kv_block=8, remat="none",
    )
