"""llama4-maverick-400b-a17b [moe]: 128 experts, top-1, shared expert.
[hf:meta-llama/Llama-4-*; unverified] — 48L d_model=5120 40H (kv=8)
d_ff=8192 vocab=202048.

Config note (see DESIGN.md): the assignment line with MoE on *every* layer
yields ~790 B params, inconsistent with the 400B-A17B name; we follow the
published Llama-4 structure — MoE every 2nd layer + a shared expert — landing
at ~398 B total / ~17 B active. Full attention assumed: long_500k skipped."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048, mlp_type="swiglu", pos_emb="rope",
    moe_experts=128, moe_top_k=1, moe_interleave=2, moe_shared_expert=True,
    moe_capacity_factor=1.25,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, mlp_type="swiglu",
        moe_experts=4, moe_top_k=1, moe_interleave=2, moe_shared_expert=True,
        q_block=8, kv_block=8, remat="none",
    )
