"""stablelm-3b [dense]: MHA (kv=32) decoder. [hf:stabilityai/stablelm-2-1_6b;
unverified] — 32L d_model=2560 32H d_ff=6912 vocab=50304. Pure full attention:
long_500k skipped (noted in DESIGN.md)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab=50304, mlp_type="swiglu", pos_emb="rope",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab=256, mlp_type="swiglu",
        q_block=8, kv_block=8, remat="none",
    )
