"""Architecture config registry: ``get(name)`` / ``names()`` / ``smoke(name)``.

One module per assigned architecture; each exposes ``CONFIG`` (the exact
published config) and ``smoke()`` (a reduced same-family config for CPU
tests)."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "h2o_danube3_4b",
    "stablelm_3b",
    "granite_3_2b",
    "nemotron_4_15b",
    "musicgen_large",
    "internvl2_76b",
    "grok_1_314b",
    "llama4_maverick_400b",
    "mamba2_370m",
    "recurrentgemma_2b",
)

# accept dashed ids from the assignment table too
_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES["llama4-maverick-400b-a17b"] = "llama4_maverick_400b"
_ALIASES["h2o-danube-3-4b"] = "h2o_danube3_4b"
_ALIASES["recurrentgemma-2b"] = "recurrentgemma_2b"


def _module(name: str):
    name = _ALIASES.get(name, name)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def smoke(name: str) -> ModelConfig:
    return _module(name).smoke()


def names() -> tuple[str, ...]:
    return ARCHS
