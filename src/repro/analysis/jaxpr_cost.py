"""Jaxpr-level cost model: loop-aware FLOP and HBM-traffic counting.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count, which under-counts scan-over-layers models by ~n_layers×accum. The
jaxpr still knows every scan length, so we walk it and produce both:

  * ``once``  — every sub-jaxpr counted once (matches XLA's convention);
  * ``full``  — loop bodies multiplied by trip counts (true per-step cost).

The ratio full/once is then used to correct XLA's per-device numbers (which
carry the post-SPMD sharding information the jaxpr lacks).

FLOPs: exact for dot_general/conv (2·M·N·K); elementwise ignored (sub-1 %
for the assigned architectures). Bytes: streaming estimate — operand+result
bytes of dots, convs, gathers and scatters (tensors too large for VMEM
residency dominate HBM traffic; fused elementwise traffic rides along with
them and is not double-counted).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np
from jax import core as jcore


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes)

    def __mul__(self, k: float):
        return Cost(self.flops * k, self.bytes * k)


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape) * aval.dtype.itemsize) if aval.shape else float(aval.dtype.itemsize)
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    m = math.prod([a.shape[i] for i in range(len(a.shape))
                   if i not in set(lc) | set(lb)])
    n = math.prod([b.shape[i] for i in range(len(b.shape))
                   if i not in set(rc) | set(rb)])
    k = math.prod([a.shape[i] for i in lc])
    batch = math.prod([a.shape[i] for i in lb])
    return 2.0 * m * n * k * batch


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel spatial * in_features)
    kernel_elems = math.prod(rhs.shape[:-1])  # all but out-features
    return 2.0 * math.prod(out.shape) * kernel_elems / max(rhs.shape[-1], 1) * 1.0


_SUBJAXPR_SCAN = ("scan",)
_SUBJAXPR_WHILE = ("while",)
_TRAFFIC_PRIMS = {"dot_general", "conv_general_dilated", "gather", "scatter",
                  "scatter-add", "scatter_add", "dynamic_slice",
                  "dynamic_update_slice", "sort", "cumsum", "cumlogsumexp"}


def _eqn_cost(eqn) -> Cost:
    prim = eqn.primitive.name
    if prim == "dot_general":
        f = _dot_flops(eqn)
        b = sum(_nbytes(v.aval) for v in eqn.invars) + \
            sum(_nbytes(v.aval) for v in eqn.outvars)
        return Cost(f, b)
    if prim == "conv_general_dilated":
        out = eqn.outvars[0].aval
        rhs = eqn.invars[1].aval
        f = 2.0 * math.prod(out.shape) * math.prod(rhs.shape[:-1]) / max(rhs.shape[-1], 1)
        b = sum(_nbytes(v.aval) for v in eqn.invars) + _nbytes(out)
        return Cost(f, b)
    if prim in _TRAFFIC_PRIMS:
        b = sum(_nbytes(v.aval) for v in eqn.invars) + \
            sum(_nbytes(v.aval) for v in eqn.outvars)
        return Cost(0.0, b)
    return Cost()


def _sub_jaxprs(eqn):
    """Yield (closed_jaxpr, multiplier) pairs for call-like primitives."""
    p = eqn.params
    name = eqn.primitive.name
    if name == "scan":
        yield p["jaxpr"], float(p["length"])
        return
    if name == "while":
        # trip count unknown at jaxpr level: count once (rare in our models)
        yield p["body_jaxpr"], 1.0
        yield p["cond_jaxpr"], 1.0
        return
    if name == "cond":
        for br in p["branches"]:
            yield br, 1.0 / max(len(p["branches"]), 1)
        return
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            sub = p[key]
            yield sub, 1.0
            return


def _walk(jaxpr, mult_loops: bool) -> Cost:
    total = Cost()
    for eqn in jaxpr.eqns:
        handled = False
        for sub, k in _sub_jaxprs(eqn):
            inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
            total = total + _walk(inner, mult_loops) * (k if mult_loops else 1.0)
            handled = True
        if not handled:
            total = total + _eqn_cost(eqn)
    return total


def jaxpr_costs(fn, *abstract_args) -> tuple[Cost, Cost]:
    """Returns (once, full) costs of ``fn`` traced at the given avals."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    once = _walk(closed.jaxpr, mult_loops=False)
    full = _walk(closed.jaxpr, mult_loops=True)
    return once, full


def loop_correction(fn, *abstract_args) -> tuple[float, float, Cost]:
    """(flops_ratio, bytes_ratio, full_cost): multiply XLA's per-device
    numbers by these ratios to account for loop trip counts."""
    once, full = jaxpr_costs(fn, *abstract_args)
    fr = full.flops / once.flops if once.flops else 1.0
    br = full.bytes / once.bytes if once.bytes else 1.0
    return fr, br, full
