"""Post-SPMD HLO static analysis: loop-aware FLOPs, HBM traffic, collectives.

Why not ``compiled.cost_analysis()``: XLA counts each ``while`` body ONCE, so
scan-over-layers models are under-counted by ~n_layers (and XLA's partial
unrolling makes the error shape-dependent). We therefore parse
``compiled.as_text()`` into its computation graph and walk it from ENTRY,
multiplying by the ``known_trip_count`` recorded on each while op:

  * FLOPs   — exact for dot/convolution (2·|out|·K), counted wherever they
              appear (including inside fused computations);
  * HBM     — fusion-aware: a fusion is one HBM transaction (operands+result);
              top-scope dots/gathers/collectives/DUS count operands+results;
              ops *inside* fused computations never touch HBM;
  * wire    — collective bytes with ring-algorithm factors per op kind.

All numbers are PER DEVICE (the module is the post-partitioning program).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Iterable

from repro.core import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|[\w\[\],\{\}\d]+?)\s+"
    r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_COMP_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_WHILE_TARGETS_RE = re.compile(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}|"
                          r"true_computation=%?([\w\.\-]+), false_computation=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                    "collective-permute")

# top-scope ops whose operands+results stream through HBM
_HBM_OPS = {
    "fusion", "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "transpose", "concatenate", "pad",
    "slice", "reduce", "convert", "sort", "select-and-scatter", "reverse",
    "broadcast", "iota", "compare", "add", "multiply", "subtract", "divide",
    "exponential", "tanh", "maximum", "minimum", "rsqrt", "select", "custom-call",
}
# ...but tuple plumbing is free. ``copy`` is excluded: XLA:CPU materializes
# while-carry copies that the TPU backend aliases away.
_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "reshape", "copy"}


def shape_list(type_str: str) -> list[tuple[str, tuple[int, ...]]]:
    """All (dtype, dims) array shapes inside a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((m.group(1), dims))
    return out


def type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict          # %name -> type_str
    is_fusion_body: bool = False


def parse_module(hlo_text: str) -> tuple[dict, str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    pending: list[str] = []   # wrapped multi-line computation header
    for raw in hlo_text.splitlines():
        stripped = raw.strip()
        if cur is None:
            if pending:
                pending.append(stripped)
                if stripped.endswith("{"):
                    header = " ".join(pending)
                    pending = []
                    m = _COMP_HEADER_RE.match(header)
                    if m:
                        cur = Computation(m.group(2), [], {})
                        comps[cur.name] = cur
                        if m.group(1):
                            entry = cur.name
                continue
            looks_like_header = (("(" in stripped)
                                 and (stripped.startswith("%")
                                      or stripped.startswith("ENTRY")))
            if looks_like_header and stripped.endswith("{"):
                m = _COMP_HEADER_RE.match(stripped)
                if m:
                    cur = Computation(m.group(2), [], {})
                    comps[cur.name] = cur
                    if m.group(1):
                        entry = cur.name
                continue
            if looks_like_header and "=" not in stripped.split("(", 1)[0]:
                pending = [stripped]
            continue
        if stripped == "}":
            cur = None
            continue
        m = _OP_RE.match(raw)
        if m:
            name, type_str, opcode = m.group(1), m.group(2), m.group(3)
            cur.symbols[name] = type_str
            cur.ops.append(Op(name, type_str, opcode, stripped))
    return comps, entry


def _dot_flops(op: Op, symbols: dict) -> float:
    out_elems = 0
    for dt, dims in shape_list(op.type_str):
        n = 1
        for d in dims:
            n *= d
        out_elems += n
    k = 1
    cd = _LHS_CDIMS_RE.search(op.line)
    if cd:
        # first operand after the opcode is the lhs
        paren = op.line.split(f"{op.opcode}(", 1)[1]
        ops_m = _OPERAND_RE.findall(paren)
        if ops_m:
            lhs_type = symbols.get(ops_m[0], "")
            shapes = shape_list(lhs_type)
            if shapes:
                dims = shapes[0][1]
                for i in (int(x) for x in cd.group(1).split(",") if x):
                    if i < len(dims):
                        k *= dims[i]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, symbols: dict) -> float:
    paren = op.line.split("convolution(", 1)[1]
    ops_m = _OPERAND_RE.findall(paren)
    out_elems = sum(math.prod(d) if d else 1 for _, d in shape_list(op.type_str))
    if len(ops_m) >= 2:
        rhs = shape_list(symbols.get(ops_m[1], ""))
        if rhs:
            dims = rhs[0][1]
            kernel = math.prod(dims) / max(dims[-1], 1)
            return 2.0 * out_elems * kernel
    return 2.0 * out_elems


def _operand_bytes(op: Op, symbols: dict) -> int:
    try:
        paren = op.line.split(f"{op.opcode}(", 1)[1]
    except IndexError:
        return 0
    paren = paren.split(")", 1)[0]
    total = 0
    for nm in _OPERAND_RE.findall(paren):
        total += type_bytes(symbols.get(nm, ""))
    return total


def _wire_factor(kind: str, n: int, result_b: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return result_b * (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * result_b * (n - 1) / n
    if kind == "reduce-scatter":
        return result_b * (n - 1)
    if kind == "all-to-all":
        return result_b * (n - 1) / n
    if kind == "collective-permute":
        return float(result_b)
    return 0.0


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    # optional detail: metadata op_name prefix -> (flops, bytes)
    by_source: dict = dataclasses.field(default_factory=dict)

    def top_sources(self, n: int = 12, key: str = "bytes") -> list:
        idx = 1 if key == "bytes" else 0
        items = sorted(self.by_source.items(), key=lambda kv: -kv[1][idx])
        return items[:n]


_METADATA_RE = re.compile(r'op_name="([^"]*)"')


def _source_of(line: str) -> str:
    m = _METADATA_RE.search(line)
    if not m:
        return "<none>"
    name = m.group(1)
    # strip jit wrappers and indices: keep the trailing primitive-ish path
    parts = [p for p in name.split("/") if p and not p.startswith("jit(")]
    return "/".join(parts[-3:]) if parts else name


def _fusion_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """HBM bytes of one fusion: slice-aware inputs + DUS-aware output.

    A fused dynamic-slice only reads its slice (not the full stacked operand:
    scan-over-layers weight reads!); a fused dynamic-update-slice writes only
    the update (the buffer is aliased in place on TPU)."""
    m = _CALLS_RE.search(op.line)
    body = comps.get(m.group(1)) if m else None
    out_bytes = type_bytes(op.type_str)
    try:
        paren = op.line.split(f"{op.opcode}(", 1)[1].split(")", 1)[0]
        operands = _OPERAND_RE.findall(paren)
    except IndexError:
        operands = []
    in_bytes = 0.0
    if body is None:
        in_bytes = sum(type_bytes(1) for _ in ())  # unreachable
        for nm in operands:
            in_bytes += type_bytes(comp.symbols.get(nm, ""))
        return in_bytes + out_bytes
    # map parameter index -> sliced? / bytes actually read
    param_types: dict[int, str] = {}
    param_names: dict[str, int] = {}
    sliced_reads: dict[int, float] = {}
    alias: dict[str, str] = {}
    dus_update_bytes = None
    for bop in body.ops:
        if bop.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", bop.line)
            if pm:
                idx = int(pm.group(1))
                param_types[idx] = bop.type_str
                param_names[bop.name] = idx
        elif bop.opcode in ("bitcast", "copy", "convert", "reshape"):
            ops_m = _OPERAND_RE.findall(bop.line.split("(", 1)[1])
            if ops_m:
                alias[bop.name] = ops_m[0]
        elif bop.opcode in ("dynamic-slice", "slice"):
            ops_m = _OPERAND_RE.findall(bop.line.split(bop.opcode + "(", 1)[1])
            if ops_m:
                src = ops_m[0]
                while src in alias:
                    src = alias[src]
                if src in param_names:
                    idx = param_names[src]
                    sliced_reads[idx] = sliced_reads.get(idx, 0.0) + \
                        type_bytes(bop.type_str)
        elif bop.opcode == "dynamic-update-slice":
            ops_m = _OPERAND_RE.findall(
                bop.line.split("dynamic-update-slice(", 1)[1])
            if len(ops_m) >= 2:
                upd = ops_m[1]
                while upd in alias:
                    upd = alias[upd]
                b = type_bytes(body.symbols.get(upd, ""))
                dus_update_bytes = (dus_update_bytes or 0.0) + b
    for i, nm in enumerate(operands):
        full = type_bytes(comp.symbols.get(nm, ""))
        if i in sliced_reads:
            in_bytes += min(sliced_reads[i], full)
        else:
            in_bytes += full
    if dus_update_bytes is not None:
        out_bytes = min(out_bytes, 2.0 * dus_update_bytes)
    return in_bytes + out_bytes


def analyze(hlo_text: str, total_devices: int) -> HloCost:
    comps, entry = parse_module(hlo_text)
    cost = HloCost()

    def acc_src(op: Op, f: float, b: float):
        src = _source_of(op.line)
        cur = cost.by_source.get(src, (0.0, 0.0))
        cost.by_source[src] = (cur[0] + f, cur[1] + b)

    def visit(name: str, mult: float, in_fusion: bool, depth: int = 0):
        comp = comps.get(name)
        if comp is None or depth > 24:
            return
        for op in comp.ops:
            oc = op.opcode
            base = oc.replace("-start", "")
            if base in COLLECTIVE_KINDS and "-done" not in oc:
                b = type_bytes(op.type_str)
                if oc.endswith("-start") and base == "all-gather":
                    # result tuple holds (operand, result): count the result
                    shapes = shape_list(op.type_str)
                    if len(shapes) >= 2:
                        dt, dims = shapes[-1]
                        b = math.prod(dims) * _DTYPE_BYTES.get(dt, 0)
                gi = _GROUPS_IOTA_RE.search(op.line)
                if gi:
                    n = int(gi.group(2))
                else:
                    gl = _GROUPS_LIST_RE.search(op.line)
                    n = len(gl.group(1).split(",")) if gl else total_devices
                cost.collective_counts[base] = cost.collective_counts.get(base, 0) + mult
                cost.collective_bytes[base] = cost.collective_bytes.get(base, 0) + b * mult
                cost.wire_bytes += _wire_factor(base, n, b) * mult
                if not in_fusion:
                    cost.hbm_bytes += (type_bytes(op.type_str)
                                       + _operand_bytes(op, comp.symbols)) * mult
                continue
            if oc == "dot":
                f = _dot_flops(op, comp.symbols) * mult
                cost.flops += f
                b = 0.0
                if not in_fusion:
                    b = (type_bytes(op.type_str)
                         + _operand_bytes(op, comp.symbols)) * mult
                    cost.hbm_bytes += b
                acc_src(op, f, b)
                continue
            if oc == "convolution":
                f = _conv_flops(op, comp.symbols) * mult
                cost.flops += f
                b = 0.0
                if not in_fusion:
                    b = (type_bytes(op.type_str)
                         + _operand_bytes(op, comp.symbols)) * mult
                    cost.hbm_bytes += b
                acc_src(op, f, b)
                continue
            if oc == "while":
                tm = _TRIP_RE.search(op.line)
                trips = float(tm.group(1)) if tm else 1.0
                wt = _WHILE_TARGETS_RE.search(op.line)
                if wt:
                    visit(wt.group(2), mult * trips, in_fusion, depth + 1)
                    visit(wt.group(1), mult * trips, in_fusion, depth + 1)
                continue
            if oc == "fusion":
                m = _CALLS_RE.search(op.line)
                if m:
                    visit(m.group(1), mult, True, depth + 1)
                if not in_fusion:
                    b = _fusion_bytes(op, comp, comps) * mult
                    cost.hbm_bytes += b
                    acc_src(op, 0.0, b)
                continue
            if oc == "conditional":
                bm = _BRANCHES_RE.search(op.line)
                if bm:
                    names = []
                    if bm.group(1):
                        names = _OPERAND_RE.findall(bm.group(1)) or \
                            [x.strip().lstrip("%") for x in bm.group(1).split(",")]
                    else:
                        names = [bm.group(2), bm.group(3)]
                    for nm in names:
                        visit(nm, mult / max(len(names), 1), in_fusion, depth + 1)
                continue
            if oc in ("call", "custom-call", "reduce", "sort", "scatter",
                      "select-and-scatter", "map", "reduce-window"):
                m = _TO_APPLY_RE.search(op.line) or _CALLS_RE.search(op.line)
                if m and m.group(1) in comps:
                    visit(m.group(1), mult, in_fusion, depth + 1)
                if not in_fusion and oc not in ("call",):
                    cost.hbm_bytes += (type_bytes(op.type_str)
                                       + _operand_bytes(op, comp.symbols)) * mult
                continue
            if not in_fusion and oc not in _FREE_OPS and base not in COLLECTIVE_KINDS:
                if oc == "dynamic-slice":
                    cost.hbm_bytes += 2.0 * type_bytes(op.type_str) * mult
                elif oc == "dynamic-update-slice":
                    paren = op.line.split("dynamic-update-slice(", 1)[1]
                    ops_m = _OPERAND_RE.findall(paren.split(")", 1)[0])
                    upd = type_bytes(comp.symbols.get(ops_m[1], "")) if len(ops_m) > 1 else 0
                    cost.hbm_bytes += 2.0 * upd * mult
                elif oc in _HBM_OPS:
                    cost.hbm_bytes += (type_bytes(op.type_str)
                                       + _operand_bytes(op, comp.symbols)) * mult

    if entry is None and comps:
        entry = next(iter(comps))
    if entry is not None:
        visit(entry, 1.0, False)
    return cost


# ---------------------------------------------------------------------------
# Roofline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    """Per-device, per-step roofline terms in seconds."""

    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_global: float
    compute_s: float
    memory_s: float
    collective_s: float
    collective_counts: dict
    collective_result_bytes: dict
    memory_stats: dict

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """time(MODEL_FLOPS at peak on all chips) / bound time — the score."""
        ideal_s = self.model_flops_global / (self.chips * hw.TPU_V5E.peak_flops_bf16)
        return ideal_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["bound_s"] = self.bound_s
        d["useful_flops_ratio"] = self.useful_flops_ratio
        d["roofline_fraction"] = self.roofline_fraction
        return d


def make_roofline(arch: str, shape: str, mesh_name: str, chips: int,
                  cost: HloCost, model_flops: float, mem_stats: dict) -> Roofline:
    chip = hw.TPU_V5E
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=cost.flops,
        hbm_bytes_per_device=cost.hbm_bytes,
        wire_bytes_per_device=cost.wire_bytes,
        model_flops_global=model_flops,
        compute_s=cost.flops / chip.peak_flops_bf16,
        memory_s=cost.hbm_bytes / chip.hbm_bandwidth,
        collective_s=cost.wire_bytes / chip.ici_bandwidth,
        collective_counts=cost.collective_counts,
        collective_result_bytes=cost.collective_bytes,
        memory_stats=mem_stats,
    )


def model_flops_for(cfg, shape_kind: str, global_batch: int, seq: int) -> float:
    """6·N_active·tokens for training, 2·N_active·tokens for inference."""
    n = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n * global_batch * seq
    if shape_kind == "prefill":
        return 2.0 * n * global_batch * seq
    return 2.0 * n * global_batch  # decode: one token per sequence
