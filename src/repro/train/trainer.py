"""Train step construction: grad accumulation, mixed precision, remat,
aux-loss handling; sharded end-to-end through the platform rule engine."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import registry
from repro.models.config import ModelConfig
from repro.sharding import axes as lx_
from repro.sharding import params as P
from repro.sharding import rules as R
from repro.train import optim as optim_lib

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    accum: int = 1                 # gradient-accumulation microbatches
    accum_dtype: str = "float32"   # grad accumulation buffer dtype
    aux_weight: float = 0.01       # MoE load-balance loss weight
    z_loss: float = 1e-4
    clip: float = 1.0


def make_loss_fn(cfg: ModelConfig, tc: TrainConfig):
    def loss_fn(params, batch):
        tokens = batch.get("tokens")
        embeds = batch.get("embeds")
        logits, aux = registry.forward(params, cfg, tokens=tokens, embeds=embeds)
        from repro.models.layers import cross_entropy

        ce = cross_entropy(logits, batch["labels"], z_loss=tc.z_loss).mean()
        return ce + tc.aux_weight * aux, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns ``train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)``. ``batch`` leaves have shape (accum, microbatch, ...); the
    accumulation loop is a scan (bounded memory, overlappable collectives)."""
    optimizer = optim_lib.get(tc.optimizer)
    loss_fn = make_loss_fn(cfg, tc)
    acc_dt = jnp.dtype(tc.accum_dtype)

    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if tc.accum == 1:
            mb = jax.tree.map(lambda x: x[0], batch)
            (loss, metrics), grads = grad_fn(params, mb)
        else:
            def mb_step(acc, mb):
                (loss, metrics), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(acc_dt), acc, g)
                return acc, (loss, metrics)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            grads, (losses, metricses) = lax.scan(mb_step, zeros, batch)
            grads = jax.tree.map(lambda g: (g / tc.accum).astype(acc_dt), grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metricses)

        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params, jnp.asarray(tc.lr, F32))
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step, optimizer


# ---------------------------------------------------------------------------
# Sharded assembly
# ---------------------------------------------------------------------------


def batch_abstract(cfg: ModelConfig, global_batch: int, seq: int, accum: int):
    mb = global_batch // accum
    out: dict[str, Any] = {"labels": jax.ShapeDtypeStruct((accum, mb, seq), jnp.int32)}
    if cfg.embed_inputs:
        out["tokens"] = jax.ShapeDtypeStruct((accum, mb, seq), jnp.int32)
    else:
        out["embeds"] = jax.ShapeDtypeStruct((accum, mb, seq, cfg.d_model),
                                             jnp.bfloat16)
    return out


def batch_axes(cfg: ModelConfig):
    out: dict[str, Any] = {"labels": P.Axes(None, lx_.BATCH, lx_.SEQ)}
    if cfg.embed_inputs:
        out["tokens"] = P.Axes(None, lx_.BATCH, lx_.SEQ)
    else:
        out["embeds"] = P.Axes(None, lx_.BATCH, lx_.SEQ, lx_.EMBED)
    return out


@dataclasses.dataclass
class ShardedTrain:
    """Everything needed to lower/run a sharded train step on a mesh."""

    step_fn: Any
    params_abstract: Any
    params_shardings: Any
    opt_abstract: Any
    opt_shardings: Any
    batch_abstract: Any
    batch_shardings: Any
    metric_sharding: Any
    raw_fn: Any = None  # unjitted step (jaxpr-level cost analysis)


def _fsdp_auto(cfg: ModelConfig, mesh: Mesh) -> bool:
    """ZeRO policy: full FSDP (weights sharded over `data`) only when the
    model-parallel shard alone exceeds ~4 GiB bf16 per device; smaller models
    keep weights replicated over `data` and shard ONLY the optimizer state
    (ZeRO-1) — one weight all-gather per step instead of per layer per
    microbatch."""
    model_shard = mesh.shape.get("model", 1)
    return cfg.param_count() * 2 / model_shard > 4 * 1024**3


def build_sharded_train(cfg: ModelConfig, tc: TrainConfig, mesh: Mesh,
                        rules: R.Rules, global_batch: int, seq: int,
                        fsdp: bool | None = None) -> ShardedTrain:
    decls = registry.decls(cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else F32
    p_abs = jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
                         P.abstract_tree(decls))
    p_axes = P.axes_tree(decls)
    if fsdp is None:
        fsdp = _fsdp_auto(cfg, mesh)
    param_rules = rules if fsdp else rules.override(
        name=rules.name + "+zero1", **{lx_.EMBED: ()})
    p_shard = R.tree_shardings(p_abs, p_axes, param_rules, mesh)

    train_step, optimizer = make_train_step(cfg, tc)

    opt_abs = jax.eval_shape(optimizer.init, p_abs)
    opt_axes = optimizer.axes(p_axes)
    opt_shard = R.tree_shardings(opt_abs, opt_axes, rules, mesh)

    b_abs = batch_abstract(cfg, global_batch, seq, tc.accum)
    b_shard = R.tree_shardings(b_abs, batch_axes(cfg), rules, mesh)
    repl = NamedSharding(mesh, PartitionSpec())

    jitted = jax.jit(
        train_step,
        in_shardings=(p_shard, opt_shard, b_shard),
        out_shardings=(p_shard, opt_shard, repl),
        donate_argnums=(0, 1),
    )
    return ShardedTrain(jitted, p_abs, p_shard, opt_abs, opt_shard,
                        b_abs, b_shard, repl, raw_fn=train_step)
