"""GPipe-style pipeline parallelism over a mesh axis via collective_permute.

Optional PP for very deep stacks: layers are grouped into S stages, one per
device along the ``stage`` mesh axis; microbatches stream through with the
classic (S - 1)-bubble schedule. Activations move stage-to-stage with
``lax.ppermute`` inside ``shard_map`` — the jax-native rendition of the
send/recv pipeline, with no torch.distributed emulation.

The implementation is schedule-only (forward streaming + loss on the last
stage); it composes with grad accumulation by treating each microbatch slot
as a pipeline slot.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.compat import shard_map


def pipeline_forward(stage_fn: Callable, mesh: Mesh, axis: str = "stage"):
    """Build ``run(stage_params, x_microbatches) -> y_microbatches``.

    stage_fn(params_local, x) applies ONE stage's layers.
    stage_params leaves: (S, ...) — stacked per stage, sharded over ``axis``.
    x_microbatches: (M, mb, ...) — every microbatch visits every stage.
    """
    s = mesh.shape[axis]

    def local(stage_params, xs):
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        idx = lax.axis_index(axis)
        m = xs.shape[0]
        n_ticks = m + s - 1
        perm = [(i, (i + 1) % s) for i in range(s)]

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t (if any); others take the permuted
            # activation from the previous stage
            x_in = jnp.where(t < m, xs[jnp.minimum(t, m - 1)], jnp.zeros_like(xs[0]))
            inp = jnp.where(idx == 0, x_in, buf)
            out = stage_fn(stage_params, inp)
            # last stage emits out for microbatch (t - (S-1))
            emit_t = t - (s - 1)
            outputs = lax.cond(
                (emit_t >= 0) & (idx == s - 1),
                lambda o: o.at[jnp.maximum(emit_t, 0)].set(out),
                lambda o: o, outputs)
            buf = lax.ppermute(out, axis, perm)
            return (buf, outputs), None

        buf0 = jnp.zeros_like(xs[0])
        out0 = jnp.zeros_like(xs)
        (_, outputs), _ = lax.scan(tick, (buf0, out0), jnp.arange(n_ticks))
        # broadcast final outputs from the last stage to all (masked psum —
        # ppermute wants a bijection, a broadcast is not one)
        outputs = lax.psum(jnp.where(idx == s - 1, outputs, 0.0), axis)
        return outputs

    in_specs = (P(axis), P())
    out_specs = P()
    return shard_map(local, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_vma=False)
