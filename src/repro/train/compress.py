"""int8 gradient compression with error feedback for the cross-pod reduce.

The cross-pod DP all-reduce is the lowest-bandwidth collective in the
(2,16,16) mesh (inter-pod links). Quantizing gradients to int8 with a
per-tensor scale cuts its wire bytes 4× vs fp32 (2× vs bf16); the residual
(quantization error) is fed back into the next step's gradients, which keeps
SGD-style convergence (error-feedback compression, Seide et al. / Karimireddy
et al.).

``compressed_psum`` runs inside a ``shard_map`` manual region over the
``pod`` axis with ``data``/``model`` left on auto — model code inside is
untouched (GSPMD still partitions it), only the pod reduction is hand-rolled.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.core.compat import shard_map

F32 = jnp.float32


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g.astype(F32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compressed_psum_tree(grads: Any, error: Any, axis: str) -> tuple[Any, Any]:
    """Inside a shard_map manual region: int8-quantized psum over ``axis``
    with error feedback. Returns (reduced fp32 grads, new error state)."""
    n = compat.axis_size(axis)

    def one(g, e):
        g = g.astype(F32) + e.astype(F32)       # apply feedback
        # agree on ONE scale across the axis (scalar pmax), then the int8
        # payloads are commensurable and can be summed on the wire
        amax = lax.pmax(jnp.max(jnp.abs(g)), axis)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        err = g - dequantize(q, scale)          # what the wire loses
        q_sum = lax.psum(q.astype(jnp.int32), axis)
        reduced = q_sum.astype(F32) * scale / n
        return reduced, err.astype(e.dtype)

    out = jax.tree.map(one, grads, error)
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return red, new_err


def init_error_state(params: Any, dtype=jnp.bfloat16) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def make_pod_compressed_grad_fn(grad_fn, mesh: Mesh):
    """Wrap ``grad_fn(params, batch) -> grads`` so each pod computes grads on
    its own batch shard and the pods exchange int8-compressed sums.

    Requires the mesh to have a 'pod' axis; params replicated across pods.
    """
    if "pod" not in mesh.axis_names:
        raise ValueError("pod-compressed gradients need a 'pod' mesh axis")
    auto = frozenset(a for a in mesh.axis_names if a != "pod")

    def fn(params, batch, error):
        def inner(params, batch, error):
            grads = grad_fn(params, batch)
            red, new_err = compressed_psum_tree(grads, error, "pod")
            return red, new_err

        return shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P("pod"), P()),
            out_specs=(P(), P()),
            check_vma=False,
            auto=auto,
        )(params, batch, error)

    return fn
