"""Optimizers built from scratch (no optax): AdamW, Adafactor, Lion.

Each optimizer exposes ``init/update/axes`` — ``axes`` maps the parameter
logical-axes tree to the state's logical axes, so optimizer state shards
exactly like (or factored from) its parameters: ZeRO-style partitioning falls
out of the same rule engine that shards the model.

Mixed precision: parameters live in bf16; AdamW/Lion keep an fp32 master copy
in the state. Adafactor (used for the ≥70 B configs) keeps factored fp32
second moments and, by default, an fp32 master as well (disable with
``master=False`` to halve state bytes at the cost of bf16 update noise).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.sharding.params import Axes

F32 = jnp.float32


def _tree_map(f, *trees, **kw):
    return jax.tree.map(f, *trees, **kw)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return _tree_map(lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """``update(grads, state, params, lr) -> (new_params, new_state, metrics)``
    with ``new_state`` structurally identical to ``init(params)`` (donation-
    safe across steps)."""

    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any, dict]]
    axes: Callable[[Any], Any]   # param axes tree -> state axes tree


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip: float = 1.0) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "master": _tree_map(lambda p: p.astype(F32), params),
            "m": _tree_map(lambda p: jnp.zeros(p.shape, F32), params),
            "v": _tree_map(lambda p: jnp.zeros(p.shape, F32), params),
        }

    def update(grads, state, params, lr):
        if clip:
            grads, gnorm = clip_by_global_norm(grads, clip)
        else:
            gnorm = global_norm(grads)
        step = state["step"] + 1
        t = step.astype(F32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, master):
            g = g.astype(F32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            master = master - lr * (u + weight_decay * master)
            return m, v, master

        out = _tree_map(upd, grads, state["m"], state["v"], state["master"])
        m = _tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        v = _tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        master = _tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = _tree_map(lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, {"step": step, "master": master, "m": m, "v": v}, \
            {"grad_norm": gnorm}

    def axes(param_axes):
        return {
            "step": Axes(),
            "master": param_axes,
            "m": param_axes,
            "v": param_axes,
        }

    return Optimizer("adamw", init, update, axes)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; memory ~O(rows+cols) for matrices)
# ---------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 2 and shape[-2] >= 2


def adafactor(eps: float = 1e-30, clip_thresh: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0,
              master: bool = True) -> Optimizer:
    def init(params):
        def vr(p):
            return (jnp.zeros(p.shape[:-1], F32) if _factored(p.shape)
                    else jnp.zeros(p.shape, F32))

        def vc(p):
            return (jnp.zeros((*p.shape[:-2], p.shape[-1]), F32)
                    if _factored(p.shape) else jnp.zeros((1,), F32))

        st = {
            "step": jnp.zeros((), jnp.int32),
            "vr": _tree_map(vr, params),
            "vc": _tree_map(vc, params),
        }
        if master:
            st["master"] = _tree_map(lambda p: p.astype(F32), params)
        return st

    def update(grads, state, params, lr):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(F32) + 1.0) ** (-decay)

        def upd(g, vr, vc, p, mstr):
            g = g.astype(F32)
            g2 = g * g + eps
            if _factored(g.shape):
                vr2 = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc2 = beta * vc + (1 - beta) * g2.mean(axis=-2)
                rfac = jax.lax.rsqrt(
                    vr2 / jnp.maximum(vr2.mean(axis=-1, keepdims=True), eps))
                cfac = jax.lax.rsqrt(vc2)
                u = g * rfac[..., None] * cfac[..., None, :]
            else:
                vr2 = beta * vr + (1 - beta) * g2
                vc2 = vc
                u = g * jax.lax.rsqrt(vr2)
            # update clipping (RMS <= clip_thresh)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            base = mstr if mstr is not None else p.astype(F32)
            new = base - lr * (u + weight_decay * base)
            return vr2, vc2, new

        leaves_g, tdef = jax.tree.flatten(grads)
        leaves_vr = tdef.flatten_up_to(state["vr"])
        leaves_vc = tdef.flatten_up_to(state["vc"])
        leaves_p = tdef.flatten_up_to(params)
        leaves_m = (tdef.flatten_up_to(state["master"]) if "master" in state
                    else [None] * len(leaves_g))
        outs = [upd(g, vr, vc, p, m) for g, vr, vc, p, m in
                zip(leaves_g, leaves_vr, leaves_vc, leaves_p, leaves_m)]
        vr = jax.tree.unflatten(tdef, [o[0] for o in outs])
        vc = jax.tree.unflatten(tdef, [o[1] for o in outs])
        new_master = jax.tree.unflatten(tdef, [o[2] for o in outs])
        new_params = _tree_map(lambda mp, p: mp.astype(p.dtype), new_master, params)
        st = {"step": step, "vr": vr, "vc": vc}
        if "master" in state:
            st["master"] = new_master
        return new_params, st, {"grad_norm": global_norm(grads)}

    def axes(param_axes):
        def vr_ax(a):
            dims = tuple(a)
            return Axes(*dims[:-1]) if len(dims) >= 2 else Axes(*dims)

        def vc_ax(a):
            dims = tuple(a)
            return Axes(*dims[:-2], dims[-1]) if len(dims) >= 2 else Axes(None)

        st = {
            "step": Axes(),
            "vr": _tree_map(vr_ax, param_axes),
            "vc": _tree_map(vc_ax, param_axes),
        }
        if master:
            st["master"] = param_axes
        return st

    return Optimizer("adafactor", init, update, axes)


# ---------------------------------------------------------------------------
# Lion
# ---------------------------------------------------------------------------


def lion(b1: float = 0.9, b2: float = 0.99, weight_decay: float = 0.1,
         clip: float = 1.0) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "master": _tree_map(lambda p: p.astype(F32), params),
            "m": _tree_map(lambda p: jnp.zeros(p.shape, F32), params),
        }

    def update(grads, state, params, lr):
        if clip:
            grads, _ = clip_by_global_norm(grads, clip)

        def upd(g, m, master):
            g = g.astype(F32)
            u = jnp.sign(b1 * m + (1 - b1) * g)
            m2 = b2 * m + (1 - b2) * g
            master2 = master - lr * (u + weight_decay * master)
            return m2, master2

        out = _tree_map(upd, grads, state["m"], state["master"])
        m = _tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        master = _tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = _tree_map(lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, {"step": state["step"] + 1, "master": master, "m": m}, {}

    def axes(param_axes):
        return {"step": Axes(), "master": param_axes, "m": param_axes}

    return Optimizer("lion", init, update, axes)


def get(name: str, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "lion": lion}[name](**kw)
