"""End-to-end training driver (CPU-scale runnable; pod-scale by mesh flag).

Wires every substrate together: platform config -> rules -> sharded train
step -> step-indexed data pipeline -> checkpoint/restart -> FT controller.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 20 --global-batch 8 --seq 128 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.ckpt import checkpoint
from repro.core.platform import Platform, XHeepConfig
from repro.data.lm import LMDataConfig, LMPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import registry
from repro.runtime.ft import FTController
from repro.sharding import params as P
from repro.train.trainer import TrainConfig, build_sharded_train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "lion"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    platform = Platform(XHeepConfig())
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    rules = platform.rules(mesh)
    tc = TrainConfig(optimizer=args.optimizer, lr=args.lr, accum=args.accum)

    st = build_sharded_train(cfg, tc, mesh, rules, args.global_batch, args.seq)

    data = LMPipeline(LMDataConfig(
        vocab=cfg.vocab, seq=args.seq, global_batch=args.global_batch,
        accum=args.accum, seed=args.seed,
        embed_dim=None if cfg.embed_inputs else cfg.d_model))

    # init or restore
    decls = registry.decls(cfg)
    start_step = 0
    if args.resume and args.ckpt and checkpoint.latest_step(args.ckpt) is not None:
        params_like = st.params_abstract
        opt_like = st.opt_abstract
        params, start_step, _ = checkpoint.restore(
            args.ckpt, params_like, shardings=st.params_shardings)
        opt_state, _, _ = checkpoint.restore(
            args.ckpt + "/opt", opt_like, shardings=st.opt_shardings)
        print(f"resumed from step {start_step}")
    else:
        key = jax.random.key(args.seed)
        params = P.cast_tree(P.init_tree(decls, key),
                             jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        from repro.train import optim as optim_lib

        opt_state = optim_lib.get(tc.optimizer).init(params)

    ft = FTController(n_workers=jax.process_count())
    pending_save = None
    loss = float("nan")
    with mesh:
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = data.batch_at(step)
            params, opt_state, metrics = st.step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            ft.report_heartbeat(jax.process_index())
            ft.report_step_time(jax.process_index(), dt)
            ft.tick()
            print(f"step {step:5d} loss {loss:.4f} ({dt:.2f}s)", flush=True)
            if args.ckpt and (step + 1) % args.ckpt_every == 0:
                if pending_save is not None:
                    pending_save.join()
                pending_save = checkpoint.save(
                    args.ckpt, params, step=step + 1, async_=True,
                    metadata={"arch": cfg.name})
                checkpoint.save(args.ckpt + "/opt", opt_state, step=step + 1)
    if pending_save is not None:
        pending_save.join()
    print("done; final loss", loss)
    return loss


if __name__ == "__main__":
    main()
