"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's host-device
override to land before first jax initialization.

All meshes are built through :mod:`repro.core.compat` so the module works on
jax versions with and without ``jax.sharding.AxisType``.
"""

from __future__ import annotations

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / small-scale runs."""
    return compat.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names, for CPU smoke runs."""
    n = len(jax.devices())
    shape = (1, n) if n == 1 else (n, 1)
    return compat.make_mesh(shape, ("data", "model"))
