"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's host-device
override to land before first jax initialization.

All meshes are built through :mod:`repro.core.compat` so the module works on
jax versions with and without ``jax.sharding.AxisType``.
"""

from __future__ import annotations

import jax

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / small-scale runs."""
    return compat.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names, for CPU smoke runs."""
    n = len(jax.devices())
    shape = (1, n) if n == 1 else (n, 1)
    return compat.make_mesh(shape, ("data", "model"))


def serve_tp_mesh(tp: int, devices=None):
    """A 1-D ``("model",)`` mesh of ``tp`` devices for the tensor-parallel
    paged decode (:mod:`repro.serve.paged`). ``devices`` selects the
    slice explicitly (replica pinning); default = the first ``tp`` of
    ``jax.devices()``."""
    devices = list(devices) if devices is not None else jax.devices()[:tp]
    if len(devices) < tp:
        raise ValueError(f"need {tp} devices for tp={tp}, have "
                         f"{len(devices)} (set "
                         "--xla_force_host_platform_device_count or run on "
                         "a larger host)")
    import numpy as np

    return compat.make_mesh((tp,), ("model",),
                            devices=np.asarray(devices[:tp]))


def replica_meshes(replicas: int, tp: int, devices=None):
    """Disjoint ``("model",)`` meshes for data-parallel replica serving:
    ``replicas`` slices of ``tp`` devices each, carved consecutively from
    ``devices`` (default ``jax.devices()``). Slice ``i`` gets devices
    ``[i*tp, (i+1)*tp)`` — disjoint by construction, which is what lets
    :meth:`~repro.serve.cluster.ServeCluster.add_replica_group` pin each
    replica's arena and params to its own devices."""
    devices = list(devices) if devices is not None else jax.devices()
    need = replicas * tp
    if len(devices) < need:
        raise ValueError(f"need {need} devices for {replicas} replicas at "
                         f"tp={tp}, have {len(devices)}")
    return [serve_tp_mesh(tp, devices[i * tp:(i + 1) * tp])
            for i in range(replicas)]
