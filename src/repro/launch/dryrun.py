"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The 512-host-device override lives in :func:`configure`, called by
:func:`main` before any jax device use — never at import time. (It used
to be a module-level ``os.environ`` write, which meant *importing* this
module for its constants — e.g. ``from repro.launch.dryrun import
RESULTS`` in benchmarks — silently clobbered the process's XLA flags.)
Tests and benchmarks see the real device set; only the dry-run CLI forces
512 hosts, and ``configure`` raises instead of silently no-opping when
jax has already locked its device count.

Usage:
  python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all          # every missing cell, in-process
  python -m repro.launch.dryrun --list         # show the cell matrix
Results land in results/dryrun/<arch>__<shape>__<mesh>.json (one file per
cell; reruns overwrite).
"""

import argparse
import os
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.analysis import hlo as hlo_lib
from repro.core.platform import Platform, XHeepConfig
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.serve.engine import build_sharded_serve
from repro.sharding import rules as R
from repro.train.trainer import TrainConfig, build_sharded_train

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def configure(devices: int) -> None:
    """Force ``devices`` host CPU devices for this process.

    Merges the override into any existing ``XLA_FLAGS`` (replacing a prior
    device-count flag, keeping everything else — the Makefile prepends an
    optimization-level flag that must survive). Must run before jax
    initializes its backends: the first device use locks the count, so if
    that already happened this raises instead of silently lowering every
    cell on the wrong mesh."""
    bridge = getattr(getattr(jax, "_src", None), "xla_bridge", None)
    if bridge is not None and getattr(bridge, "_backends", None):
        raise RuntimeError(
            f"jax already initialized its backends — the {devices}-host "
            "override must land before first device use (run the dry-run "
            "as its own process, not after other jax work)")
    flag = f"--xla_force_host_platform_device_count={devices}"
    kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    os.environ["XLA_FLAGS"] = " ".join(kept + [flag])

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq=524288, global_batch=1),
}

MESHES = {"single": dict(multi_pod=False, chips=256),
          "multi": dict(multi_pod=True, chips=512)}


def accum_for(cfg) -> int:
    # microbatch must stay divisible by the multi-pod batch axes (2*16=32)
    return 8  # global 256 -> microbatch 32


def optimizer_for(cfg) -> str:
    return "adafactor" if cfg.param_count() > 5e10 else "adamw"


def cell_enabled(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return False, "N/A: pure full attention (see DESIGN.md §Arch-applicability)"
    return True, ""


def build_platform(overrides: dict | None = None) -> Platform:
    return Platform(XHeepConfig(**(overrides or {})))


# --- §Perf hillclimb variants -------------------------------------------------
# Each variant: (cfg transform, platform kwargs, rule overrides, tc kwargs,
#                fsdp override). Lowered with --variant NAME; results are
# written under that tag and compared against `baseline` by benchmarks.roofline.
import dataclasses as _dc

VARIANTS: dict[str, dict] = {
    "baseline": {},
    # the paper-faithful minimal bus: pure DP, replicated weights
    "oat_bus": {"platform": {"bus": "one_at_a_time"}, "fsdp": False},
    # remat policy: keep matmul outputs, recompute elementwise only
    "remat_dots": {"cfg": lambda c: _dc.replace(c, remat="dots")},
    # pad vocab to a shardable multiple AND shard the (tied) embedding table's
    # vocab axis so head flops/bytes go tensor-parallel
    "vocab_pad": {"cfg": lambda c: _dc.replace(c, vocab_pad_multiple=2048),
                  "rules": {"vocab_in": ("model",)}},
    # force ZeRO-1 / full-FSDP regardless of the auto policy
    "zero1": {"fsdp": False},
    "fsdp": {"fsdp": True},
    # sequence parallelism on activations (interleaved addressing)
    "interleaved": {"platform": {"addressing": "interleaved"}},
    # accumulate more/fewer microbatches
    "accum16": {"accum": 16},
    "accum4": {"accum": 4},
    # MoE: bigger capacity (less dropping)
    "cap2x": {"cfg": lambda c: _dc.replace(c, moe_capacity_factor=2.5)},
    # SSD scan in bf16 (fp32 accumulation + state)
    "ssd_bf16": {"cfg": lambda c: _dc.replace(c, ssm_compute_dtype="bfloat16")},
    "mamba_combo": {"cfg": lambda c: _dc.replace(
        c, ssm_compute_dtype="bfloat16", vocab_pad_multiple=2048),
        "rules": {"vocab_in": ("model",)}},
    # expert parallelism on a reshaped single-pod mesh: 256 chips as
    # (data=32, model=8) so 8 experts shard over `model`; expert FFN d_ff
    # shards over `data` (no FSDP contraction over d_model -> no per-matmul
    # partial-sum all-reduce); embedding vocab FSDPs over data.
    "ep_mesh": {"mesh": (32, 8), "fsdp": False,
                "rules": {"expert": ("model",), "mlp": ("data",),
                          "embed": (), "vocab_in": ("data",)}},
    # combined winners (see EXPERIMENTS.md §Perf)
    "combo": {"cfg": lambda c: _dc.replace(c, remat="dots",
                                           vocab_pad_multiple=2048),
              "rules": {"vocab_in": ("model",)}},
    "combo_moe": {"mesh": (32, 8), "fsdp": False,
                  "cfg": lambda c: _dc.replace(c, remat="dots"),
                  "rules": {"expert": ("model",), "mlp": ("data",),
                            "embed": (), "vocab_in": ("data",)}},
    # G5: expert-parallel + capacity-dim data sharding; expert weights keep
    # d_model FSDP'd over data (fit), but the dispatch buffer's capacity dim
    # is constrained to `data` so FFN outputs stay small before reduction.
    "ep_cap": {"mesh": (32, 8), "fsdp": True,
               "rules": {"expert": ("model",), "mlp": (),
                         "vocab_in": ("data",)},
               "moe_dispatch_spec": ("model", "data", None)},
}


def run_cell(arch: str, shape_name: str, mesh_name: str,
             platform: Platform | None = None, tag: str = "baseline",
             rule_overrides: dict | None = None, verbose: bool = True,
             variant: str = "baseline") -> dict:
    cfg = configs.get(arch)
    spec = SHAPES[shape_name]
    var = VARIANTS[variant]
    if "cfg" in var:
        cfg = var["cfg"](cfg)
    if "platform" in var and platform is None:
        platform = build_platform(var["platform"])
    fsdp_override = var.get("fsdp")
    accum_override = var.get("accum")
    from jax.sharding import PartitionSpec as _PS

    from repro.models import layers as _layers

    _layers.set_moe_dispatch_spec(
        _PS(*var["moe_dispatch_spec"]) if "moe_dispatch_spec" in var else None)
    ok, why = cell_enabled(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": why}

    platform = platform or build_platform()
    if "mesh" in var and mesh_name == "single":
        from repro.core import compat
        mesh = compat.make_mesh(var["mesh"], ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=MESHES[mesh_name]["multi_pod"])
    chips = MESHES[mesh_name]["chips"]
    rules = platform.rules(mesh)
    overrides = dict(var.get("rules", {}))
    if rule_overrides:
        overrides.update(rule_overrides)
    if overrides:
        rules = rules.override(name=f"{rules.name}+{variant}", **overrides)

    from repro.analysis.jaxpr_cost import loop_correction

    t0 = time.time()
    if spec["kind"] == "train":
        accum = accum_override or accum_for(cfg)
        tc = TrainConfig(optimizer=optimizer_for(cfg), accum=accum,
                         accum_dtype="bfloat16" if cfg.param_count() > 1e11
                         else "float32")
        st = build_sharded_train(cfg, tc, mesh, rules,
                                 spec["global_batch"], spec["seq"],
                                 fsdp=fsdp_override)
        corr_args = (st.raw_fn, st.params_abstract, st.opt_abstract,
                     st.batch_abstract)
        with mesh:
            lowered = st.step_fn.lower(st.params_abstract, st.opt_abstract,
                                       st.batch_abstract)
    else:
        sv = build_sharded_serve(cfg, mesh, rules, spec["global_batch"],
                                 spec["seq"],
                                 prefill_len=spec["seq"] if spec["kind"] == "prefill"
                                 else None,
                                 fsdp=fsdp_override)
        with mesh:
            if spec["kind"] == "prefill":
                p_in = sv.prefill_fn._input_abstract
                corr_args = (sv.raw_prefill_fn, sv.params_abstract, p_in)
                lowered = sv.prefill_fn.lower(sv.params_abstract, p_in)
            else:
                tok = jax.ShapeDtypeStruct((spec["global_batch"], 1), jnp.int32)
                corr_args = (sv.raw_decode_fn, sv.params_abstract,
                             sv.cache_abstract, tok)
                lowered = sv.decode_fn.lower(sv.params_abstract, sv.cache_abstract,
                                             tok)
    t_lower = time.time() - t0

    # Loop-trip-count correction ratios (XLA counts while bodies once).
    with mesh:
        fr, br, full_cost = loop_correction(*corr_args)

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_stats = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_bytes_est": mem.argument_size_in_bytes + mem.output_size_in_bytes
        + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
    }
    xla_cost = dict(compiled.cost_analysis() or {})
    txt = compiled.as_text()
    cost = hlo_lib.analyze(txt, chips)
    model_flops = hlo_lib.model_flops_for(cfg, spec["kind"],
                                          spec["global_batch"], spec["seq"])
    roof = hlo_lib.make_roofline(arch, shape_name, mesh_name, chips,
                                 cost, model_flops, mem_stats)
    out = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "status": "ok", "kind": spec["kind"],
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_bytes_text": len(txt),
        "rules": rules.name,
        "xla_raw_flops": float(xla_cost.get("flops", 0.0)),
        "xla_raw_bytes": float(xla_cost.get("bytes accessed", 0.0)),
        "jaxpr_flops_global": full_cost.flops,
        "jaxpr_bytes_global": full_cost.bytes,
        **roof.to_dict(),
    }
    if verbose:
        gb = 1024 ** 3
        print(f"[{arch} × {shape_name} × {mesh_name}] ({tag})")
        print(f"  memory/device: args {mem_stats['argument_bytes']/gb:.2f} GiB, "
              f"temp {mem_stats['temp_bytes']/gb:.2f} GiB, "
              f"peak≈{mem_stats['peak_bytes_est']/gb:.2f} GiB "
              f"(HBM {hlo_lib.hw.TPU_V5E.hbm_bytes/gb:.0f} GiB)")
        print(f"  flops/device {roof.flops_per_device:.3e}, hbm bytes "
              f"{roof.hbm_bytes_per_device:.3e}, wire bytes {roof.wire_bytes_per_device:.3e}")
        print(f"  roofline terms (s): compute {roof.compute_s:.4f}, memory "
              f"{roof.memory_s:.4f}, collective {roof.collective_s:.4f} "
              f"-> dominant: {roof.dominant}")
        print(f"  collectives: {cost.collective_counts}")
        print(f"  MODEL_FLOPS/HLO_FLOPS {roof.useful_flops_ratio:.3f}, "
              f"roofline fraction {roof.roofline_fraction:.3f}")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
    return out


def cell_path(arch: str, shape: str, mesh: str, tag: str = "baseline") -> pathlib.Path:
    suffix = "" if tag == "baseline" else f"__{tag}"
    return RESULTS / f"{arch}__{shape}__{mesh}{suffix}.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=list(MESHES))
    ap.add_argument("--tag", default=None)
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    if args.tag is None:
        args.tag = args.variant

    cells = []
    for arch in configs.names():
        aid = configs.get(arch).name
        for shape in SHAPES:
            for mesh in MESHES:
                cells.append((aid, shape, mesh))

    if args.list:
        for c in cells:
            p = cell_path(*c)
            print(("done " if p.exists() else "todo "), *c)
        return 0

    if not args.all:
        assert args.arch, "--arch required unless --all/--list"
        cells = [(args.arch, args.shape or "train_4k", args.mesh)]

    # only lowering runs touch device state or create the artifact dir —
    # `--list` must stay side-effect-free so the artifact-gated tests keep
    # skipping (and so listing never demands an uninitialized jax)
    configure(512)
    RESULTS.mkdir(parents=True, exist_ok=True)
    failures = 0
    for arch, shape, mesh in cells:
        path = cell_path(arch, shape, mesh, args.tag)
        if path.exists() and not args.force:
            continue
        try:
            out = run_cell(arch, shape, mesh, tag=args.tag,
                           variant=args.variant)
        except Exception:  # record the failure, keep going
            traceback.print_exc()
            out = {"arch": arch, "shape": shape, "mesh": mesh, "tag": args.tag,
                   "status": "error", "error": traceback.format_exc(limit=20)}
            failures += 1
        path.write_text(json.dumps(out, indent=1))
        sys.stdout.flush()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
