"""Serving driver: batched prefill + decode with throughput/energy report.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke \
      --batch 4 --prompt-len 32 --steps 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import energy
from repro.core.platform import Platform, XHeepConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import registry
from repro.serve.engine import build_sharded_serve
from repro.sharding import params as P


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    platform = Platform(XHeepConfig())
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    rules = platform.rules(mesh)
    max_len = args.max_len or (args.prompt_len + args.steps)

    sv = build_sharded_serve(cfg, mesh, rules, args.batch, max_len,
                             prefill_len=args.prompt_len)
    key = jax.random.key(args.seed)
    params = P.cast_tree(P.init_tree(registry.decls(cfg), key), jnp.bfloat16)

    done = {"flag": False}

    def on_complete(_):
        done["flag"] = True   # XAIF-style completion interrupt

    with mesh:
        if cfg.embed_inputs:
            prompt = jax.random.randint(key, (args.batch, args.prompt_len),
                                        0, cfg.vocab)
            logits, cache = sv.prefill_fn(params, prompt)
        else:
            emb = jax.random.normal(
                key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)
            logits, cache = sv.prefill_fn(params, emb)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        t0 = time.time()
        outs = []
        for _ in range(args.steps):
            outs.append(tok)
            logits, cache = sv.decode_fn(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(logits)
        dt = time.time() - t0
        on_complete(outs)

    toks = args.batch * args.steps
    n = cfg.param_count()
    e_j = energy.tpu_step_energy_j(flops=2 * n * toks, hbm_bytes=2 * n * 2,
                                   step_s=dt, chips=len(jax.devices()))
    print(f"decoded {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s); est energy {e_j:.1f} J "
          f"({e_j / max(toks, 1) * 1000:.1f} mJ/token)")
    assert done["flag"], "completion interrupt not fired"
    return toks / dt


if __name__ == "__main__":
    main()
