"""Flash attention Pallas TPU kernel (causal, GQA, optional sliding window).

TPU mapping of the FlashAttention insight: online softmax over KV tiles with
the running (m, l, acc) state carried in VMEM scratch across the innermost
(sequential) grid dimension; Q/K/V tiles are streamed HBM->VMEM by BlockSpecs.
MXU alignment: the ops wrapper pads head_dim to a multiple of 128 and the
sequence to tile multiples; tile edges default to 128 (8-sublane aligned).

Layout contract (head-major): q (BH, Sq, D), k/v (BKV, Sk, D), BH = BKV*groups.
Grid = (BH, n_q, n_k); n_k is the innermost, sequential dimension.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int | None,
                 q_block: int, kv_block: int, sq: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (qpos < sq) & (kpos < sk)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_hm(q, k, v, *, groups: int, causal: bool = True,
                       window: int | None = None, sq: int | None = None,
                       sk: int | None = None, q_block: int = 128,
                       kv_block: int = 128, interpret: bool = True):
    """Head-major flash attention (see module docstring for layout)."""
    bh, sq_pad, d = q.shape
    bkv, sk_pad, _ = k.shape
    assert bh == bkv * groups, (bh, bkv, groups)
    sq = sq if sq is not None else sq_pad
    sk = sk if sk is not None else sk_pad
    q_block = min(q_block, sq_pad)
    kv_block = min(kv_block, sk_pad)
    assert sq_pad % q_block == 0 and sk_pad % kv_block == 0
    n_q, n_k = sq_pad // q_block, sk_pad // kv_block
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        q_block=q_block, kv_block=kv_block, sq=sq, sk=sk)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, kv_block, d),
                         lambda b, qi, ki, g=groups: (b // g, ki, 0)),
            pl.BlockSpec((1, kv_block, d),
                         lambda b, qi, ki, g=groups: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
