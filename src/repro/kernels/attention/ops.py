"""jit wrapper + XAIF registration for the flash attention kernel.

The XAIF contract mirrors the paper's CGRA plug-in: 3 master read ports
(Q, K, V tiles streamed from HBM), 1 master write port (O tiles), slave
ports = the static shape/window configuration; its power domain joins the
platform power manager when attached.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.power import PowerDomain
from repro.core.xaif import AcceleratorSpec, PortSpec, register
from repro.kernels.attention.kernel import flash_attention_hm
from repro.sharding import axes as lx
from repro.sharding.params import Axes


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    q_offset: int = 0, kv_len=None, q_block: int = 128,
                    kv_block: int = 128, interpret: bool = True):
    """Batch-seq-major entry: q (B,S,H,D); k/v (B,S,K,D). GQA handled by the
    kernel's block index mapping (no KV materialization)."""
    if kv_len is not None:
        raise NotImplementedError(
            "dynamic kv_len is served by the chunked backend; the Pallas "
            "kernel covers the static train/prefill shapes")
    b, sq, h, d = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    groups = h // nkv
    # head-major + pad: D to 128 (MXU), S to tile multiples
    qh = _pad_to(_pad_to(q.transpose(0, 2, 1, 3).reshape(b * h, sq, d), 2, 128),
                 1, q_block)
    kh = _pad_to(_pad_to(k.transpose(0, 2, 1, 3).reshape(b * nkv, sk, d), 2, 128),
                 1, kv_block)
    vh = _pad_to(_pad_to(v.transpose(0, 2, 1, 3).reshape(b * nkv, sk, d), 2, 128),
                 1, kv_block)
    # scale uses the padded D inside the kernel; compensate so logits match
    d_pad = qh.shape[-1]
    qh = qh * jnp.asarray(d_pad ** 0.5 / d ** 0.5, qh.dtype)
    out = flash_attention_hm(qh, kh, vh, groups=groups, causal=causal,
                             window=window, sq=sq, sk=sk, q_block=q_block,
                             kv_block=kv_block, interpret=interpret)
    out = out[:, :sq, :d].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    return out


SPEC = AcceleratorSpec(
    name="flash_attention_pallas",
    op="attention",
    impl="pallas",
    fn=flash_attention,
    slave_ports=(
        PortSpec("attn_config", Axes(), direction="slave", dtype="int32"),
    ),
    master_ports=(
        PortSpec("q", Axes(lx.BATCH, lx.SEQ, lx.HEADS, lx.HEAD_DIM)),
        PortSpec("k", Axes(lx.BATCH, lx.SEQ, lx.KV_HEADS, lx.HEAD_DIM)),
        PortSpec("v", Axes(lx.BATCH, lx.SEQ, lx.KV_HEADS, lx.HEAD_DIM)),
        PortSpec("o", Axes(lx.BATCH, lx.SEQ, lx.HEADS, lx.HEAD_DIM)),
    ),
    power_domain=PowerDomain("acc_attention", leak_uw=12.0,
                             active_dyn_uw_mhz=48.0),
    description="FlashAttention TPU kernel: online softmax over VMEM KV tiles",
)
register(SPEC, allow_override=True)
