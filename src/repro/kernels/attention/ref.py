"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax

from repro.models.layers import attention_ref


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              q_offset: int = 0, kv_len=None) -> jax.Array:
    """q: (B,S,H,D); k/v: (B,S,K,D). Naive full-score softmax attention."""
    return attention_ref(q, k, v, causal=causal, window=window,
                         q_offset=q_offset, kv_len=kv_len)
