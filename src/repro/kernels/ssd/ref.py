"""Pure-jnp oracle for the SSD kernel: the sequential recurrence."""

from __future__ import annotations

from repro.models.mamba2 import ssd_ref


def ssd(x, dA, B, C, *, init_state=None, chunk: int = 0):
    """x (b,s,h,p) pre-scaled by dt; dA (b,s,h); B/C (b,s,h,n).
    Returns (y, final_state). Sequential scan over time."""
    return ssd_ref(x, dA, B, C, init_state=init_state, chunk=chunk)
