"""jit wrapper + XAIF registration for the SSD chunk-scan kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.power import PowerDomain
from repro.core.xaif import AcceleratorSpec, PortSpec, register
from repro.kernels.ssd.kernel import ssd_hm
from repro.sharding import axes as lx
from repro.sharding.params import Axes


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dA, B, C, *, chunk: int, init_state=None, interpret: bool = True):
    """Model-layout entry: x (b,s,h,p), dA (b,s,h), B/C (b,s,h,n) ->
    (y (b,s,h,p), state (b,h,p,n))."""
    if init_state is not None:
        raise NotImplementedError("init_state continuation uses the chunked backend")
    b, s, h, p = x.shape
    n = B.shape[-1]

    def hm(a, feat):
        return a.transpose(0, 2, 1, 3).reshape(b * h, s, feat)

    y, state = ssd_hm(hm(x, p), dA.transpose(0, 2, 1).reshape(b * h, s, 1),
                      hm(B, n), hm(C, n), chunk=min(chunk, s),
                      interpret=interpret)
    y = y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    return y, state.reshape(b, h, p, n)


SPEC = AcceleratorSpec(
    name="ssd_chunk_scan_pallas",
    op="ssd",
    impl="pallas",
    fn=ssd,
    slave_ports=(PortSpec("chunk_config", Axes(), direction="slave",
                          dtype="int32"),),
    master_ports=(
        PortSpec("x", Axes(lx.BATCH, lx.SEQ, lx.HEADS, lx.HEAD_DIM)),
        PortSpec("dA", Axes(lx.BATCH, lx.SEQ, lx.HEADS)),
        PortSpec("B", Axes(lx.BATCH, lx.SEQ, lx.HEADS, lx.STATE)),
        PortSpec("C", Axes(lx.BATCH, lx.SEQ, lx.HEADS, lx.STATE)),
        PortSpec("y", Axes(lx.BATCH, lx.SEQ, lx.HEADS, lx.HEAD_DIM)),
    ),
    power_domain=PowerDomain("acc_ssd", leak_uw=9.0, active_dyn_uw_mhz=40.0),
    description="SSD chunk scan: MXU intra-chunk, VMEM-resident state",
)
register(SPEC, allow_override=True)
