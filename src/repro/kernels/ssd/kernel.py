"""Mamba-2 SSD chunk-scan Pallas TPU kernel.

TPU adaptation of the SSD insight (state-space duality): the sequence is
processed in chunks; within a chunk everything is dense matmul work for the
MXU (intra-chunk scores through a decay mask), and the O(state) recurrence
only crosses chunk boundaries — carried here in VMEM scratch across the
innermost sequential grid dimension, so the state never round-trips to HBM.

Layout contract (head-major): x (BH, S, P), dA (BH, S, 1), B/C (BH, S, N);
outputs y (BH, S, P) and final state (BH, P, N). Grid = (BH, n_chunks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, st_out_ref, state_scr, *,
                chunk: int):
    ci = pl.program_id(1)
    n_c = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)      # (Q, P)
    da = da_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    b = b_ref[0].astype(jnp.float32)      # (Q, N)
    c = c_ref[0].astype(jnp.float32)      # (Q, N)

    a_cs = jnp.cumsum(da)                 # (Q,)
    # intra-chunk: scores[i,j] = (C_i · B_j) * exp(A_cs[i]-A_cs[j]) for j<=i
    seg = a_cs[:, None] - a_cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(jj <= ii, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * decay
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the incoming state
    state = state_scr[...]                # (P, N)
    y += jax.lax.dot_general(c, state, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(a_cs)[:, None]

    # state update: state' = state*exp(A_total) + X^T (B * decay_to_end)
    decay_states = jnp.exp(a_cs[-1] - a_cs)[:, None] * b   # (Q, N)
    state_scr[...] = state * jnp.exp(a_cs[-1]) + jax.lax.dot_general(
        x, decay_states, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_c - 1)
    def _emit_state():
        st_out_ref[0] = state_scr[...].astype(st_out_ref.dtype)


def ssd_hm(x, da, b, c, *, chunk: int, interpret: bool = True):
    """Head-major SSD scan. Returns (y (BH,S,P), state (BH,P,N))."""
    bh, s, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    n_c = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, p, n), lambda i, ci: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, da, b, c)
