"""RG-LRU linear-recurrence Pallas TPU kernel (RecurrentGemma).

The recurrence h_t = a_t * h_{t-1} + b_t is sequential in time but fully
parallel across the width lanes — the natural TPU mapping is: width on the
128-lane vector axis, time as a fori_loop inside a block, and the running
state h in VMEM scratch carried across the innermost (sequential) sequence
grid dimension. No matmuls: this is a VPU kernel.

Layout contract: a, b (B, S, W); grid = (B, n_w, n_s), n_s sequential.
Outputs: y (B, S, W) and final state (B, W).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, y_ref, h_out_ref, h_scr, *, s_block: int):
    si = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, s_block, step, h_scr[0])
    h_scr[0] = h

    @pl.when(si == n_s - 1)
    def _emit():
        h_out_ref[0] = h_scr[0].astype(h_out_ref.dtype)


def rglru_scan(a, b, *, s_block: int = 128, w_block: int = 128,
               interpret: bool = True):
    """a, b: (B, S, W). Returns (y (B,S,W) fp32-accurate, h_final (B,W))."""
    bsz, s, w = a.shape
    s_block = min(s_block, s)
    w_block = min(w_block, w)
    assert s % s_block == 0 and w % w_block == 0
    n_s, n_w = s // s_block, w // w_block

    kernel = functools.partial(_rglru_kernel, s_block=s_block)
    return pl.pallas_call(
        kernel,
        grid=(bsz, n_w, n_s),
        in_specs=[
            pl.BlockSpec((1, s_block, w_block), lambda i, wi, si: (i, si, wi)),
            pl.BlockSpec((1, s_block, w_block), lambda i, wi, si: (i, si, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, s_block, w_block), lambda i, wi, si: (i, si, wi)),
            pl.BlockSpec((1, w_block), lambda i, wi, si: (i, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, w_block), jnp.float32)],
        interpret=interpret,
    )(a, b)
