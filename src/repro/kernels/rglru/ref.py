"""Pure-jnp oracle for the RG-LRU kernel: sequential linear scan."""

from __future__ import annotations

from repro.models.griffin import linear_scan_ref


def rglru(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t; a/b (B,S,W). Returns (ys, h_final)."""
    return linear_scan_ref(a, b, h0)
