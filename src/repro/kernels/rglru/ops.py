"""jit wrapper + XAIF registration for the RG-LRU scan kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.power import PowerDomain
from repro.core.xaif import AcceleratorSpec, PortSpec, register
from repro.kernels.rglru.kernel import rglru_scan
from repro.sharding import axes as lx
from repro.sharding.params import Axes


@functools.partial(jax.jit, static_argnames=("interpret",))
def rglru(a, b, h0=None, *, interpret: bool = True):
    """a, b: (B,S,W) -> (ys (B,S,W), h_final (B,W))."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(b.dtype))
    return rglru_scan(a.astype(jnp.float32), b.astype(jnp.float32),
                      interpret=interpret)


SPEC = AcceleratorSpec(
    name="rglru_scan_pallas",
    op="rglru",
    impl="pallas",
    fn=rglru,
    master_ports=(
        PortSpec("a", Axes(lx.BATCH, lx.SEQ, lx.RNN_WIDTH)),
        PortSpec("b", Axes(lx.BATCH, lx.SEQ, lx.RNN_WIDTH)),
        PortSpec("y", Axes(lx.BATCH, lx.SEQ, lx.RNN_WIDTH)),
    ),
    power_domain=PowerDomain("acc_rglru", leak_uw=6.0, active_dyn_uw_mhz=22.0),
    description="RG-LRU linear scan: width on vector lanes, VMEM state",
)
register(SPEC, allow_override=True)
