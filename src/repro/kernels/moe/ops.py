"""jit wrapper + XAIF registration for the MoE grouped-matmul kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.power import PowerDomain
from repro.core.xaif import AcceleratorSpec, PortSpec, register
from repro.kernels.moe.kernel import grouped_matmul
from repro.sharding import axes as lx
from repro.sharding.params import Axes


def _blocks_for(c, d, f):
    def pick(n, pref):
        for b in (pref, 128, 64, 32, 16, 8, 4, 2, 1):
            if b <= n and n % b == 0:
                return b
        return 1

    return dict(c_block=pick(c, 128), f_block=pick(f, 128), d_block=pick(d, 256))


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def moe_ffn(xg, p, kind: str = "swiglu", *, interpret: bool = True):
    """xg: (E, C, D); p: expert weights {w_gate, w_up, w_down} (E,...)."""
    e, c, d = xg.shape
    f = p["w_gate"].shape[-1]
    kw = dict(_blocks_for(c, d, f), interpret=interpret)
    gate = grouped_matmul(xg, p["w_gate"].astype(xg.dtype), **kw)
    up = grouped_matmul(xg, p["w_up"].astype(xg.dtype), **kw)
    act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
    kw2 = dict(_blocks_for(c, f, d), interpret=interpret)
    return grouped_matmul((act * up).astype(xg.dtype),
                          p["w_down"].astype(xg.dtype), **kw2)


SPEC = AcceleratorSpec(
    name="moe_grouped_matmul_pallas",
    op="moe_ffn",
    impl="pallas",
    fn=moe_ffn,
    slave_ports=(PortSpec("routing_config", Axes(), direction="slave",
                          dtype="int32"),),
    master_ports=(
        PortSpec("tokens_in", Axes(lx.EXPERT, None, lx.EMBED)),
        PortSpec("w_gate", Axes(lx.EXPERT, lx.EMBED, lx.MLP)),
        PortSpec("w_up", Axes(lx.EXPERT, lx.EMBED, lx.MLP)),
        PortSpec("w_down", Axes(lx.EXPERT, lx.MLP, lx.EMBED)),
        PortSpec("tokens_out", Axes(lx.EXPERT, None, lx.EMBED)),
    ),
    power_domain=PowerDomain("acc_moe", leak_uw=14.0, active_dyn_uw_mhz=52.0),
    description="Expert-grid MXU matmul; unrouted experts stay power-gated",
)
register(SPEC, allow_override=True)
