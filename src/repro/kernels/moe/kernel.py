"""Grouped (per-expert) matmul Pallas TPU kernel for MoE FFNs.

After capacity-grouped dispatch the MoE FFN is a batch of E independent
(C × D) @ (D × F) matmuls. The kernel tiles each expert's matmul for the MXU
with a VMEM fp32 accumulator across the innermost (sequential) K dimension —
the standard TPU matmul pattern with an expert grid axis in front, which is
what makes expert-parallel sharding compose: the expert axis is embarrassingly
parallel and shards over the `model` mesh axis via XAIF's port contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    di = pl.program_id(3)
    n_d = pl.num_programs(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == n_d - 1)
    def _emit():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def grouped_matmul(x, w, *, c_block: int = 128, f_block: int = 128,
                   d_block: int = 256, interpret: bool = True):
    """x: (E, C, D), w: (E, D, F) -> (E, C, F)."""
    e, c, d = x.shape
    f = w.shape[-1]
    c_block = min(c_block, c)
    f_block = min(f_block, f)
    d_block = min(d_block, d)
    assert c % c_block == 0 and f % f_block == 0 and d % d_block == 0
    grid = (e, c // c_block, f // f_block, d // d_block)

    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c_block, d_block), lambda ei, ci, fi, di: (ei, ci, di)),
            pl.BlockSpec((1, d_block, f_block), lambda ei, ci, fi, di: (ei, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, c_block, f_block),
                               lambda ei, ci, fi, di: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((c_block, f_block), jnp.float32)],
        interpret=interpret,
    )(x, w)
