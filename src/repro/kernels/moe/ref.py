"""Pure-jnp oracles for the grouped-matmul / MoE FFN kernels."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import _expert_ffn


def grouped_matmul(x, w):
    """x: (E,C,D) @ w: (E,D,F) -> (E,C,F)."""
    return jnp.einsum("ecd,edf->ecf", x, w)


def moe_ffn(xg, p, kind: str = "swiglu"):
    """Per-expert gated FFN on capacity-grouped tokens."""
    return _expert_ffn(xg, p, kind)
