"""Pallas TPU kernels, registered as XAIF accelerators on import."""

from repro.kernels.attention import ops as attention_ops
from repro.kernels.conv1d import ops as conv1d_ops
from repro.kernels.moe import ops as moe_ops
from repro.kernels.paged_attention import ops as paged_attention_ops
from repro.kernels.rglru import ops as rglru_ops
from repro.kernels.ssd import ops as ssd_ops

__all__ = ["attention_ops", "conv1d_ops", "moe_ops", "paged_attention_ops",
           "rglru_ops", "ssd_ops"]
