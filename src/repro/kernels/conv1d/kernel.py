"""Depthwise causal conv1d Pallas TPU kernel — the framework's "CGRA".

This is the accelerator of the paper's healthcare integration example
(HEEPocrates runs its seizure-CNN convolutions on a 4-PE CGRA for a 4.9×
energy win). The TPU adaptation: channels ride the 128-lane vector axis
(≙ the CGRA's parallel PEs), taps are unrolled (≙ the CGRA context-memory
program), and the causal halo is stitched from the PREVIOUS sequence block
via a second BlockSpec view — no gather, no HBM round-trip for the overlap.

Layout: x (B, S, D), w (W, D), depthwise: y[t,d] = Σ_i w[i,d]·x[t-W+1+i,d].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, xprev_ref, w_ref, o_ref, *, width: int, s_block: int):
    si = pl.program_id(1)
    x = x_ref[0].astype(jnp.float32)          # (bs, bd)
    prev = xprev_ref[0].astype(jnp.float32)   # (bs, bd) — previous block
    halo = prev[-(width - 1):]                # (W-1, bd)
    halo = jnp.where(si == 0, jnp.zeros_like(halo), halo)  # causal start
    xcat = jnp.concatenate([halo, x], axis=0)  # (bs+W-1, bd)
    w = w_ref[...].astype(jnp.float32)        # (W, bd)
    acc = jnp.zeros((s_block, x.shape[1]), jnp.float32)
    for i in range(width):                    # taps unrolled (CGRA program)
        acc += xcat[i:i + s_block] * w[i]
    o_ref[0] = acc.astype(o_ref.dtype)


def conv1d_causal(x, w, *, s_block: int = 256, d_block: int = 128,
                  interpret: bool = True):
    """x: (B, S, D), w: (W, D) -> (B, S, D)."""
    b, s, d = x.shape
    width = w.shape[0]
    s_block = min(s_block, s)
    d_block = min(d_block, d)
    assert s % s_block == 0 and d % d_block == 0
    assert s_block >= width - 1, "block must cover the halo"
    grid = (b, s // s_block, d // d_block)

    kernel = functools.partial(_conv_kernel, width=width, s_block=s_block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s_block, d_block), lambda i, si, di: (i, si, di)),
            # previous block view for the halo (clamped at the left edge)
            pl.BlockSpec((1, s_block, d_block),
                         lambda i, si, di: (i, jnp.maximum(si - 1, 0), di)),
            pl.BlockSpec((width, d_block), lambda i, si, di: (0, di)),
        ],
        out_specs=pl.BlockSpec((1, s_block, d_block),
                               lambda i, si, di: (i, si, di)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        interpret=interpret,
    )(x, x, w)
