"""Pure-jnp oracle for the causal depthwise conv1d kernel."""

from __future__ import annotations

from repro.models.layers import causal_conv1d


def conv1d(x, w, state=None):
    """x (B,S,D), w (W,D). Returns y only (oracle for the kernel)."""
    y, _ = causal_conv1d(x, w, state)
    return y
