"""jit wrapper + XAIF registration for the conv1d "CGRA" accelerator.

Port structure intentionally mirrors the paper's CGRA (§IV-A2): two slave
ports (configuration registers + context memory = the tap weights) and four
master ports (the 4 PEs' independent HBM streams ≙ 4×32 bit OBI masters,
128 bit/cycle); one interrupt line (completion callback); one power-control
port (the `cgra` power domain registered with the platform power manager).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.power import PowerDomain
from repro.core.xaif import AcceleratorSpec, PortSpec, register
from repro.kernels.conv1d.kernel import conv1d_causal
from repro.sharding import axes as lx
from repro.sharding.params import Axes


def _pick_block(n, pref):
    for bbb in (pref, 128, 64, 32, 16, 8, 4, 2, 1):
        if bbb <= n and n % bbb == 0:
            return bbb
    return 1


@functools.partial(jax.jit, static_argnames=("interpret",))
def conv1d(x, w, *, interpret: bool = True):
    """x: (B,S,D), w: (W,D) -> (B,S,D) causal depthwise conv."""
    b, s, d = x.shape
    sb = _pick_block(s, 256)
    if sb < w.shape[0] - 1:
        sb = s  # tiny sequences: single block
    return conv1d_causal(x, w, s_block=sb, d_block=_pick_block(d, 128),
                         interpret=interpret)


SPEC = AcceleratorSpec(
    name="cgra_conv1d_pallas",
    op="conv1d",
    impl="pallas",
    fn=conv1d,
    slave_ports=(
        PortSpec("config_regs", Axes(), direction="slave", dtype="int32"),
        PortSpec("context_memory", Axes(lx.CONV, lx.RNN_WIDTH), direction="slave"),
    ),
    master_ports=(
        PortSpec("pe0_stream", Axes(lx.BATCH, lx.SEQ, lx.RNN_WIDTH)),
        PortSpec("pe1_stream", Axes(lx.BATCH, lx.SEQ, lx.RNN_WIDTH)),
        PortSpec("pe2_stream", Axes(lx.BATCH, lx.SEQ, lx.RNN_WIDTH)),
        PortSpec("pe3_stream", Axes(lx.BATCH, lx.SEQ, lx.RNN_WIDTH)),
    ),
    power_domain=PowerDomain("cgra", leak_uw=15.0, active_dyn_uw_mhz=54.63,
                             retainable=False),
    description="CGRA-analogue depthwise conv: taps unrolled, lanes as PEs",
)
register(SPEC, allow_override=True)
