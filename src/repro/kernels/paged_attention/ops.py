"""Dispatch + XAIF registration for the paged decode attention kernel.

``paged_decode_append`` is the engine-facing fused op: scatter the step's
new K/V entry into each slot's tail page (an in-place update on the donated
pool buffers), then run single-query attention *directly against the page
pool* through the block table. Two backends:

* ``impl="ref"`` — the pure-jax oracle (``ref.py``). Its gather + masked
  attention is arranged to be bit-identical to the PR 2 lane-cache decode,
  so it is also the engine's default: paged serving changes memory layout,
  never tokens.
* ``impl="pallas"`` — the fused TPU kernel (``kernel.py``): block-table
  scalar prefetch, one pool page streamed per grid step, online softmax in
  VMEM scratch. On a real TPU the append scatter fuses into the same
  program via ``input_output_aliases``; in this CPU repro the scatter is an
  XLA in-place update on the donated pool and the kernel runs in interpret
  mode.

The XAIF contract mirrors the paper's CGRA plug-in: master read ports for
the query and the two pool planes plus the block table, one master write
port for O, slave ports = the static page-size/window configuration.
"""

from __future__ import annotations

from repro.core.power import PowerDomain
from repro.core.xaif import AcceleratorSpec, PortSpec, register
from repro.kernels.paged_attention import ref
from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.sharding import axes as lx
from repro.sharding.params import Axes


def paged_attention(q, k_pool, v_pool, tables, lengths, *,
                    window: int | None = None, impl: str = "ref",
                    interpret: bool = True):
    """Single-query paged attention over a (P, ps, K, D) page pool.

    q (B, H, D); tables (B, NP) int32 page ids; lengths (B,) valid counts.
    With ``window`` the tables carry **ring** semantics — entry ``e`` holds
    the newest block ``b ≡ e (mod NP)`` — and only the last ``window``
    positions attend; see the ``kernel.py``/``ref.py`` module docstrings.
    """
    if impl == "pallas":
        return paged_attention_kernel(q, k_pool, v_pool, tables, lengths,
                                      window=window, interpret=interpret)
    if impl == "ref":
        return ref.paged_attention(q, k_pool, v_pool, tables, lengths,
                                   window=window)
    raise ValueError(f"unknown paged_attention impl {impl!r}")


def paged_decode_append(q, k_new, v_new, k_pool, v_pool, tables, lengths, *,
                        append_mask=None, window: int | None = None,
                        impl: str = "ref", interpret: bool = True):
    """Fused decode step: append the new KV entry, attend over it in place.

    Appends ``k_new[b]``/``v_new[b]`` at position ``lengths[b]`` of slot
    ``b``'s page chain (``append_mask`` False drops the append — the lane is
    riding the batch idle and its output is ignored), then attends over
    ``lengths[b] + 1`` positions. With ``window`` the block tables are ring
    tables (the tail entry wraps modulo the table width) and attention
    covers only the last ``window`` positions — bit-identical to the lane
    backend's ring cache. Returns ``(o, k_pool', v_pool')`` — pass donated
    pools so XLA updates them in place.
    """
    if impl == "ref":
        return ref.paged_decode_append(q, k_new, v_new, k_pool, v_pool,
                                       tables, lengths,
                                       append_mask=append_mask, window=window)
    k_pool, v_pool = ref.append_to_tail_pages(k_new, v_new, k_pool, v_pool,
                                              tables, lengths, append_mask)
    o = paged_attention(q, k_pool, v_pool, tables, lengths + 1,
                        window=window, impl=impl, interpret=interpret)
    return o, k_pool, v_pool


SPEC = AcceleratorSpec(
    name="paged_attention_pallas",
    op="paged_attention",
    impl="pallas",
    fn=paged_attention_kernel,
    slave_ports=(
        PortSpec("paged_config", Axes(), direction="slave", dtype="int32"),
    ),
    master_ports=(
        PortSpec("q", Axes(lx.DECODE_BATCH, lx.HEADS, lx.HEAD_DIM)),
        PortSpec("k_pool", Axes(None, lx.CACHE_SEQ, lx.KV_HEADS, lx.HEAD_DIM)),
        PortSpec("v_pool", Axes(None, lx.CACHE_SEQ, lx.KV_HEADS, lx.HEAD_DIM)),
        PortSpec("block_table", Axes(lx.DECODE_BATCH, None), dtype="int32"),
        PortSpec("o", Axes(lx.DECODE_BATCH, lx.HEADS, lx.HEAD_DIM)),
    ),
    power_domain=PowerDomain("acc_paged_attention", leak_uw=10.0,
                             active_dyn_uw_mhz=42.0),
    description=("Paged decode attention: block-table scalar prefetch, one "
                 "pool page per grid step, online softmax in VMEM scratch"),
)
register(SPEC, allow_override=True)
