"""Pure-jax oracle for the paged decode attention kernel.

Semantics (shared with the Pallas kernel in ``kernel.py``): each slot ``b``
holds one single-token query and a *block table* — a row of page ids into a
global KV page pool. The op gathers the slot's pages, masks positions at or
beyond ``lengths[b]``, and computes grouped-query attention. The reference
deliberately reconstructs the slot's KV exactly as the lane-cache engine
lays it out (page ``j`` occupies positions ``[j*ps, (j+1)*ps)``) and then
runs the very same :func:`repro.models.layers.attention_chunked` the lane
decode path uses — so the paged engine's decode is *bit-identical* to the
PR 2 per-lane cache, not merely allclose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention_chunked, attention_ref


def paged_attention(q, k_pool, v_pool, tables, lengths, *,
                    window: int | None = None) -> jax.Array:
    """Single-query paged attention, pure-jax reference.

    q: (B, H, D) — one post-rope query per slot.
    k_pool/v_pool: (P, ps, K, D) — the global page pool (one layer).
    tables: (B, NP) int32 — page ids per slot; unused entries must point at
        pages whose positions fall at or beyond ``lengths[b]`` (they are
        masked, so their contents are never observable).
    lengths: (B,) int32 — valid KV entries per slot; attention covers
        positions ``[0, lengths[b])``.
    window: optional sliding window — only the last ``window`` positions
        attend (the query sits at position ``lengths[b] - 1``). The
        windowed path goes through the naive oracle (the per-slot query
        offset is data-dependent, which the chunked custom-vjp backend
        cannot take); the global path reuses ``attention_chunked`` so it is
        bit-identical to the lane-cache decode.
    """
    _, ps, kh, d = k_pool.shape

    def one(qb, tb, lb):
        kg = k_pool[tb].reshape(1, -1, kh, d)
        vg = v_pool[tb].reshape(1, -1, kh, d)
        if window is not None:
            return attention_ref(qb[None, None], kg, vg, causal=False,
                                 window=window, q_offset=lb - 1,
                                 kv_len=lb)[0, 0]
        return attention_chunked(qb[None, None], kg, vg, causal=False,
                                 kv_len=lb)[0, 0]

    return jax.vmap(one)(q, tables, lengths)


def append_to_tail_pages(k_new, v_new, k_pool, v_pool, tables, lengths,
                         append_mask=None):
    """Scatter each slot's new KV entry into its tail page, in place.

    The entry lands at page ``tables[b, lengths[b] // ps]``, row
    ``lengths[b] % ps``. ``append_mask`` (B,) bool drops masked lanes'
    writes by pointing them at the out-of-range page index (``mode="drop"``
    — the pool is untouched bitwise). Shared by the ref and pallas
    dispatch paths so the append semantics cannot diverge between them.
    """
    n_pages, ps = k_pool.shape[0], k_pool.shape[1]
    b = k_new.shape[0]
    page = tables[jnp.arange(b), lengths // ps]
    off = lengths % ps
    if append_mask is not None:
        page = jnp.where(append_mask, page, n_pages)
    k_pool = k_pool.at[page, off].set(k_new.astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[page, off].set(v_new.astype(v_pool.dtype), mode="drop")
    return k_pool, v_pool


def paged_decode_append(q, k_new, v_new, k_pool, v_pool, tables, lengths, *,
                        append_mask=None, window: int | None = None):
    """Reference for the fused decode step: append, then attend.

    Writes ``k_new[b]``/``v_new[b]`` into slot ``b``'s tail page at position
    ``lengths[b]``, then attends over ``lengths[b] + 1`` entries. Masked
    lanes append nothing and their output is garbage (must be ignored).
    Returns ``(o, k_pool', v_pool')``.
    """
    k_pool, v_pool = append_to_tail_pages(k_new, v_new, k_pool, v_pool,
                                          tables, lengths, append_mask)
    o = paged_attention(q, k_pool, v_pool, tables, lengths + 1, window=window)
    return o, k_pool, v_pool
