"""Pure-jax oracle for the paged decode attention kernel.

Semantics (shared with the Pallas kernel in ``kernel.py``): each slot ``b``
holds one single-token query and a *block table* — a row of page ids into a
global KV page pool. The op gathers the slot's pages, masks positions at or
beyond ``lengths[b]``, and computes grouped-query attention. The reference
deliberately reconstructs the slot's KV exactly as the lane-cache engine
lays it out and then runs the very same
:func:`repro.models.layers.attention_chunked` the lane decode path uses —
so the paged engine's decode is *bit-identical* to the PR 2 per-lane
cache, not merely allclose. That holds for both table layouts:

* **Contiguous** (``window=None``): page ``j`` of the table covers
  positions ``[j*ps, (j+1)*ps)`` — the gather reproduces the lane's
  linear cache buffer.
* **Ring** (``window=W``): the table is a *ring block table* with ``R``
  entries; entry ``e`` holds the page of the **newest** block ``b`` with
  ``b ≡ e (mod R)`` and ``b <= (n-1)//ps`` (older same-entry blocks have
  been recycled — their positions fall wholly outside the window). The
  gather reconstructs the lane backend's **ring buffer**: a ``W``-position
  buffer where position ``p`` sits at index ``p % W``, attended over
  ``kv_len = min(n, W)`` — byte-for-byte the layout
  ``transformer._attn_decode`` keeps for sliding-window configs, so
  windowed paged decode is bit-identical to the lane ring cache. A table
  with ``R >= ceil(W/ps) + 1`` entries always covers the window
  (``(R-1)*ps >= W``), which is why a long-running sliding-window slot
  holds O(window) pages instead of O(seq). A full-width contiguous table
  is the degenerate ring (no entry is ever reused), so callers with
  un-recycled tables can pass ``window`` unchanged.

Tensor parallelism: every head count here is read off the operand shapes
(``H`` from q, ``K`` from the pool; GQA groups = H // K), never from a
config — so the same code runs unchanged inside ``shard_map`` on a
per-device head slice (H/tp query heads against a pool arena holding
Kh/tp KV heads). Each query head attends only to its own KV head, so a
head slice's output block is bitwise the same rows of the full-H result;
the serving layer all-gathers the blocks before the output projection
(see ``transformer.decode_step_paged``). The Pallas kernel shares the
shape-polymorphic contract, keeping ref/pallas parity checks valid per
device slice too.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import attention_chunked


def paged_attention(q, k_pool, v_pool, tables, lengths, *,
                    window: int | None = None) -> jax.Array:
    """Single-query paged attention, pure-jax reference.

    q: (B, H, D) — one post-rope query per slot.
    k_pool/v_pool: (P, ps, K, D) — the global page pool (one layer).
    tables: (B, NP) int32 — page ids per slot; unused entries must point at
        pages whose positions are masked (beyond ``lengths[b]``, or outside
        the window), so their contents are never observable.
    lengths: (B,) int32 — valid KV entries per slot; attention covers
        positions ``[0, lengths[b])`` (clipped to the window).
    window: optional sliding window — only the last ``window`` positions
        attend (the query sits at position ``lengths[b] - 1``), and the
        table is read with **ring** semantics (see the module docstring).
        Both paths reconstruct the lane engine's exact cache layout (linear
        buffer / ring buffer) and run the same ``attention_chunked``, so
        either way the result is bit-identical to the lane decode.
    """
    _, ps, kh, d = k_pool.shape
    n_entries = tables.shape[1]

    def one(qb, tb, lb):
        if window is not None:
            # lane ring layout: buffer index i holds the newest position
            # p < lb with p ≡ i (mod window); kv_len clips the cold start
            i = jnp.arange(window)
            p = i + ((lb - 1 - i) // window) * window
            p = jnp.maximum(p, 0)          # i >= lb lanes: masked by kv_len
            entry = (p // ps) % n_entries  # ring block-table mapping
            kg = k_pool[tb[entry], p % ps]           # (window, K, D)
            vg = v_pool[tb[entry], p % ps]
            kv_len = jnp.minimum(lb, window)
            return attention_chunked(qb[None, None], kg[None], vg[None],
                                     causal=False, kv_len=kv_len)[0, 0]
        kg = k_pool[tb].reshape(1, -1, kh, d)
        vg = v_pool[tb].reshape(1, -1, kh, d)
        return attention_chunked(qb[None, None], kg, vg, causal=False,
                                 kv_len=lb)[0, 0]

    return jax.vmap(one)(q, tables, lengths)


def append_to_tail_pages(k_new, v_new, k_pool, v_pool, tables, lengths,
                         append_mask=None):
    """Scatter each slot's new KV entry into its tail page, in place.

    The entry lands at page ``tables[b, (lengths[b] // ps) % NP]``, row
    ``lengths[b] % ps`` — the ``% NP`` makes the same code serve contiguous
    tables (where ``lengths // ps < NP`` always) and ring tables (where the
    tail block's entry wraps). ``append_mask`` (B,) bool drops masked
    lanes' writes by pointing them at the out-of-range page index
    (``mode="drop"`` — the pool is untouched bitwise). Shared by the ref
    and pallas dispatch paths so the append semantics cannot diverge
    between them.
    """
    n_pages, ps = k_pool.shape[0], k_pool.shape[1]
    b = k_new.shape[0]
    page = tables[jnp.arange(b), (lengths // ps) % tables.shape[1]]
    off = lengths % ps
    if append_mask is not None:
        page = jnp.where(append_mask, page, n_pages)
    k_pool = k_pool.at[page, off].set(k_new.astype(k_pool.dtype), mode="drop")
    v_pool = v_pool.at[page, off].set(v_new.astype(v_pool.dtype), mode="drop")
    return k_pool, v_pool


def paged_decode_append(q, k_new, v_new, k_pool, v_pool, tables, lengths, *,
                        append_mask=None, window: int | None = None):
    """Reference for the fused decode step: append, then attend.

    Writes ``k_new[b]``/``v_new[b]`` into slot ``b``'s tail page at position
    ``lengths[b]``, then attends over ``lengths[b] + 1`` entries (the last
    ``window`` of them when windowed). Masked lanes append nothing and
    their output is garbage (must be ignored). Returns
    ``(o, k_pool', v_pool')``.
    """
    k_pool, v_pool = append_to_tail_pages(k_new, v_new, k_pool, v_pool,
                                          tables, lengths, append_mask)
    o = paged_attention(q, k_pool, v_pool, tables, lengths + 1, window=window)
    return o, k_pool, v_pool
