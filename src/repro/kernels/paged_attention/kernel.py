"""Paged decode attention Pallas TPU kernel (single query, GQA, block table).

The decode-side analogue of the flash kernel in ``kernels/attention``: one
query per slot attends over that slot's KV, but the KV lives *in place* in a
global page pool — fixed-size pages of ``page_size`` positions — reached
through a per-slot block table instead of a contiguous per-slot lane. The
block table rides in as a scalar-prefetch operand
(:class:`pltpu.PrefetchScalarGridSpec`), so the page id is known before the
kernel body runs and each grid step DMA-streams exactly one pool page
HBM->VMEM; nothing is ever copied into a per-slot contiguous buffer (the
VWR2A "operate on data where it already sits" discipline).

Grid = (slots, n_pages); the page dimension is innermost and sequential, and
the running (m, l, acc) online-softmax state is carried across it in VMEM
scratch, exactly like the flash kernel carries its KV-tile loop.

Layout contract: q (B, H, D); k/v pool (P, page_size, K, D); tables (B, NP)
int32 page ids; lengths (B,) int32 valid-position counts. GQA is folded
head-major: head h reads KV head ``h // (H // K)``. H and K are read off
the operand shapes, so the kernel serves a tensor-parallel head slice
(H/tp, K/tp inside ``shard_map``) exactly like the full head set.

With ``window`` set the table is a **ring block table** (the sliding-window
serving layout): entry ``e`` holds the page of the newest block
``b ≡ e (mod NP)`` at or below the tail block — older same-entry blocks
have been recycled because their positions fall wholly outside the window,
so a slot's table needs only ``ceil(window/page_size) + 1`` entries no
matter how long the sequence runs. The kernel still streams one page per
grid step; it just derives each entry's absolute positions from the ring
mapping and masks to ``[kv_len - window, kv_len)``. A full-width
contiguous table is the degenerate ring (no entry reused), so the same
code path serves both layouts.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page_size: int, groups: int,
                  window: int | None, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)
    n_p = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = lengths_ref[b]
    q = q_ref[0].astype(jnp.float32)               # (H, D)
    k = k_ref[0].astype(jnp.float32)               # (ps, K, D)
    v = v_ref[0].astype(jnp.float32)
    h, d = q.shape
    kh = k.shape[1]

    # GQA head-major fold: (H, D) -> (K, G, D); batch the KV-head axis
    qf = q.reshape(kh, groups, d)
    s = lax.dot_general(qf, k, (((2,), (2,)), ((0,), (1,))),
                        preferred_element_type=jnp.float32) * scale  # (K,G,ps)
    s = s.reshape(h, page_size)

    if window is not None:
        # ring block table: entry j holds the newest block b ≡ j (mod n_p)
        # with b <= (kv_len-1)//ps — recycled (older) blocks fall wholly
        # outside the window, so positions derive from that block index
        cur = (kv_len - 1) // page_size
        blk = cur - jnp.mod(cur - j, n_p)
        kpos = blk * page_size + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kpos >= 0) & (kpos < kv_len) & (kpos >= kv_len - window)
    else:
        kpos = j * page_size + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (H, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    pf = p.reshape(kh, groups, page_size)
    pv = lax.dot_general(pf, v, (((2,), (0,)), ((0,), (1,))),
                         preferred_element_type=jnp.float32)  # (K, G, D)
    acc_scr[...] = acc_scr[...] * corr + pv.reshape(h, d)
    m_scr[...] = m_new

    @pl.when(j == n_p - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def paged_attention_kernel(q, k_pool, v_pool, tables, lengths, *,
                           window: int | None = None,
                           scale: float | None = None,
                           interpret: bool = True) -> jax.Array:
    """Fused paged single-query attention (see module docstring for layout).

    Returns o (B, H, D). ``scale`` defaults to ``1/sqrt(D)`` — pass the
    unpadded head dim's scale explicitly when D is padded for the MXU.
    """
    b, h, d = q.shape
    n_pages, ps, kh, dk = k_pool.shape
    assert dk == d, (dk, d)
    assert h % kh == 0, (h, kh)
    groups = h // kh
    np_per_slot = tables.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    kernel = functools.partial(_paged_kernel, page_size=ps, groups=groups,
                               window=window, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, np_per_slot),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda bi, j, t, ln: (bi, 0, 0)),
            pl.BlockSpec((1, ps, kh, d),
                         lambda bi, j, t, ln: (t[bi, j], 0, 0, 0)),
            pl.BlockSpec((1, ps, kh, d),
                         lambda bi, j, t, ln: (t[bi, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda bi, j, t, ln: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pool, v_pool)
