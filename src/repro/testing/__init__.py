"""Test-support utilities that ship with the library (no test-only deps)."""
