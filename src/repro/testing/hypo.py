"""A tiny, dependency-free stand-in for the ``hypothesis`` subset we use.

The property tests in ``tests/`` are written against ``hypothesis`` when it
is installed. The serving image does not ship it, so this module provides a
seeded-random fallback implementing exactly the API surface those tests use:

* ``@given(**kwargs)`` with keyword strategies
* ``@settings(max_examples=..., deadline=...)`` stacked outside ``given``
* ``strategies.integers/floats/booleans/lists/sampled_from``

Semantics differ from real hypothesis in the expected ways: examples are
drawn from a fixed-seed PRNG (deterministic across runs, no shrinking, no
example database). Each strategy exposes ``example(rng)``; ``given`` draws
``max_examples`` assignments and calls the test once per assignment.

Usage in tests::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from repro.testing.hypo import given, settings, strategies as st
"""

from __future__ import annotations


import random

_DEFAULT_MAX_EXAMPLES = 50
_SEED = 0xA11CE


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> Strategy:
        return Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def sampled_from(options) -> Strategy:
        options = list(options)
        return Strategy(lambda rng: rng.choice(options))

    @staticmethod
    def lists(elements: Strategy, *, min_size: int = 0,
              max_size: int = 10) -> Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return Strategy(draw)


strategies = _Strategies()
st = strategies  # common alias


def given(**strat_kwargs):
    """Decorator: run the test once per drawn example (seeded, deterministic)."""

    def deco(fn):
        # NB: no functools.wraps — copying __wrapped__ would let pytest see
        # the original signature and demand fixtures for the strategy params.
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hypo_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for i in range(n):
                drawn = {k: s.example(rng) for k, s in strat_kwargs.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"falsifying example (#{i + 1}/{n}): {drawn}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper._hypo_given = True
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator: bound the number of examples ``given`` draws."""

    def deco(fn):
        fn._hypo_max_examples = max_examples
        return fn

    return deco
