"""Sharded checkpointing with elastic resharding.

Format: one directory per step — ``manifest.json`` (treedef, shapes, dtypes,
step, user metadata) + one ``.npy`` per leaf. Writes are atomic (tmp dir +
rename) so a mid-save crash never corrupts the latest checkpoint; saves can
run on a background thread (overlaps the next train step).

Elastic restore: leaves are materialized with ``jax.device_put`` against the
TARGET mesh's shardings — a checkpoint written on (2,16,16) restores onto
(16,16) or any other mesh (tested down to single-device), which is the
restart path after losing a pod.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any

import jax
import ml_dtypes
import numpy as np

_SEP = "/"

# numpy can't serialize bfloat16 natively: round-trip through a uint16 view
_VIEW_DTYPES = {"bfloat16": ml_dtypes.bfloat16}


def _to_savable(a: np.ndarray) -> tuple[np.ndarray, str]:
    if a.dtype == ml_dtypes.bfloat16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _from_saved(a: np.ndarray, dtype: str) -> np.ndarray:
    if dtype in _VIEW_DTYPES:
        return a.view(_VIEW_DTYPES[dtype])
    return a


def _flatten(tree: Any) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_names(treedef) -> list[str]:
    dummy = treedef.unflatten(list(range(treedef.num_leaves)))
    paths = jax.tree_util.tree_flatten_with_path(dummy)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        names.append("__".join(parts) or "leaf")
    return names


def save(path: str | pathlib.Path, tree: Any, *, step: int,
         metadata: dict | None = None, async_: bool = False):
    """Write checkpoint for ``step``. Returns a join()-able handle if async."""
    path = pathlib.Path(path)
    leaves, treedef = _flatten(tree)
    names = _leaf_names(treedef)
    # materialize to host BEFORE returning (so training can mutate buffers)
    host = [np.asarray(x) for x in leaves]

    def _write():
        final = path / f"step_{step:09d}"
        tmp = path / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        savable = [_to_savable(a) for a in host]
        manifest = {
            "step": step,
            "metadata": metadata or {},
            "leaves": [
                {"name": n, "shape": list(a.shape), "dtype": dt}
                for n, (a, dt) in zip(names, savable)
            ],
        }
        for n, (a, _) in zip(names, savable):
            np.save(tmp / f"{n}.npy", a)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(path: str | pathlib.Path) -> int | None:
    path = pathlib.Path(path)
    if not path.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in path.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(path: str | pathlib.Path, tree_like: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``tree_like``; if ``shardings`` given
    (same structure), leaves are placed with those shardings — this is where
    elastic resharding happens."""
    path = pathlib.Path(path)
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    d = path / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    _, treedef = _flatten(tree_like)
    names = _leaf_names(treedef)
    want = {e["name"] for e in manifest["leaves"]}
    have = set(names)
    if want != have:
        raise ValueError(f"checkpoint/tree mismatch: only-ckpt={want-have} "
                         f"only-tree={have-want}")
    dtype_of = {e["name"]: e["dtype"] for e in manifest["leaves"]}
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(names))
    leaves = []
    for n, sh in zip(names, shard_leaves):
        a = _from_saved(np.load(d / f"{n}.npy"), dtype_of[n])
        leaves.append(jax.device_put(a, sh) if sh is not None else a)
    return treedef.unflatten(leaves), step, manifest["metadata"]
