"""Sharded checkpointing with elastic resharding.

Format: one directory per step — ``manifest.json`` (treedef, shapes, dtypes,
per-leaf byte counts and CRCs, step, user metadata) + one ``.npy`` per leaf.
Writes are atomic (tmp dir + rename; the manifest is written last, so a
half-written tmp dir is never mistaken for a checkpoint, and an existing
step directory is renamed aside rather than deleted before the swap) so a
mid-save crash never corrupts the latest checkpoint; saves can run on a
background thread (overlaps the next train step). ``restore`` verifies
every leaf against the manifest — missing file, size mismatch, or CRC
mismatch raises a clear "partial/corrupted" error instead of silently
loading damaged weights (the engine-rebuild path leans on this).

Elastic restore: leaves are materialized with ``jax.device_put`` against the
TARGET mesh's shardings — a checkpoint written on (2,16,16) restores onto
(16,16) or any other mesh (tested down to single-device), which is the
restart path after losing a pod.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import threading
import zlib
from typing import Any

import jax
import ml_dtypes
import numpy as np

_SEP = "/"

# numpy can't serialize bfloat16 natively: round-trip through a uint16 view
_VIEW_DTYPES = {"bfloat16": ml_dtypes.bfloat16}


def _to_savable(a: np.ndarray) -> tuple[np.ndarray, str]:
    if a.dtype == ml_dtypes.bfloat16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _from_saved(a: np.ndarray, dtype: str) -> np.ndarray:
    if dtype in _VIEW_DTYPES:
        return a.view(_VIEW_DTYPES[dtype])
    return a


def _flatten(tree: Any) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_names(treedef) -> list[str]:
    dummy = treedef.unflatten(list(range(treedef.num_leaves)))
    paths = jax.tree_util.tree_flatten_with_path(dummy)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        names.append("__".join(parts) or "leaf")
    return names


def save(path: str | pathlib.Path, tree: Any, *, step: int,
         metadata: dict | None = None, async_: bool = False):
    """Write checkpoint for ``step``. Returns a join()-able handle if async."""
    path = pathlib.Path(path)
    leaves, treedef = _flatten(tree)
    names = _leaf_names(treedef)
    # materialize to host BEFORE returning (so training can mutate buffers)
    host = [np.asarray(x) for x in leaves]

    def _write():
        final = path / f"step_{step:09d}"
        tmp = path / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        savable = [_to_savable(a) for a in host]
        manifest = {
            "step": step,
            "metadata": metadata or {},
            "leaves": [
                {"name": n, "shape": list(a.shape), "dtype": dt,
                 "nbytes": int(a.nbytes),
                 "crc32": zlib.crc32(np.ascontiguousarray(a).tobytes())}
                for n, (a, dt) in zip(names, savable)
            ],
        }
        for n, (a, _) in zip(names, savable):
            np.save(tmp / f"{n}.npy", a)
        # manifest last: a tmp dir interrupted mid-write has no manifest
        # and is invisible to latest_step/restore
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            # never delete the old step before the new one is in place: a
            # crash between rmtree and rename must not lose the latest
            # checkpoint. The dot-prefixed name hides the old copy from
            # latest_step's step_* glob during the swap.
            old = path / f".old_step_{step:09d}"
            if old.exists():
                shutil.rmtree(old)
            final.rename(old)
            tmp.rename(final)
            shutil.rmtree(old)
        else:
            tmp.rename(final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(path: str | pathlib.Path) -> int | None:
    path = pathlib.Path(path)
    if not path.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in path.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(path: str | pathlib.Path, tree_like: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``tree_like``; if ``shardings`` given
    (same structure), leaves are placed with those shardings — this is where
    elastic resharding happens."""
    path = pathlib.Path(path)
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    d = path / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    _, treedef = _flatten(tree_like)
    names = _leaf_names(treedef)
    want = {e["name"] for e in manifest["leaves"]}
    have = set(names)
    if want != have:
        raise ValueError(f"checkpoint/tree mismatch: only-ckpt={want-have} "
                         f"only-tree={have-want}")
    entry_of = {e["name"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(names))
    leaves = []
    for n, sh in zip(names, shard_leaves):
        ent = entry_of[n]
        f = d / f"{n}.npy"
        if not f.exists():
            raise ValueError(
                f"checkpoint {d} is corrupt: leaf file {n}.npy missing "
                "(partial write?)")
        try:
            raw = np.load(f)
        except Exception as e:
            raise ValueError(
                f"checkpoint {d} is corrupt: leaf {n} unreadable "
                f"(partial write?): {e}") from e
        # integrity checks against the manifest (older checkpoints
        # without nbytes/crc32 fields skip them — shape is always known)
        if list(raw.shape) != list(ent["shape"]):
            raise ValueError(
                f"checkpoint {d} is corrupt: leaf {n} has shape "
                f"{list(raw.shape)}, manifest says {ent['shape']}")
        if "nbytes" in ent and int(raw.nbytes) != int(ent["nbytes"]):
            raise ValueError(
                f"checkpoint {d} is corrupt: leaf {n} is {raw.nbytes} "
                f"bytes, manifest says {ent['nbytes']} (partial write?)")
        if "crc32" in ent:
            crc = zlib.crc32(np.ascontiguousarray(raw).tobytes())
            if crc != int(ent["crc32"]):
                raise ValueError(
                    f"checkpoint {d} is corrupt: leaf {n} CRC mismatch "
                    f"({crc:#010x} != {int(ent['crc32']):#010x})")
        a = _from_saved(raw, ent["dtype"])
        leaves.append(jax.device_put(a, sh) if sh is not None else a)
    return treedef.unflatten(leaves), step, manifest["metadata"]
