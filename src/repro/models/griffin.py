"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU + local attention.

Layer pattern is (rec, rec, attn) repeating — 26 layers = 8 triples + a
(rec, rec) tail. The RG-LRU linear recurrence has three backends:
  * ``ref``     — sequential time scan (oracle);
  * ``chunked`` — ``lax.associative_scan`` (log-depth parallel scan);
  * ``pallas``  — fused block-scan kernel via XAIF (:mod:`repro.kernels.rglru`).

Decode state is O(rnn_width) per recurrent layer + a 2048-token window cache
per attention layer — context-length-independent, hence long_500k-eligible.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding import axes as lx
from repro.sharding.params import Axes, ParamDecl

F32 = jnp.float32


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _block_linear(x, w):
    """Block-diagonal linear: x (B,S,W), w (nb, bs, bs)."""
    b, s, width = x.shape
    nb, bs, _ = w.shape
    xr = x.reshape(b, s, nb, bs)
    return jnp.einsum("bsgi,gij->bsgj", xr, w).reshape(b, s, width)


def rglru_gates(x, p, c: float):
    """Returns (a, b_in): recurrence coefficient and gated input."""
    r = jax.nn.sigmoid(_block_linear(x, p["w_r"].astype(x.dtype)).astype(F32)
                       + p["b_r"].astype(F32))
    i = jax.nn.sigmoid(_block_linear(x, p["w_i"].astype(x.dtype)).astype(F32)
                       + p["b_i"].astype(F32))
    log_a = -c * jax.nn.softplus(p["a_param"].astype(F32)) * r
    a = jnp.exp(log_a)
    b_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * x.astype(F32))
    return a, b_in


def linear_scan_ref(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t, sequential. a,b: (B,S,W)."""
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), F32)

    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    hf, ys = lax.scan(step, h0.astype(F32),
                      (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), hf


def linear_scan_assoc(a, b, h0=None):
    """Parallel (log-depth) scan over the sequence axis."""
    if h0 is not None:
        # fold h0 into the first step: b_0 += a_0 * h0
        b = b.at[:, 0].add(a[:, 0] * h0.astype(F32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    ya, yb = lax.associative_scan(combine, (a.astype(F32), b.astype(F32)), axis=1)
    return yb, yb[:, -1]


def linear_scan_blocked(a, b, h0=None, *, block: int = 256):
    """Sequential over blocks, associative within a block: log-depth work with
    O(block·W) peak memory instead of O(S·W·log S) — mirrors the Pallas
    kernel's VMEM-state structure."""
    bsz, s, w = a.shape
    if s <= block:
        return linear_scan_assoc(a, b, h0)
    pad = (-s) % block
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    nb = a.shape[1] // block
    ab = jnp.moveaxis(a.reshape(bsz, nb, block, w), 1, 0)
    bb = jnp.moveaxis(b.reshape(bsz, nb, block, w), 1, 0)
    h0 = jnp.zeros((bsz, w), F32) if h0 is None else h0.astype(F32)

    def step(h, inp):
        a_blk, b_blk = inp
        ys, hf = linear_scan_assoc(a_blk, b_blk, h)
        return hf, ys

    hf, ys = lax.scan(step, h0, (ab, bb))
    ys = jnp.moveaxis(ys, 0, 1).reshape(bsz, nb * block, w)[:, :s]
    return ys, ys[:, -1]


def linear_scan(a, b, h0=None, *, impl: str = "chunked"):
    if impl == "ref":
        return linear_scan_ref(a, b, h0)
    if impl == "chunked":
        return linear_scan_blocked(a, b, h0)
    from repro.core.xaif import REGISTRY

    return REGISTRY.dispatch("rglru", impl, a, b, h0)


# ---------------------------------------------------------------------------
# Declarations
# ---------------------------------------------------------------------------


def _rglru_decls(width: int, nb: int) -> dict[str, ParamDecl]:
    bs = width // nb
    return {
        "a_param": ParamDecl((width,), Axes(lx.RNN_WIDTH), init="normal", scale=0.5),
        "w_r": ParamDecl((nb, bs, bs), Axes(lx.HEADS, None, None), init="fan_in"),
        "b_r": ParamDecl((width,), Axes(lx.RNN_WIDTH), init="zeros"),
        "w_i": ParamDecl((nb, bs, bs), Axes(lx.HEADS, None, None), init="fan_in"),
        "b_i": ParamDecl((width,), Axes(lx.RNN_WIDTH), init="zeros"),
    }


def _rec_mix_decls(cfg: ModelConfig) -> dict[str, Any]:
    d, w = cfg.d_model, cfg.rnn_width or cfg.d_model
    return {
        "ln": L.rmsnorm_decl(d),
        "w_y": ParamDecl((d, w), Axes(lx.EMBED, lx.RNN_WIDTH), init="fan_in"),
        "w_x": ParamDecl((d, w), Axes(lx.EMBED, lx.RNN_WIDTH), init="fan_in"),
        "conv": L.conv1d_decl(cfg.ssm_conv_width, w),
        "rglru": _rglru_decls(w, cfg.n_heads),
        "w_out": ParamDecl((w, d), Axes(lx.RNN_WIDTH, lx.EMBED), init="fan_in"),
    }


def _attn_mix_decls(cfg: ModelConfig) -> dict[str, ParamDecl]:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "ln": L.rmsnorm_decl(d),
        "wq": ParamDecl((d, h, hd), Axes(lx.EMBED, lx.HEADS, lx.HEAD_DIM), init="fan_in"),
        "wk": ParamDecl((d, k, hd), Axes(lx.EMBED, lx.KV_HEADS, lx.HEAD_DIM), init="fan_in"),
        "wv": ParamDecl((d, k, hd), Axes(lx.EMBED, lx.KV_HEADS, lx.HEAD_DIM), init="fan_in"),
        "wo": ParamDecl((h, hd, d), Axes(lx.HEADS, lx.HEAD_DIM, lx.EMBED), init="fan_in"),
    }


def _layer_decls(cfg: ModelConfig, kind: str) -> dict[str, Any]:
    mix = _rec_mix_decls(cfg) if kind == "rec" else _attn_mix_decls(cfg)
    return {"mix": mix, "ln_mlp": L.rmsnorm_decl(cfg.d_model),
            "mlp": L.mlp_decls(cfg.d_model, cfg.d_ff, cfg.mlp_type)}


def _pattern(cfg: ModelConfig) -> list[str]:
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def plan(cfg: ModelConfig) -> tuple[int, list[str]]:
    """(n_triples, tail_kinds)."""
    pat = _pattern(cfg)
    plen = len(cfg.block_pattern or ("rec", "rec", "attn"))
    n_full = cfg.n_layers // plen
    tail = pat[n_full * plen:]
    return n_full, tail


def decls(cfg: ModelConfig) -> dict[str, Any]:
    from repro.sharding.params import stack_tree

    n_full, tail = plan(cfg)
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    triple = {f"p{j}_{k}": _layer_decls(cfg, k) for j, k in enumerate(pat)}
    tree: dict[str, Any] = {
        "embed": L.embed_decl(cfg),
        "triples": stack_tree(triple, n_full, lx.LAYERS),
        "tail": {f"t{j}_{k}": _layer_decls(cfg, k) for j, k in enumerate(tail)},
        "ln_f": L.rmsnorm_decl(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        tree["head"] = L.head_decl(cfg)
    return tree


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GriffinCache:
    conv: jax.Array    # (n_rec, B, cw-1, W)
    h: jax.Array       # (n_rec, B, W) fp32
    k: jax.Array       # (n_attn, B, win, kv, hd)
    v: jax.Array
    pos: jax.Array

    @staticmethod
    def _shapes(cfg: ModelConfig, batch: int, max_len: int):
        kinds = _pattern(cfg)
        n_rec = kinds.count("rec")
        n_attn = kinds.count("attn")
        w = cfg.rnn_width or cfg.d_model
        win = min(cfg.attn_window or max_len, max_len)
        return (
            (n_rec, batch, cfg.ssm_conv_width - 1, w),
            (n_rec, batch, w),
            (n_attn, batch, win, cfg.n_kv_heads, cfg.resolved_head_dim),
        )

    @staticmethod
    def init(cfg, batch, max_len, dtype=jnp.bfloat16) -> "GriffinCache":
        s = GriffinCache._shapes(cfg, batch, max_len)
        return GriffinCache(jnp.zeros(s[0], dtype), jnp.zeros(s[1], F32),
                            jnp.zeros(s[2], dtype), jnp.zeros(s[2], dtype),
                            jnp.zeros((), jnp.int32))

    @staticmethod
    def abstract(cfg, batch, max_len, dtype=jnp.bfloat16) -> "GriffinCache":
        s = GriffinCache._shapes(cfg, batch, max_len)
        return GriffinCache(jax.ShapeDtypeStruct(s[0], dtype),
                            jax.ShapeDtypeStruct(s[1], F32),
                            jax.ShapeDtypeStruct(s[2], dtype),
                            jax.ShapeDtypeStruct(s[2], dtype),
                            jax.ShapeDtypeStruct((), jnp.int32))

    @staticmethod
    def axes() -> "GriffinCache":
        kv = Axes(None, lx.DECODE_BATCH, lx.CACHE_SEQ, lx.KV_HEADS, lx.HEAD_DIM)
        return GriffinCache(Axes(None, lx.DECODE_BATCH, None, lx.RNN_WIDTH),
                            Axes(None, lx.DECODE_BATCH, lx.RNN_WIDTH), kv, kv, Axes())


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _rec_mix_train(x, p, cfg: ModelConfig):
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    y = jax.nn.gelu(h @ p["w_y"].astype(h.dtype))
    u = h @ p["w_x"].astype(h.dtype)
    u, _ = L.causal_conv1d(u, p["conv"].astype(u.dtype))
    a, b_in = rglru_gates(u, p["rglru"], cfg.rglru_c)
    ys, _ = linear_scan(a, b_in, impl=cfg.scan_impl)
    out = (ys.astype(x.dtype) * y) @ p["w_out"].astype(x.dtype)
    return x + out


def _rec_mix_decode(x, p, cfg: ModelConfig, conv_st, h_st):
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    y = jax.nn.gelu(h @ p["w_y"].astype(h.dtype))
    u = h @ p["w_x"].astype(h.dtype)
    u, conv2 = L.causal_conv1d(u, p["conv"].astype(u.dtype), conv_st)
    a, b_in = rglru_gates(u, p["rglru"], cfg.rglru_c)
    h_new = a[:, 0] * h_st + b_in[:, 0]
    out = (h_new[:, None].astype(x.dtype) * y) @ p["w_out"].astype(x.dtype)
    return x + out, conv2, h_new


def _attn_mix_train(x, p, cfg: ModelConfig, positions):
    from repro.models.transformer import _project_qkv

    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(h, p, cfg, positions)
    o = L.attention(q, k, v, impl=cfg.attn_impl, causal=True, window=cfg.attn_window)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


def _attn_mix_decode(x, p, cfg: ModelConfig, ck, cv, pos):
    from repro.models.transformer import _project_qkv

    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(h, p, cfg, pos[None, None])
    win = ck.shape[1]
    slot = pos % win
    ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, axis=1)
    cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, axis=1)
    kv_len = jnp.minimum(pos + 1, win)
    o = L.attention(q, ck, cv, impl="chunked", causal=False, kv_len=kv_len)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype)), ck, cv


def _layer_train(x, lp, kind, cfg, positions):
    if kind == "rec":
        x = _rec_mix_train(x, jax.tree.map(lambda a: a, lp["mix"]), cfg)
    else:
        x = _attn_mix_train(x, lp["mix"], cfg, positions)
    h = L.rmsnorm(x, lp["ln_mlp"], cfg.norm_eps)
    return x + L.mlp(h, jax.tree.map(lambda a: a.astype(x.dtype), lp["mlp"]),
                     cfg.mlp_type)


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None):
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    if positions is None:
        positions = jnp.arange(s)[None, :]
    x = params["embed"].astype(jnp.bfloat16)[tokens] if embeds is None else embeds
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)  # gemma-style scaling
    pat = cfg.block_pattern or ("rec", "rec", "attn")

    def body(carry, tp):
        xc = carry
        for j, kind in enumerate(pat):
            xc = _layer_train(xc, tp[f"p{j}_{kind}"], kind, cfg, positions)
        return xc, None

    from repro.models.transformer import _maybe_remat

    x, _ = lax.scan(_maybe_remat(body, cfg), x, params["triples"])
    _, tail = plan(cfg)
    for j, kind in enumerate(tail):
        x = _layer_train(x, params["tail"][f"t{j}_{kind}"], kind, cfg, positions)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return L.lm_head(x, params, cfg), jnp.zeros((), F32)


def _rec_mix_prefill(x, p, cfg: ModelConfig):
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    y = jax.nn.gelu(h @ p["w_y"].astype(h.dtype))
    u = h @ p["w_x"].astype(h.dtype)
    tail = u[:, -(cfg.ssm_conv_width - 1):]
    u, _ = L.causal_conv1d(u, p["conv"].astype(u.dtype))
    a, b_in = rglru_gates(u, p["rglru"], cfg.rglru_c)
    ys, h_fin = linear_scan(a, b_in, impl=cfg.scan_impl)
    out = (ys.astype(x.dtype) * y) @ p["w_out"].astype(x.dtype)
    return x + out, tail, h_fin


def _attn_mix_prefill(x, p, cfg: ModelConfig, positions, win: int):
    import numpy as np

    from repro.models.transformer import _project_qkv

    b, s = x.shape[:2]
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(h, p, cfg, positions)
    o = L.attention(q, k, v, impl=cfg.attn_impl, causal=True, window=cfg.attn_window)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    cdtype = jnp.bfloat16
    if s >= win:
        slots = np.arange(s - win, s) % win
        ck = jnp.zeros((b, win, *k.shape[2:]), cdtype).at[:, slots].set(
            k[:, s - win:].astype(cdtype))
        cv = jnp.zeros((b, win, *v.shape[2:]), cdtype).at[:, slots].set(
            v[:, s - win:].astype(cdtype))
    else:
        ck = jnp.pad(k.astype(cdtype), ((0, 0), (0, win - s), (0, 0), (0, 0)))
        cv = jnp.pad(v.astype(cdtype), ((0, 0), (0, win - s), (0, 0), (0, 0)))
    return x, ck, cv


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, max_len=None):
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    max_len = max_len or s
    win = min(cfg.attn_window or max_len, max_len)
    positions = jnp.arange(s)[None, :]
    x = params["embed"].astype(jnp.bfloat16)[tokens] if embeds is None else embeds
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    pat = cfg.block_pattern or ("rec", "rec", "attn")

    def body(carry, tp):
        xc = carry
        convs, hs, ks, vs = [], [], [], []
        for j, kind in enumerate(pat):
            lp = tp[f"p{j}_{kind}"]
            if kind == "rec":
                xc, tail_c, h_fin = _rec_mix_prefill(xc, lp["mix"], cfg)
                convs.append(tail_c)
                hs.append(h_fin)
            else:
                xc, ck, cv = _attn_mix_prefill(xc, lp["mix"], cfg, positions, win)
                ks.append(ck)
                vs.append(cv)
            hh = L.rmsnorm(xc, lp["ln_mlp"], cfg.norm_eps)
            xc = xc + L.mlp(hh, jax.tree.map(lambda a: a.astype(xc.dtype), lp["mlp"]),
                            cfg.mlp_type)
        return xc, (jnp.stack(convs), jnp.stack(hs), jnp.stack(ks), jnp.stack(vs))

    body_fn = body if cfg.remat == "none" else jax.checkpoint(body)
    x, (convs, hs, ks, vs) = lax.scan(body_fn, x, params["triples"])
    convs = [convs.reshape(-1, *convs.shape[2:])]
    hs = [hs.reshape(-1, *hs.shape[2:])]
    ks = [ks.reshape(-1, *ks.shape[2:])]
    vs = [vs.reshape(-1, *vs.shape[2:])]
    _, tail = plan(cfg)
    for j, kind in enumerate(tail):
        lp = params["tail"][f"t{j}_{kind}"]
        if kind == "rec":
            x, tail_c, h_fin = _rec_mix_prefill(x, lp["mix"], cfg)
            convs.append(tail_c[None])
            hs.append(h_fin[None])
        else:
            x, ck, cv = _attn_mix_prefill(x, lp["mix"], cfg, positions, win)
            ks.append(ck[None])
            vs.append(cv[None])
        hh = L.rmsnorm(x, lp["ln_mlp"], cfg.norm_eps)
        x = x + L.mlp(hh, jax.tree.map(lambda a: a.astype(x.dtype), lp["mlp"]),
                      cfg.mlp_type)

    xf = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = L.lm_head(xf, params, cfg)[:, 0]
    cache = GriffinCache(jnp.concatenate(convs).astype(jnp.bfloat16),
                         jnp.concatenate(hs).astype(F32),
                         jnp.concatenate(ks), jnp.concatenate(vs),
                         jnp.asarray(s, jnp.int32))
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache: GriffinCache, tokens):
    """tokens (B,1) -> (logits, cache'). Iterates layers unrolled (26 is
    manageable for a single-token step) to keep heterogeneous cache routing
    simple and allocation-free."""
    pos = cache.pos
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    n_full, tail = plan(cfg)

    conv_out, h_out, k_out, v_out = [], [], [], []
    ri, ai = 0, 0

    def run_layer(x, lp, kind):
        nonlocal ri, ai
        if kind == "rec":
            x2, conv2, h2 = _rec_mix_decode(x, lp["mix"], cfg,
                                            cache.conv[ri], cache.h[ri])
            conv_out.append(conv2)
            h_out.append(h2)
            ri += 1
        else:
            x2, ck, cv = _attn_mix_decode(x, lp["mix"], cfg,
                                          cache.k[ai], cache.v[ai], pos)
            k_out.append(ck)
            v_out.append(cv)
            ai += 1
        hh = L.rmsnorm(x2, lp["ln_mlp"], cfg.norm_eps)
        return x2 + L.mlp(hh, jax.tree.map(lambda a: a.astype(x2.dtype), lp["mlp"]),
                          cfg.mlp_type)

    for t in range(n_full):
        tp = jax.tree.map(lambda a: a[t], params["triples"])
        for j, kind in enumerate(pat):
            x = run_layer(x, tp[f"p{j}_{kind}"], kind)
    for j, kind in enumerate(tail):
        x = run_layer(x, params["tail"][f"t{j}_{kind}"], kind)

    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = L.lm_head(x, params, cfg)[:, 0]
    new = GriffinCache(jnp.stack(conv_out), jnp.stack(h_out),
                       jnp.stack(k_out), jnp.stack(v_out), pos + 1)
    return logits, new
