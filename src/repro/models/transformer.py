"""Decoder-only transformer family: dense / GQA / SWA / MoE / modality stubs.

Structure: weights for all layers are stacked and the layer stack runs under
``lax.scan`` (bounded HLO size, fast lowering at 80 layers) with configurable
remat. MoE interleaving is expressed as a "superblock" of ``moe_interleave``
layers (dense ... dense, MoE) so the scan stays homogeneous.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding import axes as lx
from repro.sharding.params import Axes, ParamDecl


def _is_moe_layer(cfg: ModelConfig, layer_idx: int) -> bool:
    if cfg.moe_experts < 2:
        return False
    return layer_idx % cfg.moe_interleave == cfg.moe_interleave - 1


def _attn_decls(cfg: ModelConfig) -> dict[str, ParamDecl]:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "ln": L.rmsnorm_decl(d),
        "wq": ParamDecl((d, h, hd), Axes(lx.EMBED, lx.HEADS, lx.HEAD_DIM), init="fan_in"),
        "wk": ParamDecl((d, k, hd), Axes(lx.EMBED, lx.KV_HEADS, lx.HEAD_DIM), init="fan_in"),
        "wv": ParamDecl((d, k, hd), Axes(lx.EMBED, lx.KV_HEADS, lx.HEAD_DIM), init="fan_in"),
        "wo": ParamDecl((h, hd, d), Axes(lx.HEADS, lx.HEAD_DIM, lx.EMBED), init="fan_in"),
    }


def _layer_decls(cfg: ModelConfig, layer_idx: int) -> dict[str, Any]:
    out: dict[str, Any] = {"attn": _attn_decls(cfg), "ln_mlp": L.rmsnorm_decl(cfg.d_model)}
    if _is_moe_layer(cfg, layer_idx):
        out["moe"] = L.moe_decls(cfg.d_model, cfg.d_ff, cfg.moe_experts,
                                 cfg.mlp_type, shared=cfg.moe_shared_expert)
    else:
        out["mlp"] = L.mlp_decls(cfg.d_model, cfg.d_ff, cfg.mlp_type)
    return out


def decls(cfg: ModelConfig) -> dict[str, Any]:
    il = cfg.moe_interleave if cfg.moe_experts >= 2 else 1
    if cfg.n_layers % il:
        raise ValueError(f"{cfg.name}: n_layers {cfg.n_layers} % interleave {il} != 0")
    n_super = cfg.n_layers // il
    superblock = {f"l{j}": _layer_decls(cfg, j) for j in range(il)}
    from repro.sharding.params import stack_tree

    tree: dict[str, Any] = {
        "embed": L.embed_decl(cfg),
        "blocks": stack_tree(superblock, n_super, lx.LAYERS),
        "ln_f": L.rmsnorm_decl(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        tree["head"] = L.head_decl(cfg)
    return tree


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: jax.Array  # (L, B, S_cache, Kh, Dh)
    v: jax.Array
    pos: jax.Array  # scalar int32 — next position to write

    @staticmethod
    def cache_len(cfg: ModelConfig, max_len: int) -> int:
        return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len

    @staticmethod
    def init(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> "KVCache":
        s = KVCache.cache_len(cfg, max_len)
        shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.resolved_head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                       jnp.zeros((), jnp.int32))

    @staticmethod
    def abstract(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> "KVCache":
        s = KVCache.cache_len(cfg, max_len)
        shape = (cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.resolved_head_dim)
        return KVCache(jax.ShapeDtypeStruct(shape, dtype),
                       jax.ShapeDtypeStruct(shape, dtype),
                       jax.ShapeDtypeStruct((), jnp.int32))

    @staticmethod
    def axes() -> "KVCache":
        a = Axes(lx.LAYERS, lx.DECODE_BATCH, lx.CACHE_SEQ, lx.KV_HEADS, lx.HEAD_DIM)
        return KVCache(a, a, Axes())


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _project_qkv(h, p, cfg: ModelConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(h.dtype))
    if cfg.pos_emb == "rope":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_train(x, p, cfg: ModelConfig, positions):
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(h, p, cfg, positions)
    o = L.attention(q, k, v, impl=cfg.attn_impl, causal=True,
                    window=cfg.sliding_window)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


def _attn_decode(x, p, cfg: ModelConfig, ck, cv, pos):
    """x: (B,1,D); ck/cv: (B,Sc,Kh,Dh). Returns (x', ck', cv')."""
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(h, p, cfg, pos[None, None] if pos.ndim == 0 else pos)
    s_cache = ck.shape[1]
    slot = pos % s_cache if cfg.sliding_window else pos
    ck = lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), slot, axis=1)
    cv = lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), slot, axis=1)
    kv_len = jnp.minimum(pos + 1, s_cache)
    # decode always uses the chunked backend: dynamic kv_len + grouped KV
    o = L.attention(q, ck, cv, impl="chunked", causal=False, window=None,
                    kv_len=kv_len)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype)), ck, cv


def _ffn(x, lp, cfg: ModelConfig, is_moe: bool):
    h = L.rmsnorm(x, lp["ln_mlp"], cfg.norm_eps)
    if is_moe:
        cast = jax.tree.map(lambda a: a.astype(x.dtype), lp["moe"])
        o, aux = L.moe(h, cast, n_exp=cfg.moe_experts, top_k=cfg.moe_top_k,
                       capacity_factor=cfg.moe_capacity_factor, kind=cfg.mlp_type,
                       impl=cfg.moe_impl)
        return x + o, aux
    cast = jax.tree.map(lambda a: a.astype(x.dtype), lp["mlp"])
    return x + L.mlp(h, cast, cfg.mlp_type), jnp.zeros((), jnp.float32)


def _superblock_train(cfg: ModelConfig):
    il = cfg.moe_interleave if cfg.moe_experts >= 2 else 1

    def fn(carry, blk):
        x, aux, positions = carry
        for j in range(il):
            lp = blk[f"l{j}"]
            x = _attn_train(x, jax.tree.map(lambda a: a.astype(x.dtype), lp["attn"]),
                            cfg, positions)
            x, a = _ffn(x, lp, cfg, _is_moe_layer(cfg, j))
            aux = aux + a
        return (x, aux, positions), None

    return fn


_REMAT_POLICIES = {
    "none": None,
    "full": None,  # checkpoint with default policy = save nothing
    "dots": "dots",
}


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def _embed(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None):
    if embeds is None:
        x = params["embed"].astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)[tokens]
    else:
        x = embeds
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal(positions, cfg.d_model).astype(x.dtype)
    return x


def _head(params, cfg: ModelConfig, x):
    return L.lm_head(x, params, cfg)


def forward(params, cfg: ModelConfig, tokens=None, embeds=None,
            positions=None) -> tuple[jax.Array, jax.Array]:
    """Training/prefill forward. Returns (logits, moe_aux_loss)."""
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    if positions is None:
        positions = jnp.arange(s)[None, :]
    x = _embed(params, cfg, tokens, embeds, positions)
    body = _maybe_remat(_superblock_train(cfg), cfg)
    (x, aux, _), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32), positions),
                              params["blocks"])
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _head(params, cfg, x), aux


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, max_len: int | None = None):
    """Run the prompt, build the KV cache. Returns (last_logits, cache)."""
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    max_len = max_len or s
    positions = jnp.arange(s)[None, :]
    x = _embed(params, cfg, tokens, embeds, positions)
    il = cfg.moe_interleave if cfg.moe_experts >= 2 else 1
    s_cache = KVCache.cache_len(cfg, max_len)
    cdtype = jnp.bfloat16

    def block(carry, blk):
        x, aux = carry
        ks, vs = [], []
        for j in range(il):
            lp = blk[f"l{j}"]
            ap = jax.tree.map(lambda a: a.astype(x.dtype), lp["attn"])
            h = L.rmsnorm(x, ap["ln"], cfg.norm_eps)
            q, k, v = _project_qkv(h, ap, cfg, positions)
            o = L.attention(q, k, v, impl=cfg.attn_impl, causal=True,
                            window=cfg.sliding_window)
            x = x + jnp.einsum("bshk,hkd->bsd", o, ap["wo"].astype(o.dtype))
            x, a = _ffn(x, lp, cfg, _is_moe_layer(cfg, j))
            aux = aux + a
            # cache tail of K/V (ring layout when windowed)
            if s >= s_cache:
                tail_k, tail_v = k[:, s - s_cache:], v[:, s - s_cache:]
                slots = (np.arange(s - s_cache, s) % s_cache)
                ck = jnp.zeros((b, s_cache, *k.shape[2:]), cdtype).at[:, slots].set(
                    tail_k.astype(cdtype))
                cv = jnp.zeros((b, s_cache, *v.shape[2:]), cdtype).at[:, slots].set(
                    tail_v.astype(cdtype))
            else:
                pad = s_cache - s
                ck = jnp.pad(k.astype(cdtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
                cv = jnp.pad(v.astype(cdtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            ks.append(ck)
            vs.append(cv)
        return (x, aux), (jnp.stack(ks), jnp.stack(vs))

    (x, _aux), (k_all, v_all) = lax.scan(_maybe_remat(block, cfg),
                                         (x, jnp.zeros((), jnp.float32)),
                                         params["blocks"])
    # (n_super, il, B, S, K, D) -> (L, B, S, K, D)
    k_all = k_all.reshape(cfg.n_layers, *k_all.shape[2:])
    v_all = v_all.reshape(cfg.n_layers, *v_all.shape[2:])
    x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = _head(params, cfg, x)[:, 0]
    cache = KVCache(k_all, v_all, jnp.asarray(s, jnp.int32))
    return logits, cache


def supports_paged(cfg: ModelConfig) -> bool:
    """True when this config can decode against a global KV page pool.

    Excluded: MoE only (capacity routing mixes tokens across batch rows, so
    a batched paged step would not be bit-independent per slot the way the
    vmapped lane step is). Sliding-window configs page too: their block
    tables are *rings* — ``decode_step_paged`` takes the window, the kernel
    reads ring tables, and the engine recycles pages that fall wholly
    outside the window, so a windowed slot holds O(window/page_size) pages
    (the paged rendition of the lane cache's ring layout).
    """
    return cfg.moe_experts < 2


def paged_pool_init(cfg: ModelConfig, n_pages: int, page_size: int,
                    dtype=jnp.bfloat16):
    """Zeroed global KV page pool: a (k, v) pair of (L, P, ps, Kh, Dh).

    ``n_pages`` includes any null/sentinel pages the caller reserves; the
    pool carries no per-slot structure — block tables impose it per step.
    """
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads,
             cfg.resolved_head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def decode_step_paged(params, cfg: ModelConfig, pool_k, pool_v, tables,
                      lengths, tokens, append_mask=None, impl: str | None = None,
                      window: int | None = None, tp_axis: str | None = None):
    """One serving step against the global page pool (no per-slot lanes).

    tokens (B,) int32; lengths (B,) int32 — positions already resident per
    slot (the new entry lands at ``lengths[b]``); tables (B, NP) int32 page
    ids; pool_k/pool_v (L, P, ps, Kh, Dh). ``append_mask`` (B,) bool gates
    the KV append per slot (False = the lane is idle/stalled and rides the
    batch; its logits are garbage and must be ignored). Returns
    ``(logits (B, V), pool_k', pool_v')`` — pools should be donated.

    ``window`` (defaulting to ``cfg.sliding_window``) switches the block
    tables to **ring** semantics: tables need only
    ``ceil(window/page_size) + 1`` entries, the tail entry wraps, and
    attention covers the last ``window`` positions — bit-identical to the
    lane backend's ring cache. Rope positions stay absolute (``lengths``),
    exactly as the lane decode computes them. Pass an explicit ``window``
    when the serving engine clamps it to the device cache length.

    Every per-slot quantity (rope position, KV length, page chain) is a
    batched vector, so one launch serves ragged slots; the attention itself
    is the fused paged kernel (``repro.kernels.paged_attention``), reading
    K/V in place from the pool through the block table.

    ``tp_axis`` names the mesh axis this step runs tensor-parallel over
    (inside ``shard_map``): params arrive head-sharded (wq/wk/wv slices),
    the pool arena holds this device's KV-head slice, and the per-device
    attention outputs are all-gathered along the head axis right before
    the (replicated) output projection — the step's only collective. Each
    query head's attention touches only its own KV head, so the gathered
    head block is bitwise the single-device one; everything downstream of
    the gather is replicated compute. ``None`` (default) is the
    single-device path, bit-identical by construction.
    """
    from repro.kernels.paged_attention import ops as paged_ops

    if window is None:
        window = cfg.sliding_window
    if impl is None:
        impl = "pallas" if cfg.attn_impl == "pallas" else "ref"
    positions = lengths[:, None]
    x = _embed(params, cfg, tokens[:, None], None, positions)
    il = cfg.moe_interleave if cfg.moe_experts >= 2 else 1
    n_super = cfg.n_layers // il
    pk = pool_k.reshape(n_super, il, *pool_k.shape[1:])
    pv = pool_v.reshape(n_super, il, *pool_v.shape[1:])

    def block(carry, blk_and_pool):
        x, aux = carry
        blk, pk_b, pv_b = blk_and_pool
        pk_o, pv_o = [], []
        for j in range(il):
            lp = blk[f"l{j}"]
            ap = jax.tree.map(lambda a: a.astype(x.dtype), lp["attn"])
            h = L.rmsnorm(x, ap["ln"], cfg.norm_eps)
            q, k, v = _project_qkv(h, ap, cfg, positions)
            o, pk_j, pv_j = paged_ops.paged_decode_append(
                q[:, 0], k[:, 0], v[:, 0], pk_b[j], pv_b[j], tables, lengths,
                append_mask=append_mask, window=window, impl=impl)
            if tp_axis is not None:
                # (B, H/tp, Dh) per device -> (B, H, Dh), heads in mesh
                # order = single-device order; wo is replicated, so the
                # projection below is bitwise the unsharded one
                o = lax.all_gather(o, tp_axis, axis=1, tiled=True)
            x = x + jnp.einsum("bshk,hkd->bsd", o[:, None],
                               ap["wo"].astype(o.dtype))
            x, a = _ffn(x, lp, cfg, _is_moe_layer(cfg, j))
            aux = aux + a
            pk_o.append(pk_j)
            pv_o.append(pv_j)
        return (x, aux), (jnp.stack(pk_o), jnp.stack(pv_o))

    (x, _aux), (pk_new, pv_new) = lax.scan(
        block, (x, jnp.zeros((), jnp.float32)), (params["blocks"], pk, pv))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = _head(params, cfg, x)[:, 0]
    return (logits, pk_new.reshape(pool_k.shape), pv_new.reshape(pool_v.shape))


def decode_step(params, cfg: ModelConfig, cache: KVCache, tokens):
    """One serving step: tokens (B,1) int32 -> (logits (B,V), cache')."""
    b = tokens.shape[0]
    pos = cache.pos
    x = _embed(params, cfg, tokens, None, pos[None, None])
    il = cfg.moe_interleave if cfg.moe_experts >= 2 else 1
    n_super = cfg.n_layers // il
    ck = cache.k.reshape(n_super, il, *cache.k.shape[1:])
    cv = cache.v.reshape(n_super, il, *cache.v.shape[1:])

    def block(carry, blk_and_cache):
        x, aux = carry
        blk, ck_b, cv_b = blk_and_cache
        ck_o, cv_o = [], []
        for j in range(il):
            lp = blk[f"l{j}"]
            ap = jax.tree.map(lambda a: a.astype(x.dtype), lp["attn"])
            x, ck_j, cv_j = _attn_decode(x, ap, cfg, ck_b[j], cv_b[j], pos)
            x, a = _ffn(x, lp, cfg, _is_moe_layer(cfg, j))
            aux = aux + a
            ck_o.append(ck_j)
            cv_o.append(cv_j)
        return (x, aux), (jnp.stack(ck_o), jnp.stack(cv_o))

    (x, _aux), (ck_new, cv_new) = lax.scan(block, (x, jnp.zeros((), jnp.float32)),
                                           (params["blocks"], ck, cv))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = _head(params, cfg, x)[:, 0]
    new_cache = KVCache(ck_new.reshape(cache.k.shape), cv_new.reshape(cache.v.shape),
                        pos + 1)
    return logits, new_cache
