"""Mamba-2 (state-space duality, arXiv:2405.21060) in JAX.

The SSD scan has three backends:
  * ``ref``     — sequential recurrence over time (oracle; O(S) steps);
  * ``chunked`` — the paper's chunk-parallel SSD algorithm (matmul-rich; the
                  TPU-friendly production formulation the dry-run lowers);
  * ``pallas``  — fused chunk kernel via XAIF (:mod:`repro.kernels.ssd`).

State per layer is O(heads × head_dim × state): decode cost is independent of
context length — the long_500k-eligible property.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding import axes as lx
from repro.sharding.params import Axes, ParamDecl

F32 = jnp.float32


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def segsum(x: jax.Array) -> jax.Array:
    """x: (..., l) -> (..., l, l) with out[..., i, j] = sum_{k=j+1..i} x[k]
    (=-inf above the diagonal)."""
    l = x.shape[-1]
    xx = jnp.broadcast_to(x[..., None], (*x.shape, l))  # (..., i, j) holds x[i]
    mask_strict = jnp.tril(jnp.ones((l, l), bool), -1)  # true where j < i
    xx = jnp.where(mask_strict, xx, 0.0)
    out = jnp.cumsum(xx, axis=-2)  # out[i,j] = sum_{k=j+1..i} x[k]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dA, B, C, *, chunk: int, init_state=None,
                compute_dtype=F32):
    """Chunk-parallel SSD. x:(b,s,h,p) pre-scaled by dt; dA:(b,s,h);
    B,C:(b,s,h,n). Returns (y:(b,s,h,p), final_state:(b,h,p,n)).

    ``compute_dtype=bfloat16`` keeps the matmul operands (x, B, C) and the
    emitted y in bf16 (fp32 accumulation via preferred_element_type) — the
    decay math and the carried state stay fp32."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    s_orig = s
    if s % q:
        # pad with identity steps: x=0, dA=0 (decay 1) leaves state untouched
        pad = q - s % q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // q

    cdt = compute_dtype

    def to_chunks(a, dt):
        return jnp.moveaxis(a.astype(dt).reshape(b, nc, q, *a.shape[2:]), 1, 0)

    xc = to_chunks(x, cdt)
    dAc = to_chunks(dA, F32)
    Bc = to_chunks(B, cdt)
    Cc = to_chunks(C, cdt)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), F32)

    # Sequential scan over chunks (mirrors the Pallas kernel): peak memory is
    # ONE chunk's (q × q) decay tile per head instead of all chunks at once.
    def step(state, inp):
        xq, dAq, Bq, Cq = inp                   # (b,q,...) one chunk
        dAq = jnp.moveaxis(dAq, -1, 1)          # (b,h,q)
        a_cs = jnp.cumsum(dAq, axis=-1)         # (b,h,q)
        Lmat = jnp.exp(segsum(dAq)).astype(cdt)  # (b,h,q,q)
        y = jnp.einsum("blhn,bshn,bhls,bshp->blhp", Cq, Bq, Lmat, xq,
                       preferred_element_type=F32)
        # incoming-state contribution
        y = y + jnp.einsum("blhn,bhpn,bhl->blhp", Cq.astype(F32), state,
                           jnp.exp(a_cs))
        # state update
        decay_states = jnp.exp(a_cs[..., -1:] - a_cs)   # (b,h,q)
        new_state = state * jnp.exp(a_cs[..., -1])[..., None, None] \
            + jnp.einsum("blhn,bhl,blhp->bhpn", Bq.astype(F32), decay_states,
                         xq.astype(F32))
        return new_state, y.astype(cdt)

    final_state, ys = lax.scan(step, init_state.astype(F32), (xc, dAc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)[:, :s_orig]
    return y.astype(x.dtype), final_state


def ssd_ref(x, dA, B, C, *, init_state=None, chunk: int = 0):
    """Sequential oracle recurrence."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), F32)

    def step(state, inp):
        x_t, dA_t, B_t, C_t = inp
        state = state * jnp.exp(dA_t.astype(F32))[..., None, None] \
            + jnp.einsum("bhp,bhn->bhpn", x_t.astype(F32), B_t.astype(F32))
        y = jnp.einsum("bhpn,bhn->bhp", state, C_t.astype(F32))
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dA, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    final, ys = lax.scan(step, init_state.astype(F32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


def ssd(x, dA, B, C, *, impl: str, chunk: int, init_state=None,
        compute_dtype=F32):
    if impl == "ref":
        return ssd_ref(x, dA, B, C, init_state=init_state)
    if impl == "chunked":
        return ssd_chunked(x, dA, B, C, chunk=chunk, init_state=init_state,
                           compute_dtype=compute_dtype)
    from repro.core.xaif import REGISTRY

    return REGISTRY.dispatch("ssd", impl, x, dA, B, C, chunk=chunk,
                             init_state=init_state)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def _layer_decls(cfg: ModelConfig) -> dict[str, Any]:
    d, di, h, n, w = (cfg.d_model, cfg.d_inner, cfg.ssm_heads, cfg.ssm_state,
                      cfg.ssm_conv_width)
    return {
        "ln": L.rmsnorm_decl(d),
        "w_z": ParamDecl((d, di), Axes(lx.EMBED, lx.RNN_WIDTH), init="fan_in"),
        "w_x": ParamDecl((d, di), Axes(lx.EMBED, lx.RNN_WIDTH), init="fan_in"),
        "w_B": ParamDecl((d, n), Axes(lx.EMBED, lx.STATE), init="fan_in"),
        "w_C": ParamDecl((d, n), Axes(lx.EMBED, lx.STATE), init="fan_in"),
        "w_dt": ParamDecl((d, h), Axes(lx.EMBED, lx.HEADS), init="fan_in"),
        "conv_x": L.conv1d_decl(w, di),
        "conv_B": ParamDecl((w, n), Axes(lx.CONV, lx.STATE), init="fan_in"),
        "conv_C": ParamDecl((w, n), Axes(lx.CONV, lx.STATE), init="fan_in"),
        "A_log": ParamDecl((h,), Axes(lx.HEADS), init="zeros"),
        "D": ParamDecl((h,), Axes(lx.HEADS), init="ones"),
        "dt_bias": ParamDecl((h,), Axes(lx.HEADS), init="zeros"),
        "ln_gate": ParamDecl((di,), Axes(lx.RNN_WIDTH), init="ones"),
        "w_out": ParamDecl((di, d), Axes(lx.RNN_WIDTH, lx.EMBED), init="fan_in"),
    }


def decls(cfg: ModelConfig) -> dict[str, Any]:
    from repro.sharding.params import stack_tree

    tree: dict[str, Any] = {
        "embed": L.embed_decl(cfg),
        "blocks": stack_tree(_layer_decls(cfg), cfg.n_layers, lx.LAYERS),
        "ln_f": L.rmsnorm_decl(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        tree["head"] = L.head_decl(cfg)
    return tree


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMCache:
    conv_x: jax.Array   # (L, B, W-1, d_inner)
    conv_B: jax.Array   # (L, B, W-1, n)
    conv_C: jax.Array   # (L, B, W-1, n)
    state: jax.Array    # (L, B, H, P, N)
    pos: jax.Array

    @staticmethod
    def _shapes(cfg: ModelConfig, batch: int):
        w = cfg.ssm_conv_width
        return (
            (cfg.n_layers, batch, w - 1, cfg.d_inner),
            (cfg.n_layers, batch, w - 1, cfg.ssm_state),
            (cfg.n_layers, batch, w - 1, cfg.ssm_state),
            (cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
        )

    @staticmethod
    def init(cfg, batch, max_len=None, dtype=jnp.bfloat16) -> "SSMCache":
        s = SSMCache._shapes(cfg, batch)
        return SSMCache(jnp.zeros(s[0], dtype), jnp.zeros(s[1], dtype),
                        jnp.zeros(s[2], dtype), jnp.zeros(s[3], jnp.float32),
                        jnp.zeros((), jnp.int32))

    @staticmethod
    def abstract(cfg, batch, max_len=None, dtype=jnp.bfloat16) -> "SSMCache":
        s = SSMCache._shapes(cfg, batch)
        return SSMCache(jax.ShapeDtypeStruct(s[0], dtype),
                        jax.ShapeDtypeStruct(s[1], dtype),
                        jax.ShapeDtypeStruct(s[2], dtype),
                        jax.ShapeDtypeStruct(s[3], jnp.float32),
                        jax.ShapeDtypeStruct((), jnp.int32))

    @staticmethod
    def axes() -> "SSMCache":
        return SSMCache(
            Axes(lx.LAYERS, lx.DECODE_BATCH, None, lx.RNN_WIDTH),
            Axes(lx.LAYERS, lx.DECODE_BATCH, None, lx.STATE),
            Axes(lx.LAYERS, lx.DECODE_BATCH, None, lx.STATE),
            Axes(lx.LAYERS, lx.DECODE_BATCH, lx.HEADS, lx.HEAD_DIM, lx.STATE),
            Axes(),
        )


def _mix(x, lp, cfg: ModelConfig):
    """Shared projection stage. Returns z, xs (pre-scaled), dA, B, C, dt."""
    h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
    z = h @ lp["w_z"].astype(h.dtype)
    xin = h @ lp["w_x"].astype(h.dtype)
    Braw = h @ lp["w_B"].astype(h.dtype)
    Craw = h @ lp["w_C"].astype(h.dtype)
    dt_raw = h @ lp["w_dt"].astype(h.dtype)
    return z, xin, Braw, Craw, dt_raw


def _ssm_math(xin, Braw, Craw, dt_raw, lp, cfg: ModelConfig):
    b, s = xin.shape[:2]
    hn, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    dt = jax.nn.softplus(dt_raw.astype(F32) + lp["dt_bias"].astype(F32))  # (b,s,h)
    A = -jnp.exp(lp["A_log"].astype(F32))                                  # (h,)
    dA = dt * A
    xh = xin.reshape(b, s, hn, p)
    xs = xh.astype(F32) * dt[..., None]
    Bh = jnp.broadcast_to(Braw[:, :, None, :], (b, s, hn, n))
    Ch = jnp.broadcast_to(Craw[:, :, None, :], (b, s, hn, n))
    return xh, xs, dA, Bh, Ch


def _block_train(x, lp, cfg: ModelConfig):
    z, xin, Braw, Craw, dt_raw = _mix(x, lp, cfg)
    xin, _ = L.causal_conv1d(jax.nn.silu(xin), lp["conv_x"].astype(xin.dtype))
    Braw, _ = L.causal_conv1d(jax.nn.silu(Braw), lp["conv_B"].astype(Braw.dtype))
    Craw, _ = L.causal_conv1d(jax.nn.silu(Craw), lp["conv_C"].astype(Craw.dtype))
    xh, xs, dA, Bh, Ch = _ssm_math(xin, Braw, Craw, dt_raw, lp, cfg)
    y, _ = ssd(xs, dA, Bh, Ch, impl=cfg.scan_impl, chunk=cfg.ssm_chunk,
               compute_dtype=jnp.dtype(cfg.ssm_compute_dtype))
    y = y + xh.astype(F32) * lp["D"].astype(F32)[None, None, :, None]
    y = y.reshape(*x.shape[:2], cfg.d_inner)
    y = L.rmsnorm(y.astype(x.dtype) * jax.nn.silu(z), lp["ln_gate"], cfg.norm_eps)
    return x + y @ lp["w_out"].astype(y.dtype)


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None):
    x = params["embed"].astype(jnp.bfloat16)[tokens] if embeds is None else embeds

    def body(carry, lp):
        return _block_train(carry, jax.tree.map(lambda a: a, lp), cfg), None

    from repro.models.transformer import _maybe_remat

    x, _ = lax.scan(_maybe_remat(body, cfg), x, params["blocks"])
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return L.lm_head(x, params, cfg), jnp.zeros((), F32)


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, max_len=None):
    """Prompt pass producing the SSM cache (final conv tails + states)."""
    x = params["embed"].astype(jnp.bfloat16)[tokens] if embeds is None else embeds
    b, s = x.shape[:2]
    w = cfg.ssm_conv_width

    def body(carry, lp):
        xc = carry
        z, xin, Braw, Craw, dt_raw = _mix(xc, lp, cfg)
        xin_a, Braw_a, Craw_a = (jax.nn.silu(xin), jax.nn.silu(Braw), jax.nn.silu(Craw))
        conv_tails = (xin_a[:, -(w - 1):], Braw_a[:, -(w - 1):], Craw_a[:, -(w - 1):])
        xin_c, _ = L.causal_conv1d(xin_a, lp["conv_x"].astype(xin.dtype))
        Braw_c, _ = L.causal_conv1d(Braw_a, lp["conv_B"].astype(Braw.dtype))
        Craw_c, _ = L.causal_conv1d(Craw_a, lp["conv_C"].astype(Craw.dtype))
        xh, xs, dA, Bh, Ch = _ssm_math(xin_c, Braw_c, Craw_c, dt_raw, lp, cfg)
        y, st = ssd(xs, dA, Bh, Ch, impl=cfg.scan_impl, chunk=cfg.ssm_chunk)
        y = y + xh.astype(F32) * lp["D"].astype(F32)[None, None, :, None]
        y = y.reshape(b, s, cfg.d_inner)
        y = L.rmsnorm(y.astype(xc.dtype) * jax.nn.silu(z), lp["ln_gate"], cfg.norm_eps)
        return xc + y @ lp["w_out"].astype(y.dtype), (conv_tails, st)

    body_fn = body if cfg.remat == "none" else jax.checkpoint(body)
    x, (tails, states) = lax.scan(body_fn, x, params["blocks"])
    xf = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    logits = L.lm_head(xf, params, cfg)[:, 0]
    cache = SSMCache(tails[0].astype(jnp.bfloat16), tails[1].astype(jnp.bfloat16),
                     tails[2].astype(jnp.bfloat16), states.astype(F32),
                     jnp.asarray(s, jnp.int32))
    return logits, cache


def decode_step(params, cfg: ModelConfig, cache: SSMCache, tokens):
    """tokens: (B,1) -> (logits (B,V), cache')."""
    x = params["embed"].astype(jnp.bfloat16)[tokens]

    def body(carry, inp):
        xc = carry
        lp, cx, cB, cC, st = inp
        z, xin, Braw, Craw, dt_raw = _mix(xc, lp, cfg)
        xin_c, cx2 = L.causal_conv1d(jax.nn.silu(xin), lp["conv_x"].astype(xin.dtype), cx)
        Braw_c, cB2 = L.causal_conv1d(jax.nn.silu(Braw), lp["conv_B"].astype(Braw.dtype), cB)
        Craw_c, cC2 = L.causal_conv1d(jax.nn.silu(Craw), lp["conv_C"].astype(Craw.dtype), cC)
        xh, xs, dA, Bh, Ch = _ssm_math(xin_c, Braw_c, Craw_c, dt_raw, lp, cfg)
        # single-step recurrence
        x_t, dA_t, B_t, C_t = xs[:, 0], dA[:, 0], Bh[:, 0], Ch[:, 0]
        st2 = st * jnp.exp(dA_t)[..., None, None] \
            + jnp.einsum("bhp,bhn->bhpn", x_t, B_t)
        y = jnp.einsum("bhpn,bhn->bhp", st2, C_t)[:, None]
        y = y + xh.astype(F32) * lp["D"].astype(F32)[None, None, :, None]
        y = y.reshape(xc.shape[0], 1, cfg.d_inner)
        y = L.rmsnorm(y.astype(xc.dtype) * jax.nn.silu(z), lp["ln_gate"], cfg.norm_eps)
        return xc + y @ lp["w_out"].astype(y.dtype), (cx2, cB2, cC2, st2)

    x, (cx, cB, cC, st) = lax.scan(
        body, x, (params["blocks"], cache.conv_x, cache.conv_B, cache.conv_C,
                  cache.state))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = L.lm_head(x, params, cfg)[:, 0]
    return logits, SSMCache(cx, cB, cC, st, cache.pos + 1)
