"""Shared neural layers, declared with ParamDecl and written for GSPMD.

Execution-backend note (the platform's ``core`` choice):
  * ``ref``     — naive formulations; the correctness oracle family.
  * ``chunked`` — two-level-blocked online-softmax attention and scan-based
                  recurrences; the HBM-friendly pure-JAX production path that
                  the dry-run lowers (flash-attention structure, without the
                  S² score materialization).
  * ``pallas``  — TPU kernels from :mod:`repro.kernels` plugged in via XAIF.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding import axes as lx
from repro.sharding.params import Axes, ParamDecl

F32 = jnp.float32

# ---------------------------------------------------------------------------
# Norms / embeddings / positional
# ---------------------------------------------------------------------------


def rmsnorm_decl(d: int) -> ParamDecl:
    return ParamDecl((d,), Axes(lx.EMBED), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * scale.astype(F32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=F32) / half)
    ang = positions.astype(F32)[..., None] * freqs  # (..., seq, half)
    ang = ang[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(1e4) * jnp.arange(half, dtype=F32) / half)
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention — ref / chunked(two-level flash-structured) / banded-local
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_fold(q: jax.Array, n_kv: int) -> jax.Array:
    """(B,S,H,D) -> (B,S,K,G,D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  q_offset: int = 0, kv_len: jax.Array | None = None) -> jax.Array:
    """Naive full-score oracle. q:(B,Sq,H,D) k,v:(B,Sk,K,D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    nkv = k.shape[2]
    qf = _gqa_fold(q, nkv).astype(F32)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qf, k.astype(F32)) / math.sqrt(d)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(F32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


def _blk_mask(qpos, kpos, causal, window, kv_limit):
    mask = kpos[None, :] < kv_limit
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window is not None:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)
    return mask  # (qb, kb)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash(q, k, v, kv_limit, causal, window, q_offset, q_block, kv_block):
    out, _ = _flash_fwd_impl(q, k, v, kv_limit, causal, window, q_offset,
                             q_block, kv_block)
    return out


def _flash_fwd_impl(q, k, v, kv_limit, causal, window, q_offset, q_block, kv_block):
    """Returns (out (B,Sq,H,D), lse (B,K,G,Sq_pad)). Only O(S·D) live memory:
    the FlashAttention forward, expressed as a two-level lax.scan."""
    b, sq, h, d = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    qb, kb = min(q_block, sq), min(kv_block, sk)
    sq_p, sk_p = -(-sq // qb) * qb, -(-sk // kb) * kb
    qf = _gqa_fold(jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0))), nkv)
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(d)
    g = h // nkv
    n_q, n_k = sq_p // qb, sk_p // kb

    def q_step(_, qi):
        qblk = lax.dynamic_slice_in_dim(qf, qi * qb, qb, axis=1).astype(F32)
        qpos = q_offset + qi * qb + jnp.arange(qb)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = lax.dynamic_slice_in_dim(kp, ki * kb, kb, axis=1)
            vblk = lax.dynamic_slice_in_dim(vp, ki * kb, kb, axis=1)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk.astype(F32)) * scale
            kpos = ki * kb + jnp.arange(kb)
            mask = _blk_mask(qpos, kpos, causal, window, kv_limit)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p, vblk.astype(F32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, nkv, g, qb), NEG_INF, F32)
        l0 = jnp.zeros((b, nkv, g, qb), F32)
        a0 = jnp.zeros((b, nkv, g, qb, d), F32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_k))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))        # (B,K,G,qb)
        return None, (o.transpose(0, 3, 1, 2, 4), lse)

    _, (blocks, lses) = lax.scan(q_step, None, jnp.arange(n_q))
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq_p, h, d)
    lse = jnp.moveaxis(lses, 0, -2).reshape(b, nkv, g, sq_p)  # (B,K,G,n_q*qb)
    return out[:, :sq].astype(q.dtype), lse


def _flash_fwd(q, k, v, kv_limit, causal, window, q_offset, q_block, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, kv_limit, causal, window, q_offset,
                               q_block, kv_block)
    return out, (q, k, v, out, lse, kv_limit)


def _flash_bwd(causal, window, q_offset, q_block, kv_block, res, do):
    """FlashAttention backward: recompute score tiles — nothing O(S²) stored."""
    q, k, v, out, lse, kv_limit = res
    b, sq, h, d = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    qb, kb = min(q_block, sq), min(kv_block, sk)
    sq_p, sk_p = -(-sq // qb) * qb, -(-sk // kb) * kb
    scale = 1.0 / math.sqrt(d)
    g = h // nkv
    n_q, n_k = sq_p // qb, sk_p // kb

    pad_q = ((0, 0), (0, sq_p - sq), (0, 0), (0, 0))
    pad_k = ((0, 0), (0, sk_p - sk), (0, 0), (0, 0))
    qf = _gqa_fold(jnp.pad(q, pad_q), nkv).astype(F32)       # (B,Sqp,K,G,D)
    kp = jnp.pad(k, pad_k).astype(F32)
    vp = jnp.pad(v, pad_k).astype(F32)
    dof = _gqa_fold(jnp.pad(do.astype(F32), pad_q), nkv)
    of = _gqa_fold(jnp.pad(out.astype(F32), pad_q), nkv)
    delta = jnp.sum(dof * of, axis=-1)                        # (B,Sqp,K,G)
    delta = delta.transpose(0, 2, 3, 1)                       # (B,K,G,Sqp)

    def kv_step(dq_acc, ki):
        kblk = lax.dynamic_slice_in_dim(kp, ki * kb, kb, axis=1)
        vblk = lax.dynamic_slice_in_dim(vp, ki * kb, kb, axis=1)
        kpos = ki * kb + jnp.arange(kb)

        def q_step(carry, qi):
            dk_b, dv_b = carry
            qblk = lax.dynamic_slice_in_dim(qf, qi * qb, qb, axis=1)
            doblk = lax.dynamic_slice_in_dim(dof, qi * qb, qb, axis=1)
            lseblk = lax.dynamic_slice_in_dim(lse, qi * qb, qb, axis=3)
            dltblk = lax.dynamic_slice_in_dim(delta, qi * qb, qb, axis=3)
            qpos = q_offset + qi * qb + jnp.arange(qb)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk) * scale
            mask = _blk_mask(qpos, kpos, causal, window, kv_limit)
            p = jnp.where(mask[None, None, None],
                          jnp.exp(s - lseblk[..., None]), 0.0)   # (B,K,G,qb,kb)
            dv_b = dv_b + jnp.einsum("bkgqc,bqkgd->bckd", p, doblk)
            dp = jnp.einsum("bqkgd,bckd->bkgqc", doblk, vblk)
            ds = p * (dp - dltblk[..., None]) * scale
            dq_blk = jnp.einsum("bkgqc,bckd->bqkgd", ds, kblk)
            dk_b = dk_b + jnp.einsum("bkgqc,bqkgd->bckd", ds, qblk)
            return (dk_b, dv_b), (qi, dq_blk)

        dk0 = jnp.zeros((b, kb, nkv, d), F32)
        dv0 = jnp.zeros((b, kb, nkv, d), F32)
        (dk_b, dv_b), (_, dq_blocks) = lax.scan(q_step, (dk0, dv0),
                                                jnp.arange(n_q))
        # dq_blocks: (n_q, B, qb, K, G, D) -> add into accumulator
        dq_add = dq_blocks.transpose(1, 0, 2, 3, 4, 5).reshape(
            b, sq_p, nkv, g, d)
        return dq_acc + dq_add, (dk_b, dv_b)

    dq0 = jnp.zeros((b, sq_p, nkv, g, d), F32)
    dq_acc, (dk_blocks, dv_blocks) = lax.scan(kv_step, dq0, jnp.arange(n_k))
    dq = dq_acc.reshape(b, sq_p, h, d)[:, :sq].astype(q.dtype)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(b, sk_p, nkv, d)[:, :sk].astype(k.dtype)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(b, sk_p, nkv, d)[:, :sk].astype(v.dtype)
    return dq, dk, dv, None


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention_chunked(q, k, v, *, causal: bool = True, window: int | None = None,
                      q_offset: int = 0, kv_len: jax.Array | None = None,
                      q_block: int = 512, kv_block: int = 512) -> jax.Array:
    """Flash-structured attention in pure JAX with a flash BACKWARD
    (custom_vjp): neither pass materializes O(S²) score state."""
    kv_limit = jnp.asarray(k.shape[1] if kv_len is None else kv_len, jnp.int32)
    return _flash(q, k, v, kv_limit, causal, window, q_offset, q_block, kv_block)


def attention_banded(q, k, v, *, window: int, q_block: int = 512,
                     q_offset: int = 0) -> jax.Array:
    """Causal sliding-window attention with banded compute: each q block only
    touches a (window + q_block) KV stripe — O(S·W) FLOPs, the sub-quadratic
    path that makes long_500k prefill lowering feasible for SWA archs."""
    b, sq, h, d = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    qb = min(q_block, sq)
    sq_p = -(-sq // qb) * qb
    stripe = window + qb
    # left-pad KV by `window` so every stripe slice is in-bounds
    kp = jnp.pad(k, ((0, 0), (window, sq_p - sq), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, sq_p - sq), (0, 0), (0, 0)))
    qf = _gqa_fold(jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0))), nkv)
    scale = 1.0 / math.sqrt(d)
    g = h // nkv
    n_q = sq_p // qb

    def q_step(_, qi):
        qblk = lax.dynamic_slice_in_dim(qf, qi * qb, qb, axis=1).astype(F32)
        kblk = lax.dynamic_slice_in_dim(kp, qi * qb, stripe, axis=1)
        vblk = lax.dynamic_slice_in_dim(vp, qi * qb, stripe, axis=1)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk.astype(F32)) * scale
        qpos = qi * qb + jnp.arange(qb)          # absolute (unpadded) positions
        kpos = qi * qb + jnp.arange(stripe) - window
        mask = (qpos[:, None] >= kpos[None, :]) & (qpos[:, None] - kpos[None, :] < window)
        mask &= kpos[None, :] >= 0
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqc,bckd->bqkgd", p, vblk.astype(F32))
        return None, o

    _, blocks = lax.scan(q_step, None, jnp.arange(n_q))
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq_p, h, d)
    return out[:, :sq].astype(q.dtype)


_ATTN_IMPLS = {}


def attention(q, k, v, *, impl: str = "chunked", causal: bool = True,
              window: int | None = None, q_offset: int = 0,
              kv_len=None, repeat_kv: bool | None = None) -> jax.Array:
    """Dispatch point for the attention op (XAIF-pluggable).

    ``repeat_kv``: materialize KV to the full head count before the score
    matmuls. Default on for multi-token passes — it keeps the head axis
    cleanly tensor-parallel (no per-layer resharding when kv_heads doesn't
    divide the model axis); decode keeps the grouped layout (cache size wins).
    """
    if repeat_kv is None:
        repeat_kv = q.shape[1] > 1
    if repeat_kv and k.shape[2] != q.shape[2]:
        g = q.shape[2] // k.shape[2]
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    if impl == "ref":
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, kv_len=kv_len)
    if impl == "chunked":
        if window is not None and causal and q.shape[1] > 1 and kv_len is None \
                and q.shape[1] == k.shape[1]:
            return attention_banded(q, k, v, window=window, q_offset=q_offset)
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, kv_len=kv_len)
    if impl in _ATTN_IMPLS:
        return _ATTN_IMPLS[impl](q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, kv_len=kv_len)
    from repro.core.xaif import REGISTRY  # late import: plug-ins register at import

    return REGISTRY.dispatch("attention", impl, q, k, v, causal=causal,
                             window=window, q_offset=q_offset, kv_len=kv_len)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def mlp_decls(d: int, f: int, kind: str) -> dict[str, ParamDecl]:
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": ParamDecl((d, f), Axes(lx.EMBED, lx.MLP), init="fan_in"),
            "w_up": ParamDecl((d, f), Axes(lx.EMBED, lx.MLP), init="fan_in"),
            "w_down": ParamDecl((f, d), Axes(lx.MLP, lx.EMBED), init="fan_in"),
        }
    return {  # gelu / squared_relu: plain 2-matrix MLP
        "w_up": ParamDecl((d, f), Axes(lx.EMBED, lx.MLP), init="fan_in"),
        "w_down": ParamDecl((f, d), Axes(lx.MLP, lx.EMBED), init="fan_in"),
    }


def mlp(x: jax.Array, p: dict[str, jax.Array], kind: str) -> jax.Array:
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_up"]
    if kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown mlp kind {kind}")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts — capacity-grouped dropless-ish dispatch
# ---------------------------------------------------------------------------


def moe_decls(d: int, f: int, n_exp: int, kind: str = "swiglu",
              shared: bool = False) -> dict[str, Any]:
    def e(shape, ax):
        return ParamDecl((n_exp, *shape), Axes(lx.EXPERT, *ax), init="fan_in")

    decls: dict[str, Any] = {
        "router": ParamDecl((d, n_exp), Axes(lx.EMBED, None), init="fan_in"),
        "w_gate": e((d, f), (lx.EMBED, lx.MLP)),
        "w_up": e((d, f), (lx.EMBED, lx.MLP)),
        "w_down": e((f, d), (lx.MLP, lx.EMBED)),
    }
    if shared:
        decls["shared"] = mlp_decls(d, f, kind)
    return decls


def _expert_ffn(xg: jax.Array, p: dict[str, jax.Array], kind: str) -> jax.Array:
    """xg: (E, C, d) -> (E, C, d); experts batched on dim 0 (EP-shardable)."""
    gate = jnp.einsum("ecd,edf->ecf", xg, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xg, p["w_up"])
    act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
    return jnp.einsum("ecf,efd->ecd", act * up, p["w_down"])


# Optional sharding constraint on the dispatched (E, capacity, d_model)
# buffer. Setting it to e.g. PartitionSpec("model", "data", None) gives
# expert-parallel dispatch with the capacity dim data-sharded: expert-FFN
# contractions stay local and the scatter-back lowers to all-to-all instead
# of partial-sum all-reduces (EXPERIMENTS.md §Perf G5).
MOE_DISPATCH_SPEC = None


def set_moe_dispatch_spec(spec) -> None:
    global MOE_DISPATCH_SPEC
    MOE_DISPATCH_SPEC = spec


def moe(x: jax.Array, p: dict[str, Any], *, n_exp: int, top_k: int,
        capacity_factor: float = 1.25, kind: str = "swiglu",
        impl: str = "chunked") -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss). x: (B, S, d).

    Dispatch is sort-based with a static per-expert capacity: tokens beyond
    capacity are dropped (their slot contributes nothing) — GShard semantics.
    Unrouted experts do no useful work; under expert-parallel sharding this is
    the MoE rendition of X-HEEP power-gating: a domain with no activity.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt.astype(F32) @ p["router"].astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = lax.top_k(probs, top_k)             # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                           # (E,)
    ce = jnp.zeros((n_exp,), F32).at[eidx.reshape(-1)].add(
        jnp.ones((t * top_k,), F32)) / (t * top_k)
    aux = n_exp * jnp.sum(me * ce)

    cap = int(max(8, -(-int(t * top_k * capacity_factor / n_exp) // 8) * 8))
    cap = min(cap, t)

    slot_e = eidx.reshape(-1)                         # (T*k,)
    slot_g = gates.reshape(-1)
    order = jnp.argsort(slot_e)                       # stable
    sorted_e = slot_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(n_exp))
    pos = jnp.arange(t * top_k) - seg_start[sorted_e]
    keep = pos < cap
    dest = sorted_e * cap + pos                       # (T*k,) flat slot id
    tok = order // top_k                              # token of each sorted slot

    # gather tokens into (E, cap, d); sentinel row t -> zeros
    buf = jnp.full((n_exp * cap,), t, jnp.int32)
    buf = buf.at[jnp.where(keep, dest, n_exp * cap)].set(
        tok.astype(jnp.int32), mode="drop")
    xg = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)])[buf]
    xg = xg.reshape(n_exp, cap, d)
    if MOE_DISPATCH_SPEC is not None:
        xg = lax.with_sharding_constraint(xg, MOE_DISPATCH_SPEC)

    if impl == "pallas":
        from repro.core.xaif import REGISTRY

        hg = REGISTRY.dispatch("moe_ffn", "pallas", xg, p, kind)
    else:
        hg = _expert_ffn(xg, p, kind)

    h_flat = hg.reshape(n_exp * cap, d)
    slot_out = h_flat[jnp.where(keep, dest, 0)]
    w = (slot_g[order] * keep).astype(F32)[:, None]
    out = jnp.zeros((t + 1, d), F32).at[tok].add(slot_out.astype(F32) * w)[:-1]
    out = out.astype(x.dtype).reshape(b, s, d)
    if "shared" in p:
        out = out + mlp(x, p["shared"], kind)
    return out, aux


def moe_dense_ref(x, p, *, n_exp, top_k, kind="swiglu"):
    """Oracle: computes every expert densely then mixes. O(E) FLOPs."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = xt.astype(F32) @ p["router"].astype(F32)
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    full = jnp.zeros((b * s, n_exp), F32)
    full = jax.vmap(lambda f, g, i: f.at[i].set(g))(full, gates, eidx)
    outs = _expert_ffn(jnp.broadcast_to(xt, (n_exp, b * s, d)).transpose(0, 1, 2), p, kind)
    out = jnp.einsum("te,etd->td", full, outs.astype(F32))
    if "shared" in p:
        out = out + mlp(x, p["shared"], kind).reshape(b * s, d).astype(F32)
    return out.astype(x.dtype).reshape(b, s, d)


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (mamba2 / griffin temporal conv)
# ---------------------------------------------------------------------------


def conv1d_decl(width: int, channels: int) -> ParamDecl:
    return ParamDecl((width, channels), Axes(lx.CONV, lx.RNN_WIDTH), init="fan_in")


def causal_conv1d(x: jax.Array, w: jax.Array,
                  state: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,D), w: (W,D). Returns (y, new_state); state: (B,W-1,D)."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    y = sum(xx[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xx[:, -(width - 1):] if width > 1 else state
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# LM head (shared across families; handles tying + vocab padding)
# ---------------------------------------------------------------------------


def embed_decl(cfg) -> "ParamDecl":
    return ParamDecl((cfg.padded_vocab, cfg.d_model), Axes("vocab_in", lx.EMBED),
                     init="normal", scale=0.02)


def head_decl(cfg) -> "ParamDecl":
    return ParamDecl((cfg.d_model, cfg.padded_vocab), Axes(lx.EMBED, lx.VOCAB),
                     init="fan_in")


def lm_head(x: jax.Array, params, cfg) -> jax.Array:
    """x: (..., d_model) -> logits (..., padded_vocab) with padding masked."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["head"].astype(x.dtype))
    if cfg.padded_vocab != cfg.vocab:
        iota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab, logits,
                           jnp.asarray(NEG_INF, logits.dtype))
    return logits


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0) -> jax.Array:
    """TPU-friendly CE over a (possibly vocab-sharded) last axis: uses an
    iota-compare select instead of gather/one-hot so GSPMD reduces locally."""
    logits = logits.astype(F32)
    m = lax.stop_gradient(logits.max(-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    iota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.sum(jnp.where(iota == labels[..., None], shifted, 0.0), axis=-1)
    picked = picked + m[..., 0]
    loss = lse - picked
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss
