"""Family dispatch: decls/forward/prefill/decode for any ModelConfig."""

from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.models import griffin, mamba2, transformer
from repro.models.config import ModelConfig

_TRANSFORMER_FAMILIES = ("dense", "moe", "audio", "vlm")


def _mod(cfg: ModelConfig):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer
    if cfg.family == "ssm":
        return mamba2
    if cfg.family == "hybrid":
        return griffin
    raise ValueError(f"unknown family {cfg.family}")


def decls(cfg: ModelConfig):
    return _mod(cfg).decls(cfg)


def forward(params, cfg: ModelConfig, tokens=None, embeds=None, positions=None):
    return _mod(cfg).forward(params, cfg, tokens=tokens, embeds=embeds,
                             positions=positions)


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, max_len=None):
    return _mod(cfg).prefill(params, cfg, tokens=tokens, embeds=embeds,
                             max_len=max_len)


def decode_step(params, cfg: ModelConfig, cache, tokens):
    return _mod(cfg).decode_step(params, cfg, cache, tokens)


def supports_paged(cfg: ModelConfig) -> bool:
    """True when the family can decode against a global KV page pool
    (transformer-family KV caches only; see transformer.supports_paged)."""
    if cfg.family not in _TRANSFORMER_FAMILIES:
        return False
    return transformer.supports_paged(cfg)


def _require_paged(cfg: ModelConfig) -> None:
    # fail loudly, like the rest of the registry: a transformer-shaped KV
    # pool built from an SSM/Griffin config would be silently wrong
    if not supports_paged(cfg):
        raise ValueError(f"{cfg.name} ({cfg.family}) has no paged KV decode")


def paged_pool_init(cfg: ModelConfig, n_pages: int, page_size: int):
    _require_paged(cfg)
    return transformer.paged_pool_init(cfg, n_pages, page_size)


def decode_step_paged(params, cfg: ModelConfig, pool_k, pool_v, tables,
                      lengths, tokens, append_mask=None, impl=None,
                      window=None, tp_axis=None):
    _require_paged(cfg)
    return transformer.decode_step_paged(params, cfg, pool_k, pool_v, tables,
                                         lengths, tokens,
                                         append_mask=append_mask, impl=impl,
                                         window=window, tp_axis=tp_axis)


def cache_abstract(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.KVCache.abstract(cfg, batch, max_len)
    if cfg.family == "ssm":
        return mamba2.SSMCache.abstract(cfg, batch, max_len)
    return griffin.GriffinCache.abstract(cfg, batch, max_len)


def cache_init(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.KVCache.init(cfg, batch, max_len)
    if cfg.family == "ssm":
        return mamba2.SSMCache.init(cfg, batch, max_len)
    return griffin.GriffinCache.init(cfg, batch, max_len)


def cache_axes(cfg: ModelConfig):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.KVCache.axes()
    if cfg.family == "ssm":
        return mamba2.SSMCache.axes()
    return griffin.GriffinCache.axes()


def uses_token_inputs(cfg: ModelConfig) -> bool:
    """False for modality stubs whose train/prefill inputs are embeddings."""
    return cfg.embed_inputs
