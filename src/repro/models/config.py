"""ModelConfig — one dataclass covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int | None = None
    sliding_window: int | None = None      # SWA window (None = global attention)
    # mlp
    d_ff: int = 0
    mlp_type: str = "swiglu"               # swiglu|geglu|gelu|squared_relu
    # embeddings
    pos_emb: str = "rope"                  # rope|sinusoidal|none
    rope_theta: float = 1e4
    embed_inputs: bool = True              # False: modality stub feeds embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 1
    moe_interleave: int = 1                # MoE every k-th layer (1 = all)
    moe_shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_compute_dtype: str = "float32"   # bfloat16: halve SSD scan traffic
    # hybrid (recurrentgemma / griffin)
    rnn_width: int | None = None
    attn_window: int | None = None         # local-attention window in hybrid
    block_pattern: tuple[str, ...] = ()    # e.g. ("rec","rec","attn")
    rglru_c: float = 8.0
    # vocab padding: round embedding/head vocab up to this multiple so the
    # vocab axis stays shardable (e.g. 49155 -> 49408 with pad 128*k); padded
    # logits are masked to -inf in the head. 0 disables.
    vocab_pad_multiple: int = 0
    # numerics / execution
    dtype: str = "bfloat16"
    attn_impl: str = "chunked"             # ref|chunked|pallas
    scan_impl: str = "chunked"             # ssd/rglru backend
    moe_impl: str = "chunked"
    remat: str = "full"                    # none|full|dots
    q_block: int = 512
    kv_block: int = 512

    def __post_init__(self):
        if self.family in ("dense", "moe", "audio", "vlm", "hybrid"):
            if self.n_heads and self.n_heads % max(self.n_kv_heads, 1):
                raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.family == "moe" and self.moe_experts < 2:
            raise ValueError("moe family needs moe_experts >= 2")

    @property
    def padded_vocab(self) -> int:
        if not self.vocab_pad_multiple:
            return self.vocab
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def is_subquadratic(self) -> bool:
        """True if prefill/decode cost does not grow quadratically without
        bound in sequence length — the long_500k eligibility test."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True  # RG-LRU + windowed local attention
        return self.sliding_window is not None

    def param_count(self) -> int:
        from repro.models import registry
        from repro.sharding.params import count_params

        return count_params(registry.decls(self))

    def active_param_count(self) -> int:
        """Active-per-token params (differs from total only for MoE)."""
        if self.family != "moe":
            return self.param_count()
        total = self.param_count()
        n_moe_layers = len([i for i in range(self.n_layers)
                            if i % self.moe_interleave == self.moe_interleave - 1])
        per_expert = 3 * self.d_model * self.d_ff
        inactive = n_moe_layers * per_expert * (self.moe_experts - self.moe_top_k)
        return total - inactive
