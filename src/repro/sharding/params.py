"""Parameter declaration: one source of truth for shape, logical axes, init.

A model is declared as a pytree of :class:`ParamDecl`; from that single tree we
derive (a) materialized parameters, (b) the logical-axes tree used by the rule
engine, and (c) abstract ShapeDtypeStructs for the dry-run. This guarantees
the three views can never drift apart.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class Axes:
    """Logical axis names of one array. Deliberately NOT a pytree container so
    axes trees keep the same treedef as parameter trees."""

    __slots__ = ("dims",)

    def __init__(self, *dims: str | None):
        if len(dims) == 1 and isinstance(dims[0], (tuple, list)):
            dims = tuple(dims[0])
        self.dims = tuple(dims)

    def __iter__(self):
        return iter(self.dims)

    def __len__(self):
        return len(self.dims)

    def __getitem__(self, i):
        return self.dims[i]

    def __eq__(self, other):
        return isinstance(other, Axes) and self.dims == other.dims

    def __hash__(self):
        return hash(self.dims)

    def __repr__(self):
        return f"Axes{self.dims}"

    def prepend(self, name: str | None) -> "Axes":
        return Axes(name, *self.dims)


def is_axes(x) -> bool:
    return isinstance(x, Axes)


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    """Declaration of a single parameter tensor."""

    shape: tuple[int, ...]
    axes: Axes
    init: str = "normal"       # normal | zeros | ones | scaled(normal/fan_in) | embed
    scale: float | None = None  # explicit std for "normal"
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"decl rank mismatch: {self.shape} vs {self.axes}")

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "normal":
            std = self.scale if self.scale is not None else 0.02
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(self.dtype)
        if self.init == "fan_in":
            fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
            std = 1.0 / math.sqrt(max(fan_in, 1))
            return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(self.dtype)
        raise ValueError(f"unknown init {self.init}")

    def stacked(self, n: int, axis_name: str | None = None) -> "ParamDecl":
        return dataclasses.replace(
            self, shape=(n, *self.shape), axes=self.axes.prepend(axis_name)
        )


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def map_decls(fn: Callable[[ParamDecl], Any], tree: Any) -> Any:
    return jax.tree.map(fn, tree, is_leaf=is_decl)


def stack_tree(tree: Any, n: int, axis_name: str | None = None) -> Any:
    """Stack every decl in a layer tree n times (scan-over-layers weights)."""
    return map_decls(lambda d: d.stacked(n, axis_name), tree)


def abstract_tree(tree: Any) -> Any:
    return map_decls(lambda d: d.abstract(), tree)


def axes_tree(tree: Any) -> Any:
    return map_decls(lambda d: d.axes, tree)


def init_tree(tree: Any, key: jax.Array) -> Any:
    """Materialize a decl tree with per-leaf independent keys."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_decl)
    keys = jax.random.split(key, max(len(leaves), 1))
    vals = [d.materialize(k) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def count_params(tree: Any) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_decl)
    total = 0
    for leaf in leaves:
        shape = leaf.shape if hasattr(leaf, "shape") else ()
        total += int(np.prod(shape)) if shape else 1
    return total


def cast_tree(params: Any, dtype) -> Any:
    return jax.tree.map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params,
    )
