"""Logical-axis -> mesh-axis rule engine.

The engine is the software analogue of the X-HEEP bus/addressing-mode
configuration (paper §III-A3): the same model code is laid out on the machine
according to a small declarative table, and changing the table is the whole
configuration act — no model-code fork, mirroring XAIF's no-RTL-fork property.

Robustness properties (unit- and property-tested):

* divisibility fallback — a logical dim whose size does not divide the mesh
  axes assigned to it is silently replicated instead of failing to lower;
* no mesh axis is used twice within one PartitionSpec;
* unknown logical names map to replication.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.sharding import axes as lax_


MeshAxes = tuple[str, ...]


def _as_tuple(v) -> MeshAxes:
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


@dataclasses.dataclass(frozen=True)
class Rules:
    """A mapping from logical axis names to mesh axis tuples."""

    table: Mapping[str, MeshAxes]
    name: str = "custom"

    def lookup(self, logical: str | None) -> MeshAxes:
        if logical is None:
            return ()
        return _as_tuple(self.table.get(logical, ()))

    def override(self, name: str | None = None, **updates) -> "Rules":
        t = dict(self.table)
        for k, v in updates.items():
            t[k] = _as_tuple(v)
        return Rules(t, name or self.name)


def fully_connected(mesh: Mesh) -> Rules:
    """The 'fully-connected bus' preset: DP/FSDP over (pod, data), TP/EP over
    model, sequence parallelism for long-context activations."""
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    return Rules(
        {
            lax_.BATCH: batch,
            lax_.DECODE_BATCH: batch,
            lax_.SEQ: (),
            # decode-time context parallelism: KV cache sharded along its
            # sequence axis over `model` (GQA kv-head counts rarely divide it)
            lax_.CACHE_SEQ: ("model",),
            # FSDP: parameter/optimizer d_model dim sharded over `data`
            # (ZeRO-3): without it the ≥300B configs cannot fit 16 GiB HBM.
            # Activations are unaffected (batch claims `data` first).
            lax_.EMBED: ("data",),
            lax_.MLP: ("model",),
            lax_.HEADS: ("model",),
            lax_.KV_HEADS: ("model",),
            lax_.VOCAB: ("model",),
            lax_.EXPERT: ("model",),
            lax_.RNN_WIDTH: ("model",),
            lax_.FSDP: ("data",),
        },
        name="fully_connected",
    )


def one_at_a_time(mesh: Mesh) -> Rules:
    """The paper-faithful minimal-bus baseline: a single master at a time.

    Only data parallelism over one axis; parameters replicated. Matches the
    paper's one-at-a-time topology, whose bandwidth is flat no matter how many
    ports exist (Fig. 2b) — on the pod this manifests as all-reduce-everything
    with replicated memory, the starting point the optimized layouts beat.
    """
    has_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if has_pod else ("data",)
    return Rules(
        {lax_.BATCH: batch, lax_.DECODE_BATCH: batch},
        name="one_at_a_time",
    )


PRESETS = {"fully_connected": fully_connected, "one_at_a_time": one_at_a_time}


def _mesh_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(
    shape: Sequence[int],
    logical: Sequence[str | None],
    rules: Rules,
    mesh: Mesh,
) -> PartitionSpec:
    """Build a PartitionSpec for one array, with divisibility fallback."""
    if len(shape) != len(logical):
        raise ValueError(f"shape {shape} vs logical axes {logical} rank mismatch")
    sizes = _mesh_sizes(mesh)
    used: set[str] = set()
    out: list[Any] = []
    for dim, name in zip(shape, logical):
        assigned = rules.lookup(name)
        if name in lax_.UNSHARDED:
            assigned = ()
        keep: list[str] = []
        prod = 1
        for ax in assigned:
            if ax in used or ax not in sizes:
                continue
            if dim % (prod * sizes[ax]) != 0:
                continue
            keep.append(ax)
            prod *= sizes[ax]
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(tuple(keep))
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def tree_specs(abstract: Any, axes_tree: Any, rules: Rules, mesh: Mesh) -> Any:
    """Map a pytree of ShapeDtypeStructs + a matching tree of Axes leaves to a
    tree of PartitionSpecs."""
    return jax.tree.map(
        lambda a, ax: spec_for(a.shape, tuple(ax), rules, mesh),
        abstract,
        axes_tree,
    )


def tree_shardings(abstract: Any, axes_tree: Any, rules: Rules, mesh: Mesh) -> Any:
    specs = tree_specs(abstract, axes_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


# ---------------------------------------------------------------------------
# Serving tensor parallelism (head-axis sharding for the paged decode)
# ---------------------------------------------------------------------------


def validate_serve_tp(cfg, tp: int) -> None:
    """Loudly reject a (config, tp) pair the head-sharded paged decode
    cannot serve — the serving counterpart of :func:`spec_for`'s silent
    divisibility fallback, which would quietly replicate a KV cache the
    caller asked to shard.

    Requirements (each failure names its cause):

    * a transformer-family config with paged KV decode (MoE and the
      SSM/hybrid lane-fallback families have no head axis to shard);
    * ``n_kv_heads % tp == 0`` — the pool arenas shard over the KV-head
      axis, so every device must hold whole KV heads (this also implies
      ``n_heads % tp == 0``: query heads are ``groups × n_kv_heads``).
      MQA (``n_kv_heads == 1``) therefore cannot shard beyond tp=1.
    """
    from repro.models import registry

    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if not registry.supports_paged(cfg):
        raise ValueError(
            f"{cfg.name} ({cfg.family}) cannot serve tensor-parallel: the "
            "head-sharded decode requires the paged backend (MoE routing "
            "and SSM/hybrid lane caches have no KV-head axis to shard)")
    if tp == 1:
        return
    if cfg.n_kv_heads % tp:
        detail = ("MQA has a single shared KV head" if cfg.n_kv_heads == 1
                  else f"{cfg.n_kv_heads} KV heads")
        raise ValueError(
            f"{cfg.name}: n_kv_heads {cfg.n_kv_heads} % tp {tp} != 0 — the "
            f"pool arenas shard whole KV heads per device ({detail}, "
            f"cannot split across {tp} devices)")


def serve_param_spec(axes, tp_axis: str = "model") -> PartitionSpec:
    """PartitionSpec of one parameter under serving tensor parallelism.

    The rule is the logical-axis rendition of Megatron-style attention TP:
    a projection *into* head space (its :class:`~repro.sharding.params.
    Axes` contain HEADS/KV_HEADS and end in HEAD_DIM — wq/wk/wv) shards
    that head axis over ``tp_axis``; everything else — including the
    output projection wo, whose trailing axis is EMBED and which consumes
    the all-gathered heads — stays replicated, so the only collective in
    the decode step is the one all-gather at the output projection.
    """
    dims = tuple(axes)
    if not dims or dims[-1] != lax_.HEAD_DIM:
        return PartitionSpec()
    out: list = []
    sharded = False
    for name in dims:
        if not sharded and name in (lax_.HEADS, lax_.KV_HEADS):
            out.append(tp_axis)
            sharded = True
        else:
            out.append(None)
    if not sharded:
        return PartitionSpec()
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def serve_param_specs(cfg, tp_axis: str = "model"):
    """Tree of per-parameter PartitionSpecs for the TP paged decode,
    derived from the registry's own logical-axis declarations (one source
    of truth with training: :func:`repro.sharding.params.axes_tree`)."""
    from repro.models import registry
    from repro.sharding.params import axes_tree, is_axes

    decls = registry.decls(cfg)
    return jax.tree.map(lambda ax: serve_param_spec(ax, tp_axis),
                        axes_tree(decls), is_leaf=is_axes)


def serve_pool_spec(tp_axis: str = "model") -> PartitionSpec:
    """PartitionSpec of a KV pool arena ``(L, P, page, Kh, Dh)``: sharded
    over the KV-head axis only — page ids (and the block tables indexing
    them) stay device-invariant, so host-side allocation is unchanged."""
    return PartitionSpec(None, None, None, tp_axis)


def shard_bytes(shape: Sequence[int], spec: PartitionSpec, mesh: Mesh,
                dtype_bytes: int) -> int:
    """Per-device bytes of an array under a spec (for memory napkin math)."""
    sizes = _mesh_sizes(mesh)
    n = math.prod(shape) if shape else 1
    denom = 1
    for entry in spec:
        for ax in _as_tuple(entry):
            denom *= sizes.get(ax, 1)
    return int(n * dtype_bytes / max(denom, 1))
