"""Logical axis vocabulary.

Every parameter / activation dimension in the framework is annotated with a
*logical* axis name; :mod:`repro.sharding.rules` maps logical names onto mesh
axes.  This is the X-HEEP "memory addressing mode" analogue: the same model
code serves contiguous (bank-local) and interleaved (bandwidth-oriented)
layouts purely through the rule table.
"""

from __future__ import annotations

# -- activation axes ---------------------------------------------------------
BATCH = "batch"          # global batch                  -> data (+ pod)
SEQ = "seq"              # sequence / time               -> sequence parallel
DECODE_BATCH = "decode_batch"  # serving batch           -> data (+ pod)
CACHE_SEQ = "cache_seq"  # KV-cache sequence axis

# -- parameter axes ----------------------------------------------------------
EMBED = "embed"          # d_model
MLP = "mlp"              # d_ff (tensor-parallel)
HEADS = "heads"          # query heads
KV_HEADS = "kv_heads"    # key/value heads (GQA)
HEAD_DIM = "head_dim"    # per-head width
VOCAB = "vocab"          # embedding / logits vocabulary
EXPERT = "expert"        # MoE expert axis (expert-parallel)
CONV = "conv"            # short conv kernel width (mamba/griffin)
STATE = "state"          # SSM state dim
RNN_WIDTH = "rnn_width"  # RG-LRU recurrent width
LAYERS = "layers"        # stacked-scan layer axis — never sharded
FSDP = "fsdp"            # alias attached to the largest param dim for ZeRO sharding

# Axes that must never be partitioned (scan carries, small dims).
UNSHARDED = (LAYERS, CONV, HEAD_DIM, STATE)
