"""The X-HEEP platform object: configuration + dispatch + power.

Mirrors the paper's configurability axes (§III-A):

* ``core``        — CV32E20 / CV32E40X / CV32E40P, i.e. which execution
                    backend compute ops default to (ref / chunked / pallas).
* ``bus``         — one_at_a_time vs fully_connected -> sharding rule preset.
* ``addressing``  — contiguous vs interleaved -> activation layout (sequence
                    parallelism on/off).
* ``n_banks``     — memory pool shard count (per-pod HBM partitions).
* ``peripherals`` — optional subsystems (data pipeline stages, loggers).

An accelerator registered through XAIF can override the backend for its op,
and its power domain joins the platform power manager.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from jax.sharding import Mesh

from repro.core import xaif
from repro.core.power import PowerDomain, PowerManager
from repro.sharding import axes as lax_
from repro.sharding import rules as rules_lib

CORE_BACKEND = {
    "cv32e20": "ref",       # control-oriented core -> reference jnp path
    "cv32e40x": "chunked",  # XIF co-processor socket -> chunked/scan formulations
    "cv32e40p": "pallas",   # processing-oriented -> TPU kernels
}

BUSES = ("one_at_a_time", "fully_connected")
ADDRESSING = ("contiguous", "interleaved")
DEFAULT_PERIPHERALS = ("uart", "spi", "gpio", "timer", "dma", "plic")


@dataclasses.dataclass(frozen=True)
class XHeepConfig:
    core: str = "cv32e40x"
    bus: str = "fully_connected"
    addressing: str = "contiguous"
    n_banks: int = 8
    peripherals: Sequence[str] = DEFAULT_PERIPHERALS
    # op -> impl overrides (accelerator plug-ins chosen per op)
    op_impls: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.core not in CORE_BACKEND:
            raise ValueError(f"unknown core {self.core!r}; options {list(CORE_BACKEND)}")
        if self.bus not in BUSES:
            raise ValueError(f"unknown bus {self.bus!r}")
        if self.addressing not in ADDRESSING:
            raise ValueError(f"unknown addressing {self.addressing!r}")
        if self.n_banks < 1:
            raise ValueError("need at least one memory bank")


class Platform:
    """A configured X-HEEP instance hosting models and accelerators."""

    def __init__(self, config: XHeepConfig | None = None,
                 registry: xaif.XaifRegistry | None = None):
        self.config = config or XHeepConfig()
        self.registry = registry or xaif.REGISTRY
        self.power = PowerManager(
            [PowerDomain("host", leak_uw=0.0)]
            + [PowerDomain(f"bank{i}", leak_uw=0.0, retainable=True)
               for i in range(self.config.n_banks)]
        )
        self.interrupts = xaif.InterruptController()
        self._attached: list[xaif.AcceleratorSpec] = []
        self._added_domains: set[str] = set()   # domains attach() created
        self._bank_refs: dict[str, int] = {}    # shared bank occupancy

    # -- XAIF attach ---------------------------------------------------------
    def attach(self, spec: xaif.AcceleratorSpec) -> None:
        """Plug an accelerator in: register fn + join the power manager.

        Re-attaching (same op/impl) replaces the registration but joins the
        power manager exactly once — the power port is level-, not
        edge-attached.
        """
        self.registry.register(spec, allow_override=True)
        if spec.power_domain is not None:
            if spec.power_domain.name not in self.power.domains:
                self.power.add_domain(spec.power_domain)
                self._added_domains.add(spec.power_domain.name)
        replaced = [s for s in self._attached
                    if (s.op, s.impl) == (spec.op, spec.impl)]
        self._attached = [s for s in self._attached
                          if (s.op, s.impl) != (spec.op, spec.impl)]
        self._attached.append(spec)
        # a replaced spec's domain must not linger and leak — but only
        # domains attach() itself created are ours to remove (a spec naming
        # a platform built-in like "bank0" must never delete it)
        for old in replaced:
            if old.power_domain is None:
                continue
            name = old.power_domain.name
            still_used = any(
                s.power_domain is not None and s.power_domain.name == name
                for s in self._attached)
            if not still_used and name in self._added_domains:
                self.power.remove_domain(name)
                self._added_domains.discard(name)

    # -- shared bank occupancy (engines and pipelines co-own the pool) --------
    def bank_acquire(self, name: str) -> None:
        """Refcounted wake: the first user of an idle bank powers it on."""
        refs = self._bank_refs.get(name, 0)
        if refs == 0:
            self.power.wake(name)
        self._bank_refs[name] = refs + 1

    def bank_release(self, name: str) -> None:
        """Refcounted gate: the last user leaving an idle bank clock-gates
        it. Gating never fires while any other holder is live."""
        refs = self._bank_refs.get(name, 0)
        if refs <= 0:
            raise ValueError(f"bank {name!r} released more than acquired")
        self._bank_refs[name] = refs - 1
        if self._bank_refs[name] == 0:
            self.power.clock_gate(name)

    @property
    def accelerators(self) -> list[xaif.AcceleratorSpec]:
        return list(self._attached)

    # -- dispatch -------------------------------------------------------------
    def impl_for(self, op: str) -> str:
        override = dict(self.config.op_impls or {}).get(op)
        if override:
            return override
        default = CORE_BACKEND[self.config.core]
        if default in self.registry.impls(op):
            return default
        return "ref"

    def dispatch(self, op: str, *args, **kwargs):
        return self.registry.dispatch(op, self.impl_for(op), *args, **kwargs)

    # -- sharding rules (bus topology + addressing mode) ----------------------
    def rules(self, mesh: Mesh) -> rules_lib.Rules:
        preset = rules_lib.PRESETS[self.config.bus](mesh)
        if self.config.addressing == "interleaved" and self.config.bus == "fully_connected":
            # Interleaved addressing stripes sequences across banks for
            # bandwidth (paper §III-A3) == sequence parallelism on activations.
            preset = preset.override(
                name=f"{preset.name}+interleaved", **{lax_.SEQ: ("data",)}
            )
        return preset
