"""Power domains and the power manager (paper §III-A5).

The paper's power manager exposes clock-gating, power-gating and SRAM
retention to both the platform and — through XAIF power ports — to external
accelerators. Here a :class:`PowerDomain` is an accounting + *functional*
unit: domains marked OFF are skipped in compute graphs (``lax.cond`` /
unrouted experts), RETENTION keeps state without compute, CLOCK_GATED stops
dynamic switching but keeps leakage.

All coefficients are in µW (leakage) and µW/MHz (dynamic) at the calibration
voltage 0.8 V; voltage scaling follows §energy.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Mapping


class PowerState(enum.Enum):
    ON = "on"
    CLOCK_GATED = "clock_gated"
    RETENTION = "retention"   # memories only: -42.5 % leakage, no access
    OFF = "off"


# Paper: retention reduces leakage by about 42.5 % when the bank is idle.
RETENTION_LEAK_FACTOR = 1.0 - 0.425


@dataclasses.dataclass(frozen=True)
class PowerDomain:
    name: str
    leak_uw: float                 # leakage @0.8 V when ON / CLOCK_GATED
    idle_dyn_uw_mhz: float = 0.0   # clock-tree switching when ON but idle
    active_dyn_uw_mhz: float = 0.0  # switching when actively computing
    retainable: bool = False       # supports RETENTION (SRAM banks, ctx mems)

    def power_uw(self, state: PowerState, duty: float, freq_mhz: float,
                 leak_scale: float = 1.0, dyn_scale: float = 1.0) -> float:
        """Power of this domain in one scenario.

        ``duty`` is the fraction of time the domain is actively computing
        (the rest of the time it idles at clock-tree power).
        """
        if state is PowerState.OFF:
            return 0.0
        if state is PowerState.RETENTION:
            if not self.retainable:
                raise ValueError(f"domain {self.name} is not retainable")
            return self.leak_uw * RETENTION_LEAK_FACTOR * leak_scale
        leak = self.leak_uw * leak_scale
        if state is PowerState.CLOCK_GATED:
            # Gated between uses: wakes for ``duty``, burns no idle clock tree.
            return leak + self.active_dyn_uw_mhz * duty * freq_mhz * dyn_scale
        dyn = (self.active_dyn_uw_mhz * duty
               + self.idle_dyn_uw_mhz * (1.0 - duty)) * freq_mhz * dyn_scale
        return leak + dyn


class PowerManager:
    """Real-time control over the low-power techniques (paper Fig. 1).

    External accelerators get their own domains via XAIF power ports —
    :meth:`add_domain` is the power-port attach operation.
    """

    def __init__(self, domains: Iterable[PowerDomain]):
        self.domains: dict[str, PowerDomain] = {d.name: d for d in domains}
        self.states: dict[str, PowerState] = {n: PowerState.ON for n in self.domains}

    # -- XAIF power port -----------------------------------------------------
    def add_domain(self, domain: PowerDomain) -> None:
        if domain.name in self.domains:
            raise ValueError(f"duplicate power domain {domain.name!r}")
        self.domains[domain.name] = domain
        self.states[domain.name] = PowerState.ON

    def remove_domain(self, name: str) -> None:
        """Detach a power port (accelerator unplugged / spec replaced)."""
        if name not in self.domains:
            raise KeyError(name)
        del self.domains[name]
        del self.states[name]

    def set_state(self, name: str, state: PowerState) -> None:
        if name not in self.domains:
            raise KeyError(name)
        if state is PowerState.RETENTION and not self.domains[name].retainable:
            raise ValueError(f"domain {name} does not support retention")
        self.states[name] = state

    def set_states(self, states: Mapping[str, PowerState]) -> None:
        for k, v in states.items():
            self.set_state(k, v)

    def all_on(self) -> None:
        for n in self.states:
            self.states[n] = PowerState.ON

    def state(self, name: str) -> PowerState:
        if name not in self.states:
            raise KeyError(name)
        return self.states[name]

    def wake(self, name: str) -> None:
        self.set_state(name, PowerState.ON)

    def clock_gate(self, name: str) -> None:
        self.set_state(name, PowerState.CLOCK_GATED)

    def is_active(self, name: str) -> bool:
        return self.states[name] in (PowerState.ON, PowerState.CLOCK_GATED)

    # -- accounting ------------------------------------------------------------
    def power_uw(self, freq_mhz: float, *, activity: Mapping[str, float] | None = None,
                 leak_scale: float = 1.0, dyn_scale: float = 1.0) -> float:
        activity = activity or {}
        total = 0.0
        for name, dom in self.domains.items():
            total += dom.power_uw(self.states[name], activity.get(name, 0.0),
                                  freq_mhz, leak_scale, dyn_scale)
        return total

    def leakage_uw(self, leak_scale: float = 1.0) -> float:
        return sum(
            d.power_uw(self.states[n], 0.0, 0.0, leak_scale, 0.0)
            for n, d in self.domains.items()
        )
