"""Calibrated HEEPocrates energy model (paper §IV-C/D, §V, §VI).

The paper's evaluation is an energy study of fabricated silicon.  We model it
analytically: per power-domain leakage + dynamic coefficients (TSMC 65 nm LP
@0.8 V) with DVFS voltage scaling.  Coefficients were solved once against the
paper's measured anchors and are validated by ``tests/test_energy.py``:

  * 270 µW @32 kHz/0.8 V; 48 mW @470 MHz/1.2 V          (§I, §IV-C)
  * acquisition ladder 384 → 310 (−19 %) → 286 µW (−8 %)  (§IV-C1)
  * processing ladder 8.17 → 7.68 mW (−6 %)               (§IV-C2)
  * CGRA CNN 4.01 mW @60 MHz                              (§IV-C2)
  * DVFS 5.9× power, 2.8× perf, 2.1× energy               (§IV-D)
  * CGRA 16×16 conv(3×3): 4.9× energy benefit             (Fig. 6)
  * GP-peripheral trim: −65 % AO leakage, −27 %/−3 % app energy (§VI)
  * Fig. 5 orderings: Apollo best-acquisition, GAP9 best-processing,
    HEEPocrates in between.

Accounting note: the paper counts 11 power domains; we carry two extra
*accounting-only* splits (always-on essential vs general-purpose to express the
35 %/65 % leakage split of Fig. 2d, and I/O pads to express acquisition-phase
SPI pad energy) that are not independently gateable in silicon.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.power import PowerDomain, PowerManager, PowerState

# ---------------------------------------------------------------------------
# Calibrated constants (leak µW and dyn µW/MHz at 0.8 V)
# ---------------------------------------------------------------------------

V_NOM = 0.8
DYN_VOLT_EXP = 1.907   # dynamic ∝ (V/0.8)^1.907  (fits the 48 mW corner)
LEAK_VOLT_EXP = 2.26   # leakage ∝ (V/0.8)^2.26   (×2.5 at 1.2 V)

CPU_ACTIVE_DYN = 39.05      # matmul on CV32E20
IO_PADS_ACQ_DYN = 101.69    # SPI/ADC pad drivers during acquisition
CGRA_ACTIVE_DYN = 54.63     # CGRA datapath at full tilt

N_BANKS = 8

# cycle costs for the accelerator-vs-host study (Fig. 6)
CPU_CYCLES_PER_MAC = 12.0       # CV32E20: mul+acc+2 loads+addressing
CGRA_CYCLES_PER_MAC = 1.6555    # 4 PEs, ~6.6 cycles per 4-MAC bundle

# application profiles (paper Table 2 + §V-B)
HEARTBEAT_ACQ_S = 15.0
HEARTBEAT_PROC_CYCLES = 30.4e6   # morphological filtering (~80 %) + projections
SEIZURE_ACQ_S = 4.0
SEIZURE_PROC_CYCLES = 510e6      # 3×conv1d + pool + 2×FC on 23×1024 window


def leak_scale(voltage: float) -> float:
    return (voltage / V_NOM) ** LEAK_VOLT_EXP


def dyn_scale(voltage: float) -> float:
    return (voltage / V_NOM) ** DYN_VOLT_EXP


def build_heepocrates_pm() -> PowerManager:
    """The HEEPocrates power-domain set (paper Fig. 3)."""
    domains = [
        PowerDomain("ao_essential", leak_uw=54.25, idle_dyn_uw_mhz=2.0,
                    active_dyn_uw_mhz=2.0),
        PowerDomain("ao_gp_periph", leak_uw=100.75),
        PowerDomain("io_pads", leak_uw=0.0, active_dyn_uw_mhz=IO_PADS_ACQ_DYN),
        PowerDomain("cpu", leak_uw=25.0, idle_dyn_uw_mhz=3.0,
                    active_dyn_uw_mhz=CPU_ACTIVE_DYN),
        PowerDomain("periph", leak_uw=25.0, idle_dyn_uw_mhz=1.2,
                    active_dyn_uw_mhz=4.0),
        *[PowerDomain(f"bank{i}", leak_uw=5.0, idle_dyn_uw_mhz=0.12,
                      active_dyn_uw_mhz=1.0, retainable=True)
          for i in range(N_BANKS)],
        PowerDomain("cgra_logic", leak_uw=10.0, idle_dyn_uw_mhz=0.2,
                    active_dyn_uw_mhz=CGRA_ACTIVE_DYN),
        PowerDomain("cgra_mem", leak_uw=5.0, idle_dyn_uw_mhz=0.1,
                    active_dyn_uw_mhz=2.0, retainable=True),
        PowerDomain("imc", leak_uw=8.0, idle_dyn_uw_mhz=0.2,
                    active_dyn_uw_mhz=25.0),
        PowerDomain("fll", leak_uw=2.0, idle_dyn_uw_mhz=1.0,
                    active_dyn_uw_mhz=1.0),
    ]
    return PowerManager(domains)


def _banks(prefix: str, n: int) -> list[str]:
    return [f"bank{i}" for i in range(n)]


# ---------------------------------------------------------------------------
# Scenario powers (all µW unless stated)
# ---------------------------------------------------------------------------

def power_uw(pm: PowerManager, freq_mhz: float, voltage: float,
             activity: Mapping[str, float]) -> float:
    return pm.power_uw(freq_mhz, activity=activity,
                       leak_scale=leak_scale(voltage),
                       dyn_scale=dyn_scale(voltage))


def _proc_activity() -> dict[str, float]:
    # CPU matmul touching 2 of 8 banks.
    return {"cpu": 1.0, "bank0": 1.0, "bank1": 1.0}


def power_sleep_32khz() -> float:
    pm = build_heepocrates_pm()
    return power_uw(pm, 0.032, 0.8, {})


def power_max_470mhz_1v2() -> float:
    pm = build_heepocrates_pm()
    return power_uw(pm, 470.0, 1.2, _proc_activity())


def power_processing(optimized: bool = False) -> float:
    """§IV-C2: 8.17 mW all-on -> 7.68 mW with unused domains off (-6 %)."""
    pm = build_heepocrates_pm()
    if optimized:
        off = ["periph", "imc", "cgra_logic", "cgra_mem"] + [f"bank{i}" for i in range(2, 8)]
        pm.set_states({d: PowerState.OFF for d in off})
    return power_uw(pm, 170.0, 0.8, _proc_activity())


def power_acquisition(level: int = 0) -> float:
    """§IV-C1 ladder. level 0: all-on, CPU clock-gated between samples (384 µW);
    level 1: + unused banks/periph/accelerators off (310 µW);
    level 2: + CPU power-gated during idle (286 µW)."""
    pm = build_heepocrates_pm()
    cpu_duty = 0.15
    act = {"cpu": cpu_duty, "ao_essential": 1.0, "io_pads": 1.0,
           "bank0": 0.3, "bank1": 0.3, "bank2": 0.3}
    pm.set_state("cpu", PowerState.CLOCK_GATED)
    if level >= 1:
        off = ["periph", "imc", "cgra_logic", "cgra_mem"] + [f"bank{i}" for i in range(3, 8)]
        pm.set_states({d: PowerState.OFF for d in off})
    p = power_uw(pm, 1.0, 0.8, act)
    if level >= 2:
        # CPU power-gated during idle: pays leakage only for its duty cycle.
        p -= pm.domains["cpu"].leak_uw * (1.0 - cpu_duty)
    return p


def power_cgra_cnn() -> float:
    """§IV-C2: CGRA CNN at 60 MHz, CPU/periph/unused banks off -> 4.01 mW."""
    pm = build_heepocrates_pm()
    off = ["cpu", "periph", "imc"] + [f"bank{i}" for i in range(4, 8)]
    pm.set_states({d: PowerState.OFF for d in off})
    act = {"cgra_logic": 1.0, "cgra_mem": 1.0, "ao_essential": 1.0,
           "bank0": 1.0, "bank1": 1.0, "bank2": 1.0, "bank3": 1.0}
    return power_uw(pm, 60.0, 0.8, act)


# ---------------------------------------------------------------------------
# Derived paper results
# ---------------------------------------------------------------------------

def dvfs_ratios() -> tuple[float, float, float]:
    """Returns (power_ratio ~5.9, perf_ratio ~2.8, energy_ratio ~2.1)."""
    p_hi = power_max_470mhz_1v2()
    p_lo = power_processing(optimized=False)
    power_ratio = p_hi / p_lo
    perf_ratio = 470.0 / 170.0
    energy_ratio = power_ratio / perf_ratio
    return power_ratio, perf_ratio, energy_ratio


def conv_energy_uj(on_cgra: bool, img: int = 16, filt: int = 3) -> float:
    """Fig. 6: energy of one img×img conv with filt×filt filter."""
    macs = img * img * filt * filt
    if on_cgra:
        cycles = macs * CGRA_CYCLES_PER_MAC
        t_s = cycles / 60e6
        return power_cgra_cnn() * 1e-6 * t_s * 1e6
    cycles = macs * CPU_CYCLES_PER_MAC
    t_s = cycles / 170e6
    return power_processing(optimized=True) * 1e-6 * t_s * 1e6


def cgra_benefit() -> float:
    return conv_energy_uj(on_cgra=False) / conv_energy_uj(on_cgra=True)


# ---------------------------------------------------------------------------
# Fig. 5 — MCU comparison models
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class McuModel:
    """Two-phase (acquisition + processing) energy model of one MCU."""

    name: str
    acq_power_uw: float          # duty-cycled sleep/acquire power
    proc_power_uw: float         # active processing power
    proc_freq_mhz: float
    cycle_scale: Mapping[str, float]  # app -> relative cycle count vs CV32E20

    def app_energy_mj(self, app: "AppProfile") -> tuple[float, float]:
        scale = self.cycle_scale.get(app.name, 1.0)
        t_proc = app.proc_cycles * scale / (self.proc_freq_mhz * 1e6)
        e_acq = self.acq_power_uw * 1e-6 * app.acq_s * 1e3
        e_proc = self.proc_power_uw * 1e-6 * t_proc * 1e3
        return e_acq, e_proc


@dataclasses.dataclass(frozen=True)
class AppProfile:
    name: str
    acq_s: float
    proc_cycles: float


HEARTBEAT = AppProfile("heartbeat", HEARTBEAT_ACQ_S, HEARTBEAT_PROC_CYCLES)
SEIZURE = AppProfile("seizure", SEIZURE_ACQ_S, SEIZURE_PROC_CYCLES)


def mcu_models(trim_gp_periph: bool = False) -> dict[str, McuModel]:
    """Table 1 MCUs. ``trim_gp_periph`` applies the §VI what-if (remove the
    general-purpose peripherals from the HEEPocrates always-on domain)."""
    heep_acq = power_acquisition(level=2)
    heep_proc = power_processing(optimized=True)
    if trim_gp_periph:
        gp = 100.75  # 65 % of the always-on leakage (Fig. 2d)
        heep_acq -= gp
        heep_proc -= gp
    return {
        "apollo3_blue": McuModel(
            "apollo3_blue", acq_power_uw=60.0, proc_power_uw=4600.0,
            proc_freq_mhz=96.0, cycle_scale={"heartbeat": 0.88, "seizure": 1.1}),
        "gap9": McuModel(
            "gap9", acq_power_uw=400.0, proc_power_uw=5600.0,
            proc_freq_mhz=240.0, cycle_scale={"heartbeat": 0.6, "seizure": 0.6}),
        "heepocrates": McuModel(
            "heepocrates", acq_power_uw=heep_acq, proc_power_uw=heep_proc,
            proc_freq_mhz=170.0, cycle_scale={}),
    }


def gp_trim_saving(app: AppProfile) -> float:
    """Fraction of HEEPocrates app energy saved by trimming GP peripherals
    (paper: ~27 % heartbeat, ~3 % seizure)."""
    base = sum(mcu_models()["heepocrates"].app_energy_mj(app))
    trimmed = sum(mcu_models(trim_gp_periph=True)["heepocrates"].app_energy_mj(app))
    return 1.0 - trimmed / base


# ---------------------------------------------------------------------------
# Serving operating points (DVFS analogue for the serving stack)
# ---------------------------------------------------------------------------

# Cycle model for the serving energy meter: a decode token replays the whole
# cached context (memory-bound), a prefill token is written once. The absolute
# numbers are model constants (they scale every per-token figure together);
# what the calibration pins down is the *ratio* between operating points,
# which inherits the paper's §IV-D DVFS curve through leak/dyn voltage
# scaling below.
CYCLES_PER_DECODE_TOKEN = 2e6
CYCLES_PER_PREFILL_TOKEN = 1e6


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One DVFS point of the serving platform (paper §IV-D).

    ``max`` is the 470 MHz/1.2 V corner the engine boots at; ``nominal`` is
    the 170 MHz/0.8 V point the DVFS-throttle policy drops to. The energy
    meter charges dynamic energy ∝ dyn_scale (CV²·cycles — frequency
    cancels) and leakage ∝ leak_scale × time (frequency-dependent), so the
    tokens/joule ratio between the two points lands on the calibrated
    ~2.1× energy ratio of ``dvfs_ratios()``.
    """

    name: str
    freq_mhz: float
    voltage: float

    @property
    def leak_scale(self) -> float:
        """Leakage multiplier vs the 0.8 V baseline at this voltage."""
        return leak_scale(self.voltage)

    @property
    def dyn_scale(self) -> float:
        """Dynamic-energy multiplier vs the 0.8 V baseline at this voltage."""
        return dyn_scale(self.voltage)


OPERATING_POINTS: dict[str, OperatingPoint] = {
    "max": OperatingPoint("max", freq_mhz=470.0, voltage=1.2),
    "nominal": OperatingPoint("nominal", freq_mhz=170.0, voltage=0.8),
}


def operating_point(name: str) -> OperatingPoint:
    """Look up a named DVFS point, with a helpful error on typos."""
    try:
        return OPERATING_POINTS[name]
    except KeyError:
        raise ValueError(
            f"unknown operating point {name!r} "
            f"(have {sorted(OPERATING_POINTS)})") from None


# ---------------------------------------------------------------------------
# TPU-scale energy reporting (the platform mechanism at pod scale)
# ---------------------------------------------------------------------------

# Public v5e-class estimates for J/op accounting in serving/training reports.
TPU_PJ_PER_FLOP_BF16 = 0.8e-12 * 1e12   # ~0.8 pJ/FLOP -> J per TFLOP = 0.8
TPU_PJ_PER_HBM_BYTE = 0.12               # ~120 pJ/byte
TPU_IDLE_W = 60.0                        # per-chip idle
TPU_PEAK_W = 250.0                       # per-chip active


def tpu_step_energy_j(flops: float, hbm_bytes: float, step_s: float,
                      chips: int, duty: float = 1.0) -> float:
    """Coarse per-step energy: switching + static, the pod-scale analogue of
    the per-domain accounting above."""
    dyn = flops * 0.8e-12 + hbm_bytes * 120e-12
    static = chips * (TPU_IDLE_W + (TPU_PEAK_W - TPU_IDLE_W) * duty * 0.2) * step_s
    return dyn + static
