"""Hardware constants for roofline analysis.

Two hardware models live here:

* TPU v5e — the TARGET of this framework (the dry-run meshes, the roofline).
* TSMC 65 nm LP silicon — the paper's measured HEEPocrates chip, used by the
  calibrated energy model in :mod:`repro.core.energy`.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Per-chip roofline terms (all per second)."""

    name: str
    peak_flops_bf16: float  # FLOP/s
    hbm_bandwidth: float    # bytes/s
    ici_bandwidth: float    # bytes/s per link
    hbm_bytes: int          # capacity
    vmem_bytes: int         # on-chip vector memory
    mxu_dim: int = 128      # systolic array tile edge


# Constants fixed by the brief: 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI.
TPU_V5E = ChipSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """A (voltage, frequency) DVFS point of the HEEPocrates silicon."""

    voltage: float   # volts
    freq_hz: float   # hertz


# Measured silicon envelope (paper §IV-C): 0.8 V/170 MHz ... 1.2 V/470 MHz,
# down to the 32 kHz always-on clock.
HEEPOCRATES_POINTS = {
    "sleep_32khz_0v8": OperatingPoint(0.8, 32e3),
    "acquisition_1mhz_0v8": OperatingPoint(0.8, 1e6),
    "processing_170mhz_0v8": OperatingPoint(0.8, 170e6),
    "max_470mhz_1v2": OperatingPoint(1.2, 470e6),
    "cgra_60mhz_0v8": OperatingPoint(0.8, 60e6),
}


def bytes_of(shape, dtype_bytes: int) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n * dtype_bytes
