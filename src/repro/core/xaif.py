"""XAIF — the eXtendible Accelerator InterFace (paper §III-B), JAX edition.

In silicon, XAIF lets an accelerator plug into the host through
(1) slave/master OBI bus ports, (2) interrupt lines, (3) power-control ports,
without forking the platform RTL.  Here an accelerator is a JAX-compatible
callable (typically a Pallas kernel wrapper) plus the same three contracts:

* ``slave_ports``  — what the host pushes *into* the accelerator
  (configuration, weights): named abstract values.
* ``master_ports`` — what the accelerator reads/writes in HBM on its own:
  named logical-axes contracts. The number of master ports is the bandwidth
  contract (paper: CGRA = 4×32 bit master ports = 128 bit/cycle); at pod scale
  a port is one sharded operand, and "bandwidth" is its per-device HBM+ICI
  traffic — the Fig. 2 exploration is reproduced from exactly this.
* ``interrupt``    — completion notification: the serving engine's callback
  hook (jax.debug callbacks / host polling in the engine loop).
* ``power_domain`` — a PowerDomain attached to the platform PowerManager so
  the accelerator participates in clock/power-gating and energy accounting.

Registering an accelerator NEVER requires editing platform or model code —
models dispatch ops through the registry by name (the no-RTL-fork property).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

from repro.core.power import PowerDomain
from repro.sharding.params import Axes


@dataclasses.dataclass(frozen=True)
class PortSpec:
    """One XAIF bus port: a named operand with a logical sharding contract."""

    name: str
    axes: Axes                    # logical axes of the operand
    direction: str = "master"     # "master" (acc <-> HBM) | "slave" (host -> acc)
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.direction not in ("master", "slave"):
            raise ValueError(f"bad port direction {self.direction}")


@dataclasses.dataclass(frozen=True)
class AcceleratorSpec:
    """A pluggable accelerator implementation of one framework op."""

    name: str                     # e.g. "flash_attention_pallas"
    op: str                       # op it implements, e.g. "attention"
    impl: str                     # impl key, e.g. "pallas"
    fn: Callable[..., Any]
    slave_ports: Sequence[PortSpec] = ()
    master_ports: Sequence[PortSpec] = ()
    interrupt: bool = True
    power_domain: PowerDomain | None = None
    description: str = ""

    @property
    def bus_width_bits(self) -> int:
        """Paper-style bandwidth figure: 32 bit per master port per cycle."""
        return 32 * len(self.master_ports)


class InterruptController:
    """The platform's interrupt fabric (paper: PLIC + fast interrupts).

    Accelerators and the serving engine raise *lines* by name; the host
    (or any observer) connects handlers per line. Firing a line with no
    handler is not an error — the event is still counted, mirroring a
    masked interrupt that stays pending in the controller.
    """

    def __init__(self):
        self._handlers: dict[str, list[Callable[..., Any]]] = {}
        self.counts: dict[str, int] = {}

    def lines(self) -> list[str]:
        return sorted(set(self._handlers) | set(self.counts))

    def connect(self, line: str, handler: Callable[..., Any]) -> None:
        self._handlers.setdefault(line, []).append(handler)

    def disconnect(self, line: str, handler: Callable[..., Any]) -> None:
        self._handlers.get(line, []).remove(handler)

    def fire(self, line: str, payload: Any = None) -> int:
        """Raise ``line``; returns the number of handlers that ran."""
        self.counts[line] = self.counts.get(line, 0) + 1
        handlers = list(self._handlers.get(line, ()))
        for h in handlers:
            h(payload)
        return len(handlers)

    def count(self, line: str) -> int:
        return self.counts.get(line, 0)


class XaifRegistry:
    """op name -> impl name -> accelerator. The platform's plug-in socket."""

    def __init__(self):
        self._ops: dict[str, dict[str, AcceleratorSpec]] = {}

    def register(self, spec: AcceleratorSpec, *, allow_override: bool = False) -> None:
        impls = self._ops.setdefault(spec.op, {})
        if spec.impl in impls and not allow_override:
            raise ValueError(f"impl {spec.impl!r} already registered for op {spec.op!r}")
        impls[spec.impl] = spec

    def get(self, op: str, impl: str) -> AcceleratorSpec:
        try:
            return self._ops[op][impl]
        except KeyError:
            raise KeyError(
                f"no accelerator for op={op!r} impl={impl!r}; "
                f"registered: { {o: sorted(i) for o, i in self._ops.items()} }"
            ) from None

    def impls(self, op: str) -> list[str]:
        return sorted(self._ops.get(op, {}))

    def ops(self) -> list[str]:
        return sorted(self._ops)

    def dispatch(self, op: str, impl: str, *args, **kwargs):
        return self.get(op, impl).fn(*args, **kwargs)


# The process-global registry: kernels self-register on import (ops.py files).
REGISTRY = XaifRegistry()


def register(spec: AcceleratorSpec, *, allow_override: bool = False) -> AcceleratorSpec:
    REGISTRY.register(spec, allow_override=allow_override)
    return spec


def accelerator(op: str, impl: str, *, slave_ports=(), master_ports=(),
                power_domain: PowerDomain | None = None, description: str = "",
                allow_override: bool = False):
    """Decorator form of :func:`register`."""

    def deco(fn):
        register(
            AcceleratorSpec(
                name=f"{op}_{impl}", op=op, impl=impl, fn=fn,
                slave_ports=tuple(slave_ports), master_ports=tuple(master_ports),
                power_domain=power_domain, description=description,
            ),
            allow_override=allow_override,
        )
        return fn

    return deco
