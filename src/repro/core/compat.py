"""Version-compat shims for the jax API surface we depend on.

The repo targets the jax_pallas image (jax 0.4.37 today) but uses a few
APIs whose location moved across jax releases:

* ``jax.sharding.AxisType`` (explicit/auto axis types) only exists on
  jax >= 0.5; on older jax every mesh axis is implicitly "auto", so the
  equivalent is simply not passing ``axis_types``.
* ``jax.make_mesh`` grew its ``axis_types`` keyword at the same time.
* ``shard_map`` lived in ``jax.experimental.shard_map`` before being
  promoted to ``jax.shard_map``.

Everything that needs one of these goes through this module so no other
file hard-references a version-specific attribute.
"""

from __future__ import annotations

import jax

# -- axis types --------------------------------------------------------------

AxisType = getattr(jax.sharding, "AxisType", None)
HAS_AXIS_TYPES = AxisType is not None


def axis_types_auto(n: int):
    """``(AxisType.Auto,) * n`` on new jax, ``None`` (implicit auto) on old."""
    if HAS_AXIS_TYPES:
        return (AxisType.Auto,) * n
    return None


def make_mesh(shape, axes, *, devices=None):
    """``jax.make_mesh`` with auto axis types wherever the API allows them."""
    shape, axes = tuple(shape), tuple(axes)
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPES:
        kwargs["axis_types"] = axis_types_auto(len(axes))
    return jax.make_mesh(shape, axes, **kwargs)


def axis_size(axis_name):
    """``lax.axis_size`` (jax >= 0.5); older jax spells it ``psum(1, axis)``
    (constant-folded to the static mesh-axis size)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# -- shard_map ---------------------------------------------------------------

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.6: experimental location, and the replication-check kwarg
    # is still called check_rep there (renamed to check_vma later)
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f=None, /, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if f is None:
            return lambda g: _shard_map(g, **kwargs)
        return _shard_map(f, **kwargs)
