"""Deterministic synthetic LM data pipeline.

Stateless and step-indexed: ``batch_at(step)`` is a pure function of
(seed, step, shape), so a restarted job resumes bit-identically from a
checkpointed step — the data-side half of fault tolerance. The generator
mimics Zipfian token statistics so softmax/loss magnitudes are realistic.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    seq: int
    global_batch: int
    accum: int = 1
    seed: int = 0
    embed_dim: int | None = None   # set for modality-stub archs -> embeds


class LMPipeline:
    def __init__(self, cfg: LMDataConfig):
        self.cfg = cfg

    def _key(self, step: int) -> jax.Array:
        return jax.random.fold_in(jax.random.key(self.cfg.seed), step)

    def batch_at(self, step: int) -> dict:
        c = self.cfg
        mb = c.global_batch // c.accum
        key = self._key(step)
        ktok, kemb = jax.random.split(key)
        # learnable stream: even positions are Zipf-ish draws, odd positions
        # are a fixed affine function of their predecessor — a model that
        # learns the bigram structure halves the CE vs the unigram floor.
        n = c.seq + 1
        half = (n + 1) // 2
        u = jax.random.uniform(ktok, (c.accum, mb, half), minval=1e-6)
        ranks = jnp.floor(jnp.exp(jnp.log(u) * 0.9) * c.vocab)
        evens = jnp.clip(ranks.astype(jnp.int32), 0, c.vocab - 1)
        odds = (evens * 7 + 13) % c.vocab
        toks = jnp.stack([evens, odds], axis=-1).reshape(c.accum, mb, 2 * half)
        toks = toks[..., :n]
        out = {"labels": toks[..., 1:]}
        if c.embed_dim is None:
            out["tokens"] = toks[..., :-1]
        else:
            out["embeds"] = jax.random.normal(
                kemb, (c.accum, mb, c.seq, c.embed_dim), jnp.bfloat16)
        return out

    def shard_batch(self, batch: dict, shardings) -> dict:
        return jax.tree.map(jax.device_put, batch, shardings)
