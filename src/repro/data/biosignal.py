"""Synthetic biosignal acquisition pipeline (paper §V-B).

Simulates the HEEPocrates acquisition phase: ECG (3 leads @256 Hz, 16 bit)
for the heartbeat classifier and EEG (23 leads @256 Hz) for the seizure CNN.
The generator streams sample windows exactly like the paper's SPI+DMA path
stores them into SRAM banks; bank residency is reported so the power manager
can gate unused banks (the -19 % acquisition optimization).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

SAMPLE_RATE_HZ = 256
BANK_BYTES = 32 * 1024   # one X-HEEP SRAM bank


@dataclasses.dataclass(frozen=True)
class AcquisitionSpec:
    name: str
    leads: int
    window_s: float
    bits_per_sample: int = 16

    @property
    def samples_per_window(self) -> int:
        return int(self.window_s * SAMPLE_RATE_HZ)

    @property
    def window_bytes(self) -> int:
        return self.leads * self.samples_per_window * self.bits_per_sample // 8

    @property
    def banks_needed(self) -> int:
        return max(1, math.ceil(self.window_bytes / BANK_BYTES))


# Paper Table 2
HEARTBEAT_ECG = AcquisitionSpec("heartbeat_ecg", leads=3, window_s=15.0)
SEIZURE_EEG = AcquisitionSpec("seizure_eeg", leads=23, window_s=4.0)


def ecg_window(spec: AcquisitionSpec, seed: int = 0,
               abnormal: bool = True) -> np.ndarray:
    """(leads, samples) int16 synthetic ECG with QRS-like spikes."""
    rng = np.random.default_rng(seed)
    n = spec.samples_per_window
    t = np.arange(n) / SAMPLE_RATE_HZ
    out = np.zeros((spec.leads, n), np.float32)
    hr = 1.2  # ~72 bpm
    for lead in range(spec.leads):
        base = 0.05 * np.sin(2 * np.pi * 0.3 * t + lead)
        qrs = np.zeros(n, np.float32)
        phase = (t * hr) % 1.0
        qrs += np.exp(-((phase - 0.5) ** 2) / 0.0004) * (1.0 + 0.1 * lead)
        if abnormal:
            beat_idx = (t * hr).astype(int)
            irregular = (beat_idx % 7 == 3).astype(np.float32)
            qrs += irregular * np.exp(-((phase - 0.62) ** 2) / 0.001) * 0.8
        noise = rng.normal(0, 0.02, n).astype(np.float32)
        out[lead] = base + qrs + noise
    return np.clip(out * 16384, -32768, 32767).astype(np.int16)


def eeg_window(spec: AcquisitionSpec, seed: int = 0,
               seizure: bool = False) -> np.ndarray:
    """(leads, samples) int16 synthetic EEG; seizures add 3 Hz spike-waves."""
    rng = np.random.default_rng(seed)
    n = spec.samples_per_window
    t = np.arange(n) / SAMPLE_RATE_HZ
    out = np.zeros((spec.leads, n), np.float32)
    for lead in range(spec.leads):
        alpha = 0.3 * np.sin(2 * np.pi * 10 * t + rng.uniform(0, 6))
        beta = 0.1 * np.sin(2 * np.pi * 22 * t + rng.uniform(0, 6))
        sig = alpha + beta + rng.normal(0, 0.15, n)
        if seizure:
            sw = np.sign(np.sin(2 * np.pi * 3 * t)) * 0.9
            sig = sig * 0.4 + sw * (1 + 0.05 * lead)
        out[lead] = sig
    return np.clip(out * 8192, -32768, 32767).astype(np.int16)


class AcquisitionSim:
    """Streams windows + reports bank usage to the power manager."""

    def __init__(self, spec: AcquisitionSpec, n_banks: int = 8, seed: int = 0):
        self.spec = spec
        self.n_banks = n_banks
        self.seed = seed

    def bank_states(self) -> list[bool]:
        """True = bank holds acquisition data (must stay on/retained)."""
        used = self.spec.banks_needed
        return [i < used for i in range(self.n_banks)]

    def window(self, idx: int) -> np.ndarray:
        if self.spec.name.startswith("heartbeat"):
            return ecg_window(self.spec, seed=self.seed + idx)
        return eeg_window(self.spec, seed=self.seed + idx,
                          seizure=(idx % 5 == 0))
