"""The paper's healthcare benchmark applications (§V-B), in JAX.

* Heartbeat classifier [Braojos et al., DATE'13]: morphological filtering
  (~80 % of cycles) + random-projection classification over 3-lead ECG.
* Seizure detection CNN [Gómez et al., 2020]: 3 × (conv1d + pool + ReLU)
  + 2 fully-connected layers over 23-lead EEG.

Both run on the *host* path (pure jnp) or offload their convolution/filter
inner loops to the CGRA accelerator (the conv1d Pallas kernel) through XAIF —
the software side of the paper's Fig. 6 experiment.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import biosignal

F32 = jnp.float32


# ---------------------------------------------------------------------------
# Heartbeat classifier
# ---------------------------------------------------------------------------


def _erode(x: jax.Array, width: int) -> jax.Array:
    """Morphological erosion along time: min over a sliding window."""
    pads = [(0, 0), (width // 2, width - 1 - width // 2)]
    xp = jnp.pad(x, pads, constant_values=jnp.inf)
    return jnp.min(jnp.stack([xp[:, i:i + x.shape[1]] for i in range(width)]),
                   axis=0)


def _dilate(x: jax.Array, width: int) -> jax.Array:
    pads = [(0, 0), (width // 2, width - 1 - width // 2)]
    xp = jnp.pad(x, pads, constant_values=-jnp.inf)
    return jnp.max(jnp.stack([xp[:, i:i + x.shape[1]] for i in range(width)]),
                   axis=0)


def morphological_filter(ecg: jax.Array, width: int = 13) -> jax.Array:
    """Baseline-wander removal by opening+closing (the 80 %-of-cycles stage)."""
    x = ecg.astype(F32)
    opened = _dilate(_erode(x, width), width)
    closed = _erode(_dilate(opened, width), width)
    return x - closed


@dataclasses.dataclass(frozen=True)
class HeartbeatModel:
    projection_dim: int = 32
    sigma: float = 2.0     # adaptive threshold: mean + sigma*std
    seed: int = 42

    def projection(self, window: int) -> jax.Array:
        key = jax.random.key(self.seed)
        return jax.random.normal(key, (window, self.projection_dim), F32) \
            / np.sqrt(window)

    @functools.partial(jax.jit, static_argnums=(0,))
    def classify(self, ecg: jax.Array) -> jax.Array:
        """ecg: (leads, samples) int16 -> per-beat abnormality flags.

        Stages (paper §V-B1): morphological filtering -> R-peak-aligned beat
        segmentation -> random projection -> template-deviation score.
        Lead 0 is analysed first; the other leads confirm."""
        filt = morphological_filter(ecg.astype(F32) / 16384.0)
        n = filt.shape[1]
        period = 256.0 / 1.2                     # nominal 72 bpm grid
        n_beats = int(n / period) - 1
        half = 64
        width = 192

        # R-peak detection: argmax of |lead 0| within each nominal region
        starts = (jnp.arange(1, n_beats + 1) * period - period / 2).astype(jnp.int32)
        region = jnp.arange(int(period))
        ridx = jnp.clip(starts[:, None] + region[None, :], 0, n - 1)
        peaks = starts + jnp.argmax(jnp.abs(filt[0])[ridx], axis=1)

        # peak-centered beat windows, all leads
        widx = jnp.clip(peaks[:, None] - half + jnp.arange(width)[None, :],
                        0, n - 1)                # (beats, width)
        beats = filt[:, widx]                    # (leads, beats, width)
        proj = self.projection(width)
        feats = jnp.einsum("lbt,td->lbd", beats, proj)

        def dev_scores(f):   # f: (beats, dim)
            template = jnp.median(f, axis=0)
            return jnp.linalg.norm(f - template, axis=-1)

        s0 = dev_scores(feats[0])
        thr0 = s0.mean() + self.sigma * s0.std()
        suspect = s0 > thr0                                     # lead 0 first
        sc = jax.vmap(dev_scores)(feats[1:]).mean(0)
        thrc = sc.mean() + 0.5 * self.sigma * sc.std()
        return suspect & (sc > thrc)

    def mac_count(self, samples: int) -> int:
        beat_len = 213
        n_beats = samples // beat_len
        morph = samples * 13 * 4 * 3           # 4 morphology passes x 3 leads
        proj = n_beats * beat_len * self.projection_dim * 3
        return morph + proj


# ---------------------------------------------------------------------------
# Seizure detection CNN
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SeizureCNN:
    channels: tuple = (23, 32, 32, 16)
    kernel: int = 4
    hidden: int = 64
    seed: int = 7

    def init(self):
        key = jax.random.key(self.seed)
        ks = jax.random.split(key, 8)
        p = {}
        for i in range(3):
            cin, cout = self.channels[i], self.channels[i + 1]
            p[f"conv{i}_w"] = jax.random.normal(
                ks[i], (self.kernel, cin, cout), F32) * (1.0 / np.sqrt(self.kernel * cin))
            p[f"conv{i}_b"] = jnp.zeros((cout,), F32)
        feat = self.channels[-1] * (1024 // 2 ** 3)
        p["fc1_w"] = jax.random.normal(ks[4], (feat, self.hidden), F32) / np.sqrt(feat)
        p["fc1_b"] = jnp.zeros((self.hidden,), F32)
        p["fc2_w"] = jax.random.normal(ks[5], (self.hidden, 2), F32) / np.sqrt(self.hidden)
        p["fc2_b"] = jnp.zeros((2,), F32)
        return p

    def _conv(self, x, w, b, impl: str):
        """x: (B,S,Cin), w: (K,Cin,Cout). Full conv = K·Cin·Cout MACs/sample.
        The CGRA path streams each tap-slice through the depthwise kernel."""
        k, cin, cout = w.shape
        if impl == "cgra":
            import repro.kernels  # noqa: F401  (ensure XAIF registration)
            from repro.core.xaif import REGISTRY

            # express the dense conv as cin depthwise convs + channel mix
            # (the CGRA's 4 PEs stream 4 taps — paper Fig. 6 kernel shape)
            y = 0.0
            for ci in range(cin):
                xi = jnp.broadcast_to(x[..., ci:ci + 1], x.shape[:-1] + (cout,))
                y = y + REGISTRY.dispatch("conv1d", "pallas", xi, w[:, ci, :])
            return y + b
        # host path: shift-and-accumulate (CV32E20-style MAC loop)
        s = x.shape[1]
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        y = sum(jnp.einsum("bsc,cd->bsd", xp[:, i:i + s], w[i])
                for i in range(k))
        return y + b

    @functools.partial(jax.jit, static_argnums=(0, 2))
    def apply(self, eeg: jax.Array, impl: str = "host", params=None) -> jax.Array:
        """eeg: (leads, samples) int16 -> (2,) logits [normal, seizure]."""
        p = params if params is not None else self.init()
        x = (eeg.astype(F32) / 8192.0).T[None]        # (1, S, leads)
        x = x[:, :1024]
        for i in range(3):
            x = self._conv(x, p[f"conv{i}_w"], p[f"conv{i}_b"], impl)
            x = jax.nn.relu(x)
            x = x[:, ::2]                              # max-ish pool (stride)
        feat = x.reshape(1, -1)
        h = jax.nn.relu(feat @ p["fc1_w"] + p["fc1_b"])
        return (h @ p["fc2_w"] + p["fc2_b"])[0]

    def mac_count(self, samples: int = 1024) -> int:
        total, s = 0, samples
        for i in range(3):
            total += s * self.kernel * self.channels[i] * self.channels[i + 1]
            s //= 2
        feat = self.channels[-1] * s
        total += feat * self.hidden + self.hidden * 2
        return total


def run_heartbeat(seed: int = 0):
    ecg = biosignal.ecg_window(biosignal.HEARTBEAT_ECG, seed=seed)
    model = HeartbeatModel()
    flags = model.classify(jnp.asarray(ecg))
    return np.asarray(flags), model.mac_count(ecg.shape[1])


def run_seizure(seed: int = 0, impl: str = "host"):
    eeg = biosignal.eeg_window(biosignal.SEIZURE_EEG, seed=seed,
                               seizure=(seed % 5 == 0))
    model = SeizureCNN()
    logits = model.apply(jnp.asarray(eeg), impl)
    return np.asarray(logits), model.mac_count()
