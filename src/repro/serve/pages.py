"""Paged prefix cache: refcounted KV pages shared across requests.

This module is the host-side page table, the serving rendition of the
paper's refcounted memory banks. A *page* is the model state after
consuming a fixed-size extent of ``page_size`` prompt tokens: pages chain
(page *k* of a prompt extends page *k-1*), and a request whose prompt
starts with an already-resident chain is admitted with those tokens
pre-consumed — no prefill work for the shared prefix.

Since PR 4 every page additionally lives in a **namespace** (``ns``): the
model-identity component of the prefix key. One table can serve several
engines on one :class:`~repro.serve.cluster.ServeCluster` — engines
serving the *same* model (same config and weights) share a namespace and
alias each other's prefixes, while engines serving different models keep
identical token prefixes isolated (the same token ids produce different
KV states under different weights, so cross-namespace aliasing would be
silently wrong). The default ``ns=""`` keeps the single-engine API
unchanged. Capacity and LRU eviction are global across namespaces — the
table is one shared residency budget, arbitrated like the paper's memory
pool.

Page *payloads* are opaque to the table. Under the engine's paged backend
a payload is a pool page id (:class:`repro.serve.paged.PagePool`) —
adoption is block-table pointing and publication a refcount bump; under
the lane backend it is a full batch-1 cache snapshot, copied into the
slot's lane on first write (the copy-on-write bullet below). Mid-flight
re-match (:meth:`PageTable.acquire_range`) lets a slot that is already
prefilling adopt a sibling's freshly published pages, and ``on_evict``
hands dropped payloads back to their owner (the pool's free list).

Sharing follows the ``Platform.bank_acquire``/``bank_release`` discipline:

* **Refcounts never go negative.** ``acquire`` pins every page of the
  matched chain; ``release`` unpins; releasing more than was acquired
  raises (exactly like over-releasing a bank).
* **A referenced page is never freed.** LRU eviction only considers pages
  with zero refs *and* no resident children — pinning a leaf transitively
  protects its ancestors through the child links.
* **Copy-on-write.** ``acquire`` hands out the shared snapshot without
  copying; the engine materialises a private lane copy only when the slot
  first writes a divergent token (its first step), and reports that event
  back through :meth:`PageTable.note_cow`. A request evicted before its
  first step never pays for the copy.
* **Eviction disowns before it calls back.** Dropping a page runs in a
  fixed order: the page leaves the table (and its parent's child count),
  its bank reference is released, and only *then* does ``on_evict`` fire
  with the payload. By the time the callback runs, the table holds no
  reference of any kind to the page — so a shared pool's ``release`` in
  the callback is the payload's final reference drop and can never race a
  transient table-held refcount, even under cross-tenant eviction.
* **Power-aware residency.** With a platform attached, each resident page
  holds one refcounted bank acquisition (round-robin over the platform's
  banks), so banks retaining shared pages stay awake and eviction of the
  last page on a bank lets it clock-gate again.

Invariants (checked by ``tests/test_pages.py``): refcounts never negative,
eviction never frees a referenced page or a page with resident children,
``acquire`` always leaves at least one prompt token to feed (the final
token must run through the model to produce the first output logits), and
reuse never changes emitted tokens — greedy decode from a correct prefix
state is bit-identical to re-running the prefill.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

__all__ = ["Page", "PrefixMatch", "PageTable"]


@dataclasses.dataclass
class Page:
    """One resident page: the state after consuming ``key`` tokens.

    ``key`` is the full consumed-token prefix (length a multiple of the
    table's ``page_size``; the page's own extent is its last ``page_size``
    tokens) and ``ns`` the namespace (model identity) the page belongs to.
    ``snapshot`` is an opaque batch-1 cache pytree owned by the table until
    eviction.
    """

    key: tuple
    snapshot: Any
    ns: str = ""
    refs: int = 0          # live slot pins (acquire/release)
    children: int = 0      # resident pages extending this chain
    bank: str | None = None
    last_used: int = 0     # LRU tick


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Result of :meth:`PageTable.acquire`: a pinned chain of pages."""

    tokens_matched: int          # prompt tokens covered by the chain
    snapshot: Any                # payload of the chain's last page
    keys: tuple                  # chain keys, shortest first (release handle)
    chain: tuple = ()            # per-page payloads, shortest-key first


class PageTable:
    """Host-side table of shared prefix pages with bank-style refcounts.

    ``capacity_pages`` bounds residency *across all namespaces*;
    ``platform`` (optional) wires page residency into the platform's shared
    bank refcounts so resident pages keep their memory bank awake. One
    (namespace, model config, ``max_len``) triple keys a compatible payload
    family — the ``ns`` keyword on every lookup/publish isolates models
    that must not alias each other's state.
    """

    def __init__(self, page_size: int, *, capacity_pages: int | None = None,
                 platform=None, on_evict=None):
        if page_size < 1:
            raise ValueError("page_size must be >= 1 token")
        if capacity_pages is not None and capacity_pages < 1:
            raise ValueError("capacity_pages must be >= 1")
        self.page_size = page_size
        self.capacity_pages = capacity_pages
        self.platform = platform
        # called with the dropped page's payload on every eviction — the
        # paged engine uses it to return pool page ids to the free list
        # (payloads are opaque to the table: device snapshots in lane mode,
        # pool indices in paged mode). Fires only after the table has fully
        # disowned the page — see "Eviction disowns before it calls back"
        # in the module docstring.
        self.on_evict = on_evict
        self._pages: dict[tuple[str, tuple], Page] = {}
        # cold-prefill dedup claims: (ns, key) -> opaque owner token.
        # Table-level (not engine-local) so that two engines sharing the
        # table — replicas of one model in one namespace — dedup identical
        # concurrent cold prefills across engines: the later slot stalls
        # on the earlier engine's claim and adopts the published page.
        self._claims: dict[tuple[str, tuple], Any] = {}
        # fault-injection hook (chaos harness): called with the namespace
        # at the top of every acquire; returning True suppresses the
        # match (a spurious cold prefill). Sharing is an optimisation
        # only, so a dropped match degrades throughput, never tokens.
        self.fault_hook = None
        self._tick = 0
        self._next_bank = 0
        self.stats = {
            "hits": 0,             # acquisitions that matched a chain
            "misses": 0,           # acquisitions with no usable chain
            "tokens_reused": 0,    # prompt tokens skipped via sharing
            "published": 0,        # pages added
            "evicted": 0,          # pages LRU-evicted
            "cow_copies": 0,       # private lane copies materialised
            "rematches": 0,        # mid-flight prefix adoptions
            "rematched_pages": 0,  # pages pinned via acquire_range (the
                                   # engine counts token-granular adoption
                                   # in its own rematched_tokens)
        }

    # -- lookup / pinning ----------------------------------------------------

    def _chain_keys(self, prompt: Sequence[int], ns: str) -> list[tuple]:
        """Resident chain keys covering a prefix of ``prompt`` in ``ns``,
        shortest first. Caps at ``len(prompt) - 1``: the final prompt token
        is always fed through the model (its logits seed generation)."""
        prompt = tuple(int(t) for t in prompt)
        ps = self.page_size
        keys = []
        for k in range(1, (len(prompt) - 1) // ps + 1):
            key = prompt[:k * ps]
            if (ns, key) not in self._pages:
                break
            keys.append(key)
        return keys

    def lookup(self, prompt: Sequence[int], ns: str = "") -> int:
        """Prompt tokens a matching resident chain covers (0 = no match).
        Pure query: no refcounts, no stats."""
        keys = self._chain_keys(prompt, ns)
        return len(keys[-1]) if keys else 0

    def acquire(self, prompt: Sequence[int], ns: str = "") -> PrefixMatch | None:
        """Pin the longest resident chain matching ``prompt``'s prefix in
        namespace ``ns``.

        Every page of the chain is individually refcounted; the caller must
        hand the returned ``keys`` back to :meth:`release` exactly once
        (on completion, eviction, or preemption). With a ``fault_hook``
        installed (chaos harness) a hook hit turns this acquire into a
        miss — the caller cold-prefills as if nothing were resident."""
        if self.fault_hook is not None and self.fault_hook(ns):
            self.stats["misses"] += 1
            return None
        keys = self._chain_keys(prompt, ns)
        if not keys:
            self.stats["misses"] += 1
            return None
        self._tick += 1
        for key in keys:
            page = self._pages[(ns, key)]
            page.refs += 1
            page.last_used = self._tick
        matched = len(keys[-1])
        self.stats["hits"] += 1
        self.stats["tokens_reused"] += matched
        return PrefixMatch(tokens_matched=matched,
                           snapshot=self._pages[(ns, keys[-1])].snapshot,
                           keys=tuple(keys),
                           chain=tuple(self._pages[(ns, k)].snapshot
                                       for k in keys))

    def acquire_range(self, prompt: Sequence[int], from_block: int,
                      to_block: int, ns: str = "") -> list[tuple[tuple, Any]]:
        """Pin resident pages covering blocks ``[from_block, to_block)`` of
        ``prompt`` — the mid-flight re-match: a slot that already consumed
        ``from_block`` pages' worth of tokens adopts a sibling's freshly
        published pages instead of recomputing them. Returns
        ``[(key, payload), ...]`` shortest-key first; every returned page is
        individually pinned and must go back through :meth:`release` (the
        caller appends the keys to its release handle)."""
        prompt = tuple(int(t) for t in prompt)
        ps = self.page_size
        out = []
        self._tick += 1
        for b in range(from_block, to_block):
            key = prompt[:(b + 1) * ps]
            page = self._pages.get((ns, key))
            if page is None:
                break                      # chain must stay contiguous
            page.refs += 1
            page.last_used = self._tick
            out.append((key, page.snapshot))
        if out:
            # page-granular accounting; tokens_reused stays admission-only
            self.stats["rematches"] += 1
            self.stats["rematched_pages"] += len(out)
        return out

    def release(self, keys: Sequence[tuple], ns: str = "") -> None:
        """Unpin a chain previously returned by :meth:`acquire`.

        Mirrors ``Platform.bank_release``: releasing a page more times than
        it was acquired raises instead of driving the refcount negative."""
        for key in keys:
            page = self._pages.get((ns, tuple(key)))
            if page is None or page.refs <= 0:
                raise ValueError(
                    f"page {key!r} (ns={ns!r}) released more than acquired")
            page.refs -= 1

    # -- cold-prefill dedup claims -------------------------------------------

    def claim(self, key: Sequence[int], owner: Any, ns: str = "") -> None:
        """Register ``owner`` as the party currently computing page
        ``key`` in ``ns``. Owners are opaque to the table (the engine
        passes an ``(engine, slot)`` pair); claims are advisory dedup
        state, not residency — they hold no refcounts and survive no
        publication (:meth:`unclaim` or a fresh :meth:`claim` replaces
        them). Table-level so claims are visible across every engine
        sharing the table."""
        self._claims[(ns, tuple(key))] = owner

    def claimant(self, key: Sequence[int], ns: str = "") -> Any:
        """The current claim owner for page ``key`` in ``ns`` (None when
        unclaimed). Pure query; staleness is the caller's judgement —
        the table cannot tell a live claimant from a dead one."""
        return self._claims.get((ns, tuple(key)))

    def unclaim(self, key: Sequence[int], ns: str = "") -> None:
        """Drop the claim on page ``key`` in ``ns`` (no-op when
        unclaimed) — fired when the page publishes (claim moot), when the
        claimant abandons the prefill, or when a waiter steals a stale
        claim."""
        self._claims.pop((ns, tuple(key)), None)

    def note_cow(self, n_pages: int) -> None:
        """Record that a slot materialised its private copy of ``n_pages``
        shared pages (the copy-on-write event, fired at first divergent
        token)."""
        self.stats["cow_copies"] += int(n_pages)

    # -- publication / eviction ----------------------------------------------

    def wants(self, key: Sequence[int], ns: str = "") -> bool:
        """True if :meth:`publish` would accept ``key`` in ``ns`` — lets
        the engine skip the device gather when the page is already
        resident."""
        key = tuple(int(t) for t in key)
        if not key or len(key) % self.page_size != 0:
            return False
        if (ns, key) in self._pages:
            return False
        return (len(key) == self.page_size
                or (ns, key[:-self.page_size]) in self._pages)

    def publish(self, key: Sequence[int], snapshot: Any,
                ns: str = "") -> bool:
        """Add the page completing chain ``key`` in namespace ``ns`` (state
        after consuming all of ``key``). Returns False when the page is
        already resident or its parent chain is gone (nothing to graft
        onto)."""
        key = tuple(int(t) for t in key)
        if not key or len(key) % self.page_size != 0:
            raise ValueError(
                f"page key length {len(key)} is not a positive multiple of "
                f"page_size={self.page_size}")
        self._tick += 1
        if (ns, key) in self._pages:
            self._pages[(ns, key)].last_used = self._tick
            return False
        parent = None
        if len(key) > self.page_size:
            parent = self._pages.get((ns, key[:-self.page_size]))
            if parent is None:
                return False         # orphan extent: chain must be contiguous
        self._make_room(protect=parent)
        page = Page(key=key, snapshot=snapshot, ns=ns,
                    last_used=self._tick, bank=self._assign_bank())
        self._pages[(ns, key)] = page
        if parent is not None:
            parent.children += 1
        self.stats["published"] += 1
        return True

    def _assign_bank(self) -> str | None:
        if self.platform is None:
            return None
        n = self.platform.config.n_banks
        bank = f"bank{self._next_bank % n}"
        self._next_bank += 1
        self.platform.bank_acquire(bank)   # resident page keeps its bank awake
        return bank

    def _make_room(self, protect: Page | None = None) -> None:
        """Evict down below capacity before an insert. Only unpinned leaves
        are candidates (refs > 0 is a live slot pin, children > 0 means a
        resident page still needs this state, and the incoming page's
        parent must survive to keep the chain contiguous). When everything
        is pinned the table overflows instead of freeing a referenced page.
        """
        if self.capacity_pages is None:
            return
        while len(self._pages) >= self.capacity_pages:
            candidates = [p for p in self._pages.values()
                          if p.refs == 0 and p.children == 0
                          and p is not protect]
            if not candidates:
                return
            self._drop(min(candidates, key=lambda p: p.last_used))
            self.stats["evicted"] += 1

    def evict_lru(self, n: int = 1, ns: str | None = None) -> int:
        """Evict up to ``n`` unpinned, childless pages in LRU order —
        restricted to namespace ``ns`` when given (``None`` = any). Returns
        the number actually evicted. This is the cluster's fair-reclaim
        primitive: a scheduler targets the tenant holding the most idle
        residency instead of wiping every namespace at once."""
        evicted = 0
        while evicted < n:
            # one scan per batch, not per page; the rescan only matters for
            # parents that became childless leaves inside the batch
            candidates = sorted(
                (p for p in self._pages.values()
                 if p.refs == 0 and p.children == 0
                 and (ns is None or p.ns == ns)),
                key=lambda p: p.last_used)
            if not candidates:
                break
            for page in candidates[:n - evicted]:
                self._drop(page)
                self.stats["evicted"] += 1
                evicted += 1
        return evicted

    def _drop(self, page: Page) -> None:
        # ordering contract (see module docstring): (1) the page leaves the
        # table and its parent's child count, (2) the bank reference is
        # released, (3) on_evict fires last, once the table holds nothing
        del self._pages[(page.ns, page.key)]
        if len(page.key) > self.page_size:
            self._pages[(page.ns, page.key[:-self.page_size])].children -= 1
        if page.bank is not None:
            self.platform.bank_release(page.bank)
        if self.on_evict is not None:
            self.on_evict(page.snapshot)

    def clear(self) -> None:
        """Drop every unpinned page in every namespace (pinned chains
        survive)."""
        for page in sorted(self._pages.values(),
                           key=lambda p: -len(p.key)):   # leaves first
            if page.refs == 0 and page.children == 0:
                self._drop(page)
                self.stats["evicted"] += 1

    # -- introspection --------------------------------------------------------

    @property
    def resident(self) -> int:
        """Number of resident pages (all namespaces)."""
        return len(self._pages)

    @property
    def pinned(self) -> int:
        """Number of pages with a live slot pin (all namespaces)."""
        return sum(p.refs > 0 for p in self._pages.values())

    def resident_by_ns(self) -> dict[str, int]:
        """Namespace -> resident page count (tenant residency footprint)."""
        out: dict[str, int] = {}
        for page in self._pages.values():
            out[page.ns] = out.get(page.ns, 0) + 1
        return out

    def unpinned_by_ns(self) -> dict[str, int]:
        """Namespace -> evictable page count (unpinned, childless) — what
        fair reclaim arbitrates over."""
        out: dict[str, int] = {}
        for page in self._pages.values():
            if page.refs == 0 and page.children == 0:
                out[page.ns] = out.get(page.ns, 0) + 1
        return out

    def refcounts(self, ns: str | None = "") -> dict:
        """Per-page refcounts (for tests and the journal): token-prefix key
        -> refs within namespace ``ns``; pass ``ns=None`` for every
        namespace, keyed ``(ns, key)``."""
        if ns is None:
            return {k: p.refs for k, p in self._pages.items()}
        return {k: p.refs for (n, k), p in self._pages.items() if n == ns}

    def has(self, key, ns: str = "") -> bool:
        """True when chain ``key`` is resident in namespace ``ns``."""
        return (ns, tuple(key)) in self._pages

    def __contains__(self, key) -> bool:
        return self.has(key)

    def __len__(self) -> int:
        return len(self._pages)
