"""Open-loop, trace-driven load generation for the serving simulator.

The scripted traces in :mod:`repro.serve.sim` are *closed-loop-ish*: a
handful of hand-placed arrivals sized to the engine under test. Real
traffic is **open-loop** — users arrive according to their own process and
do not wait for the system to have capacity, so when offered load exceeds
capacity, queues genuinely build, latency rises, and admission control has
to shed work. This module generates such traffic at 10⁵–10⁶ request
scale, *lazily* (a generator of :class:`~repro.serve.sim.Arrival`, never a
materialised list) and *deterministically* (one seeded ``random.Random``
per stream; string-seeded, so the sequence is stable across processes and
platforms — same seed ⇒ bit-identical trace).

Three arrival processes:

* :func:`poisson_times` — homogeneous Poisson (i.i.d. exponential gaps):
  the memoryless baseline.
* :func:`bursty_times` — compound Poisson: burst *events* arrive at rate
  ``rate / burst`` and each releases ~``burst`` same-instant requests.
  The mean rate matches the Poisson stream but the instantaneous rate
  spikes — the workload that hammers queue capacity and cold-prefill
  dedup (many identical prefixes arriving in one burst).
* :func:`diurnal_times` — nonhomogeneous Poisson with sinusoidal
  intensity ``rate·(1 + amplitude·sin(2πt/period))`` via Lewis–Shedler
  thinning: the day/night load curve, for testing schedulers across
  under- and over-provisioned phases of one trace.

The request *mix* is a list of :class:`TenantSpec` — each tenant routes
to one cluster engine with a relative traffic ``share``, draws prompt and
output lengths uniformly from its own ranges, optionally prepends a
shared per-tenant prompt prefix (the prefix-cache workload; two tenants
with the same ``prefix_seed`` and ``prefix_len`` share tokens, which is
how replicas of one model exercise cross-engine prefix sharing), and
optionally attaches an :class:`~repro.serve.metrics.SLO` for the
SLO-aware scheduler and the goodput accounting to read.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Iterator, Sequence

from repro.serve.engine import Request
from repro.serve.metrics import SLO
from repro.serve.sampling import SamplingParams
from repro.serve.sim import Arrival

__all__ = ["TenantSpec", "bursty_times", "diurnal_times", "open_loop_trace",
           "poisson_times"]

ARRIVAL_PROCESSES = ("poisson", "bursty", "diurnal")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's slice of an open-loop workload mix.

    ``engine`` is the cluster engine name the tenant's requests route to;
    ``share`` its relative weight in the mix. ``prompt_len`` and
    ``new_tokens`` are inclusive uniform ranges. The first
    ``prefix_len`` prompt tokens are a fixed per-``prefix_seed`` sequence
    (clamped to leave at least one fresh prompt token), so requests of
    one tenant — and of any tenant sharing the same ``prefix_seed`` —
    hit the prefix cache. ``slo`` (optional) rides on every generated
    request as ``Request.slo``. ``sampling`` (optional) turns the
    tenant's traffic stochastic: each generated request carries a copy of
    the :class:`~repro.serve.sampling.SamplingParams` with a fresh
    per-request ``seed`` drawn from the trace's mix RNG — deterministic
    per trace seed, distinct per request, and drawn *only* for sampling
    tenants so purely greedy traces stay bit-identical to PR 6.
    ``energy_cap_uj_per_token`` (optional) rides on every generated
    request as ``Request.energy_cap_uj_per_token`` — the energy-aware
    admission policy sheds the tenant's traffic when the target engine's
    projected marginal joules/token exceeds it.
    """

    engine: str
    share: float = 1.0
    prompt_len: tuple[int, int] = (4, 24)
    new_tokens: tuple[int, int] = (2, 12)
    prefix_len: int = 0
    prefix_seed: int = 0
    slo: SLO | None = None
    vocab: int = 240
    sampling: SamplingParams | None = None
    energy_cap_uj_per_token: float | None = None

    def __post_init__(self):
        if self.share <= 0:
            raise ValueError("tenant share must be positive")
        if (self.energy_cap_uj_per_token is not None
                and self.energy_cap_uj_per_token <= 0):
            raise ValueError("energy_cap_uj_per_token must be positive")
        for name, (lo, hi) in (("prompt_len", self.prompt_len),
                               ("new_tokens", self.new_tokens)):
            if lo < 1 or hi < lo:
                raise ValueError(f"{name} range must satisfy 1 <= lo <= hi, "
                                 f"got ({lo}, {hi})")
        if self.prefix_len < 0:
            raise ValueError("prefix_len cannot be negative")
        if self.vocab < 2:
            raise ValueError("vocab must be >= 2")

    def prefix_tokens(self) -> tuple[int, ...]:
        """The tenant's fixed shared-prefix tokens (deterministic in
        ``prefix_seed``; equal seeds ⇒ equal tokens, the cross-tenant
        sharing contract)."""
        return tuple((29 * self.prefix_seed + 13 * j) % self.vocab + 1
                     for j in range(self.prefix_len))


def poisson_times(rate: float, *, seed, start: float = 0.0) -> Iterator[float]:
    """Homogeneous Poisson arrival times (exponential inter-arrival
    gaps), yielded lazily and forever — slice what you need."""
    if rate <= 0:
        raise ValueError("rate must be positive")
    rng = random.Random(f"{seed}-poisson")
    t = start
    while True:
        t += rng.expovariate(rate)
        yield t


def bursty_times(rate: float, *, seed, burst: int = 8,
                 start: float = 0.0) -> Iterator[float]:
    """Compound-Poisson bursts: events at rate ``rate / burst``, each
    releasing ``1..2·burst-1`` same-instant arrivals (mean ``burst``), so
    the long-run mean rate is ``rate`` while the instantaneous rate
    spikes — the queue-building, dedup-hammering workload."""
    if rate <= 0:
        raise ValueError("rate must be positive")
    if burst < 1:
        raise ValueError("burst must be >= 1")
    rng = random.Random(f"{seed}-bursty")
    t = start
    while True:
        t += rng.expovariate(rate / burst)
        for _ in range(rng.randint(1, 2 * burst - 1)):
            yield t


def diurnal_times(rate: float, *, seed, period: float = 200.0,
                  amplitude: float = 0.8,
                  start: float = 0.0) -> Iterator[float]:
    """Nonhomogeneous Poisson with intensity ``rate·(1 +
    amplitude·sin(2πt/period))`` via Lewis–Shedler thinning (candidates
    at the peak rate, accepted with probability ``λ(t)/λ_peak``) — the
    day/night curve. Deterministic for a fixed seed."""
    if rate <= 0:
        raise ValueError("rate must be positive")
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError("amplitude must be in [0, 1]")
    if period <= 0:
        raise ValueError("period must be positive")
    rng = random.Random(f"{seed}-diurnal")
    peak = rate * (1.0 + amplitude)
    t = start
    while True:
        t += rng.expovariate(peak)
        lam = rate * (1.0 + amplitude * math.sin(2 * math.pi * t / period))
        if rng.random() * peak <= lam:
            yield t


def open_loop_trace(tenants: Sequence[TenantSpec], *, n_requests: int,
                    rate: float, seed=0, process: str = "poisson",
                    burst: int = 8, period: float = 200.0,
                    amplitude: float = 0.8,
                    start: float = 0.0) -> Iterator[Arrival]:
    """Lazily generate ``n_requests`` open-loop arrivals over a tenant mix.

    Yields time-ordered, engine-tagged :class:`~repro.serve.sim.Arrival`
    objects one at a time — 10⁶ requests cost no memory beyond the ones
    currently in flight. ``rate`` is the aggregate mean arrival rate (all
    tenants combined) fed to the chosen arrival ``process`` (``"poisson"``,
    ``"bursty"``, or ``"diurnal"``); each arrival then draws its tenant by
    ``share`` and its lengths from that tenant's ranges, all from one
    seeded RNG.

    Deterministic: a fixed ``(tenants, kwargs)`` pair yields a
    bit-identical stream on every call. :class:`Request` objects are
    engine-mutated, so to drive two identical runs call this twice — never
    replay one trace's request objects.
    """
    tenants = tuple(tenants)
    if not tenants:
        raise ValueError("open_loop_trace needs at least one TenantSpec")
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    if process == "poisson":
        times = poisson_times(rate, seed=seed, start=start)
    elif process == "bursty":
        times = bursty_times(rate, seed=seed, burst=burst, start=start)
    elif process == "diurnal":
        times = diurnal_times(rate, seed=seed, period=period,
                              amplitude=amplitude, start=start)
    else:
        raise ValueError(f"unknown arrival process {process!r} "
                         f"(one of {ARRIVAL_PROCESSES})")
    rng = random.Random(f"{seed}-mix")
    indices = list(range(len(tenants)))
    shares = [t.share for t in tenants]
    prefixes = [t.prefix_tokens() for t in tenants]
    for i in range(n_requests):
        t_arr = next(times)
        k = rng.choices(indices, weights=shares)[0]
        spec = tenants[k]
        plen = rng.randint(*spec.prompt_len)
        ntok = rng.randint(*spec.new_tokens)
        # the final prompt token is always fresh (its logits seed
        # generation), so the shared prefix is clamped to plen - 1
        prefix = prefixes[k][:min(spec.prefix_len, plen - 1)]
        tail = [rng.randint(1, spec.vocab)
                for _ in range(plen - len(prefix))]
        sampling = None
        if spec.sampling is not None:
            # the seed draw happens only for sampling tenants, so a trace
            # with no sampling tenant consumes exactly the PR 6 stream
            sampling = dataclasses.replace(spec.sampling,
                                           seed=rng.getrandbits(31))
        req = Request(id=f"{spec.engine}-{i}",
                      prompt=list(prefix) + tail,
                      max_new_tokens=ntok, slo=spec.slo, sampling=sampling,
                      energy_cap_uj_per_token=spec.energy_cap_uj_per_token)
        yield Arrival(t_arr, req, spec.engine)
