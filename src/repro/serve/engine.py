"""Serving: sharded prefill/decode steps and a continuous-batching engine.

The decode step donates the cache (in-place HBM update — the IMC-style
"computation mode" on resident state). Completion of a request is signaled
through the XAIF interrupt analogue (:class:`repro.core.xaif.
InterruptController`), mirroring the paper's accelerator end-of-computation
interrupt, and the finished slot's memory-bank power domains are clock-gated
through the platform :class:`~repro.core.power.PowerManager`.

Two layers live here:

* :func:`build_sharded_serve` — jit + shardings for pod-scale prefill/decode
  (used by the dry-run and the launch drivers, unchanged API).
* :class:`ContinuousBatchingEngine` — a request-level serving loop: FIFO
  admission queue with backpressure, slot-based batching where new requests
  are prefilled into free decode slots *without stopping in-flight decodes*
  (prefill is chunk-granular: up to ``prefill_chunk`` prompt tokens per slot
  per step, so a prefilling slot and a decoding slot ride the same batched
  step), a per-slot lane cache (donated in-place) under an optional
  :class:`repro.serve.pages.PageTable` that shares prompt-prefix pages
  across requests, and preemption-safe replay through
  :class:`repro.runtime.ft.RequestJournal`.

Engine invariants (the test suite holds the engine to these):

* **FIFO admission** — requests are admitted to slots, and complete among
  equal-length requests, strictly in arrival order; preemption re-queues
  in-flight work at the front in the same order.
* **Refcounts never negative** — every ``bank_acquire``/``page acquire``
  is released exactly once (on completion, eviction, or preemption);
  over-release raises instead of corrupting shared state.
* **Replay determinism** — decode is greedy, so replay after ``preempt()``
  reproduces every request's tokens bit-for-bit, with or without prefix
  sharing and chunked prefill; the journal cross-checks each replayed
  token and fails loudly on divergence.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import registry
from repro.models.config import ModelConfig
from repro.runtime.ft import RequestJournal
from repro.serve.pages import PageTable
from repro.sharding import axes as lx_
from repro.sharding import params as P
from repro.sharding import rules as R


@dataclasses.dataclass
class ShardedServe:
    prefill_fn: Any
    decode_fn: Any
    params_abstract: Any
    params_shardings: Any
    cache_abstract: Any
    cache_shardings: Any
    token_sharding: Any
    logit_sharding: Any
    raw_decode_fn: Any = None
    raw_prefill_fn: Any = None


def build_sharded_serve(cfg: ModelConfig, mesh: Mesh, rules: R.Rules,
                        batch: int, max_len: int,
                        prefill_len: int | None = None,
                        fsdp: bool | None = None) -> ShardedServe:
    """jit + shardings for pod-scale prefill/decode of one model config
    (used by the dry-run and launch drivers; API unchanged since PR 0)."""
    from repro.train.trainer import _fsdp_auto

    decls = registry.decls(cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    p_abs = jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
                         P.abstract_tree(decls))
    p_axes = P.axes_tree(decls)
    if fsdp is None:
        fsdp = _fsdp_auto(cfg, mesh)
    param_rules = rules if fsdp else rules.override(
        name=rules.name + "+replicated-weights", **{lx_.EMBED: ()})
    p_shard = R.tree_shardings(p_abs, p_axes, param_rules, mesh)

    c_abs = registry.cache_abstract(cfg, batch, max_len)
    c_axes = registry.cache_axes(cfg)
    c_shard = R.tree_shardings(c_abs, c_axes, rules, mesh)

    tok_shard = NamedSharding(mesh, R.spec_for((batch, 1), (lx_.DECODE_BATCH, None),
                                               rules, mesh))
    logit_shard = NamedSharding(
        mesh, R.spec_for((batch, cfg.vocab), (lx_.DECODE_BATCH, lx_.VOCAB),
                         rules, mesh))

    def decode(params, cache, tokens):
        return registry.decode_step(params, cfg, cache, tokens)

    decode_fn = jax.jit(decode,
                        in_shardings=(p_shard, c_shard, tok_shard),
                        out_shardings=(logit_shard, c_shard),
                        donate_argnums=(1,))

    prefill_fn = None
    if prefill_len:
        if cfg.embed_inputs:
            in_abs = jax.ShapeDtypeStruct((batch, prefill_len), jnp.int32)
            in_shard = NamedSharding(
                mesh, R.spec_for(in_abs.shape, (lx_.DECODE_BATCH, lx_.SEQ),
                                 rules, mesh))

            def pf(params, tokens):
                return registry.prefill(params, cfg, tokens=tokens, max_len=max_len)
        else:
            in_abs = jax.ShapeDtypeStruct((batch, prefill_len, cfg.d_model),
                                          jnp.bfloat16)
            in_shard = NamedSharding(
                mesh, R.spec_for(in_abs.shape,
                                 (lx_.DECODE_BATCH, lx_.SEQ, lx_.EMBED),
                                 rules, mesh))

            def pf(params, embeds):
                return registry.prefill(params, cfg, embeds=embeds, max_len=max_len)

        prefill_fn = jax.jit(pf, in_shardings=(p_shard, in_shard),
                             out_shardings=(logit_shard, c_shard))
        prefill_fn._input_abstract = in_abs  # used by the dry-run

    return ShardedServe(prefill_fn, decode_fn, p_abs, p_shard, c_abs, c_shard,
                        tok_shard, logit_shard,
                        raw_decode_fn=decode,
                        raw_prefill_fn=pf if prefill_len else None)


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

COMPLETE_LINE = "serve.complete"     # interrupt line raised per finished request
ADMIT_LINE = "serve.admit"           # raised per slot admission


# Jitted per-slot kernels are shared across engine instances: one step
# function per model config (jax then caches compilations by slot count /
# cache shapes), one reset function globally.
_STEP_FNS: dict = {}
_CHUNK_FNS: dict = {}
_RESET_FN = None


def _slot_step_fn(cfg: ModelConfig):
    # ModelConfig is a frozen (hashable) dataclass; an unhashable config
    # must fail loudly here rather than risk a wrong-model cache collision
    if cfg not in _STEP_FNS:
        def one(params, cache, tok):
            logits, cache = registry.decode_step(params, cfg, cache, tok)
            return jnp.argmax(logits, -1)[0].astype(jnp.int32), cache

        vstep = jax.vmap(one, in_axes=(None, 0, 0))
        _STEP_FNS[cfg] = jax.jit(vstep, donate_argnums=(1,))
    return _STEP_FNS[cfg]


def _chunk_step_fn(cfg: ModelConfig, chunk: int):
    """Per-slot step feeding up to ``chunk`` tokens in one launch.

    Each lane scans over its token buffer; iterations past the lane's
    ``count`` are masked out (the cache carry keeps the old values bitwise,
    so a decode lane with ``count == 1`` is untouched by the padding). The
    returned token is the argmax after the lane's last *fed* token — for a
    lane that just consumed its final prompt token, that is its first
    generated token.
    """
    key = (cfg, chunk)
    if key not in _CHUNK_FNS:
        def one(params, cache, toks, count):
            def body(cache, xs):
                j, tok = xs
                logits, new_cache = registry.decode_step(params, cfg, cache, tok)
                out = jnp.argmax(logits, -1)[0].astype(jnp.int32)
                keep = j < count
                cache = jax.tree.map(
                    lambda n, o: jnp.where(keep, n, o), new_cache, cache)
                return cache, out

            cache, outs = jax.lax.scan(
                body, cache, (jnp.arange(chunk, dtype=jnp.int32), toks))
            last = jax.lax.dynamic_index_in_dim(
                outs, jnp.maximum(count - 1, 0), 0, keepdims=False)
            return last, cache

        vstep = jax.vmap(one, in_axes=(None, 0, 0, 0))
        _CHUNK_FNS[key] = jax.jit(vstep, donate_argnums=(1,))
    return _CHUNK_FNS[key]


def _slot_reset_fn():
    global _RESET_FN
    if _RESET_FN is None:
        def reset(cache, slot, template):
            # reset one page to the cache family's true initial values (the
            # template), not to zeros — a future family may init non-zero
            return jax.tree.map(
                lambda leaf, init: leaf.at[slot].set(init), cache, template)

        _RESET_FN = jax.jit(reset, donate_argnums=(0,))
    return _RESET_FN


@dataclasses.dataclass
class Request:
    """One generation request. ``tokens`` is filled in by the engine."""

    id: str
    prompt: Sequence[int]
    max_new_tokens: int
    on_complete: Callable[["Request"], None] | None = None
    # engine-written bookkeeping
    tokens: list = dataclasses.field(default_factory=list)
    arrival_time: float | None = None
    admit_time: float | None = None
    finish_time: float | None = None

    def __post_init__(self):
        self.prompt = tuple(int(t) for t in self.prompt)
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass
class _Slot:
    """Host-side state of one decode slot (device state lives in the cache)."""

    request: Request
    seq: int                 # FIFO sequence number of the request
    fed: int = 0             # tokens already consumed (prompt, then generated)
    produced: int = 0        # generated tokens so far
    next_token: int = 0      # token to feed at the next engine step
    page_keys: tuple = ()    # pinned shared-prefix pages (released on evict)
    pending_snapshot: Any = None   # shared state to copy-on-write at 1st step

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.request.prompt)


class ContinuousBatchingEngine:
    """Slot-based continuous batching over a per-slot paged cache.

    Each of the ``slots`` decode lanes holds one request's cache page —
    built as ``vmap`` over the batch-1 decode step, so every slot carries
    its *own* position counter and its lane is bit-independent of the other
    lanes' contents. One :meth:`step` advances every occupied lane by one
    token: lanes still consuming their prompt are teacher-forced (token-
    granular prefill), lanes past it decode greedily. New requests are
    admitted into free lanes between steps; in-flight lanes never stop.

    The engine is deliberately clock-agnostic: pass ``clock`` (any
    ``() -> float``) and drive :meth:`step` from a scheduler or from the
    deterministic simulation harness in :mod:`repro.serve.sim`.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_len: int,
                 platform=None, queue_capacity: int | None = None,
                 clock: Callable[[], float] = lambda: 0.0,
                 journal: RequestJournal | None = None,
                 pad_token: int = 0, prefill_chunk: int = 1,
                 page_size: int | None = None,
                 page_table: PageTable | None = None,
                 page_capacity: int | None = None):
        from repro.core.platform import Platform, XHeepConfig

        if slots < 1:
            raise ValueError("engine needs at least one decode slot")
        if max_len < 2:
            raise ValueError("max_len must fit a prompt token plus one output")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.cfg = cfg
        self.params = params
        self.n_slots = slots
        self.max_len = max_len
        owns_platform = platform is None
        self.platform = platform or Platform(XHeepConfig())
        self.queue_capacity = queue_capacity
        self.clock = clock
        self.journal = journal or RequestJournal()
        self.pad_token = pad_token
        self.prefill_chunk = prefill_chunk
        # pass `page_table` to share one prefix store across engines (same
        # cfg/max_len), or just `page_size` for an engine-private table.
        # The private table is always bounded (every resident page retains a
        # full max_len cache snapshot); build a PageTable(capacity_pages=
        # None) yourself if you really want unbounded residency.
        if page_table is not None:
            self.pages: PageTable | None = page_table
        elif page_size:
            self.pages = PageTable(
                page_size,
                capacity_pages=(page_capacity if page_capacity is not None
                                else 16 * slots),
                platform=self.platform)
        else:
            self.pages = None

        self.queue: collections.deque[Request] = collections.deque()
        self._ids: set[str] = set()            # every id ever submitted
        self.slots: list[_Slot | None] = [None] * slots
        self._dirty: set[int] = set()          # lanes holding a dead cache page
        self._seq = 0

        # throughput counters — monotone by construction
        self.steps = 0
        self.tokens_generated = 0
        self.prompt_tokens_processed = 0
        self.prompt_tokens_reused = 0
        self.completed: list[Request] = []
        self.rejected = 0

        self._step_fn = _slot_step_fn(cfg)
        self._chunk_fn = (_chunk_step_fn(cfg, prefill_chunk)
                          if prefill_chunk > 1 else None)
        self._reset_fn = _slot_reset_fn()
        self._page_template = registry.cache_init(cfg, 1, max_len)
        self._cache = self._init_cache()

        n_banks = self.platform.config.n_banks
        self._slot_bank = [f"bank{i % n_banks}" for i in range(slots)]
        # our own platform: the whole idle bank pool starts gated. A shared
        # platform's states are left untouched at construction — another
        # engine may have live slot state in any bank; all wake/gate
        # transitions go through the platform's shared bank refcounts.
        if owns_platform:
            for i in range(n_banks):
                self.platform.power.clock_gate(f"bank{i}")

    # -- device-state plumbing ----------------------------------------------

    def _init_cache(self):
        # one page per slot, each an exact copy of the family's batch-1
        # initial cache (not assumed to be zeros)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_slots,) + x.shape),
            self._page_template)

    # -- admission -----------------------------------------------------------

    def submit(self, request: Request) -> bool:
        """Enqueue a request; False (and counted) when backpressure rejects it."""
        if len(request.prompt) + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {request.id!r} needs "
                f"{len(request.prompt) + request.max_new_tokens} positions, "
                f"engine max_len is {self.max_len}")
        if request.id in self._ids:
            # ids key the journal; a duplicate would silently interleave two
            # requests' tokens into one record and break preemption replay
            raise ValueError(f"duplicate request id {request.id!r}")
        if (self.queue_capacity is not None
                and len(self.queue) >= self.queue_capacity):
            self.rejected += 1
            return False
        request.arrival_time = (request.arrival_time
                                if request.arrival_time is not None
                                else self.clock())
        self._ids.add(request.id)
        self.queue.append(request)
        return True

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if not self.queue:
                break
            if self.slots[i] is not None:
                continue
            req = self.queue.popleft()              # FIFO — fairness invariant
            match = (self.pages.acquire(req.prompt)
                     if self.pages is not None else None)
            if match is None and i in self._dirty:
                self._cache = self._reset_fn(self._cache, i,
                                             self._page_template)
                self._dirty.discard(i)
            rec = self.journal.open(req.id, req.prompt, req.max_new_tokens)
            req.tokens = []
            req.admit_time = self.clock()
            slot = _Slot(request=req, seq=rec.arrival_seq)
            if match is not None:
                # shared prefix admitted pre-consumed: no reset needed (the
                # snapshot overwrites the whole lane), and the lane copy is
                # deferred to the first step — copy-on-write, so a slot
                # preempted before it runs never pays for the copy
                slot.fed = match.tokens_matched
                slot.page_keys = match.keys
                slot.pending_snapshot = match.snapshot
                self.prompt_tokens_reused += match.tokens_matched
            slot.next_token = req.prompt[slot.fed]
            self.journal.note_prefix(req.id, slot.fed, slot.page_keys)
            self.slots[i] = slot
            # shared refcount wakes the bank if idle
            self.platform.bank_acquire(self._slot_bank[i])
            self.platform.interrupts.fire(ADMIT_LINE, req)

    # -- the engine step ------------------------------------------------------

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def busy(self) -> bool:
        return self.active > 0 or bool(self.queue)

    def step(self) -> bool:
        """Admit, then advance every occupied lane one scheduling step.

        A decoding lane consumes exactly one token per step; a prefilling
        lane consumes up to ``prefill_chunk`` prompt tokens (clamped to the
        next page boundary when prefix sharing is on, so every lane state
        that completes a page is publishable). Returns False when idle.
        """
        self._admit()
        if self.active == 0:
            return False
        self._apply_pending_snapshots()
        chunk = self.prefill_chunk
        toks = np.full((self.n_slots, chunk, 1, 1), self.pad_token, np.int32)
        counts = np.zeros((self.n_slots,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            if slot.prefilling:
                prompt = slot.request.prompt
                n = min(chunk, len(prompt) - slot.fed)
                if self.pages is not None:
                    n = min(n, self.pages.page_size
                            - slot.fed % self.pages.page_size)
                for j in range(n):
                    toks[i, j, 0, 0] = prompt[slot.fed + j]
            else:
                n = 1
                toks[i, 0, 0, 0] = slot.next_token
            counts[i] = n
        # empty lanes still ride the batched step (pad token): their pages are
        # garbage afterwards and must be reset before the next admission
        self._dirty.update(i for i, s in enumerate(self.slots) if s is None)
        if chunk == 1 or int(counts.max()) <= 1:
            # steady-state decode: every lane feeds one token, so skip the
            # chunk scan (it would run chunk-1 masked iterations per lane)
            nxt, self._cache = self._step_fn(self.params, self._cache,
                                             jnp.asarray(toks[:, 0]))
        else:
            nxt, self._cache = self._chunk_fn(self.params, self._cache,
                                              jnp.asarray(toks),
                                              jnp.asarray(counts))
        nxt = np.asarray(jax.device_get(nxt))
        self.steps += 1
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            was_prefilling = slot.prefilling
            slot.fed += int(counts[i])
            if was_prefilling:
                self.prompt_tokens_processed += int(counts[i])
                self._maybe_publish(i, slot)
            if slot.prefilling:
                # still consuming the prompt: teacher-force the next token
                slot.next_token = slot.request.prompt[slot.fed]
                continue
            tok = int(nxt[i])
            slot.request.tokens.append(tok)
            self.journal.record_token(slot.request.id, tok)
            slot.produced += 1
            self.tokens_generated += 1
            slot.next_token = tok
            if slot.produced >= slot.request.max_new_tokens:
                self._complete(i)
        return True

    def _apply_pending_snapshots(self) -> None:
        """Copy-on-write: a slot admitted onto shared pages borrows them at
        admission; its private lane copy materialises here, right before
        the lane writes its first divergent token."""
        for i, slot in enumerate(self.slots):
            if slot is None or slot.pending_snapshot is None:
                continue
            self._cache = self._reset_fn(self._cache, i,
                                         slot.pending_snapshot)
            slot.pending_snapshot = None
            self._dirty.discard(i)
            self.pages.note_cow(len(slot.page_keys))

    def _maybe_publish(self, i: int, slot: _Slot) -> None:
        """Publish lane ``i``'s state when prefill lands on a page boundary
        (chunk feeds are clamped so boundaries are always hit exactly)."""
        if self.pages is None:
            return
        fed = slot.fed
        if fed % self.pages.page_size != 0:
            return
        key = slot.request.prompt[:fed]
        if not self.pages.wants(key):
            return
        snapshot = jax.tree.map(lambda x: x[i], self._cache)
        self.pages.publish(key, snapshot)

    def _complete(self, i: int) -> None:
        slot = self.slots[i]
        req = slot.request
        req.finish_time = self.clock()
        self.journal.complete(req.id)
        self._evict(i)
        self.completed.append(req)
        # XAIF end-of-computation interrupt, then the per-request handler
        self.platform.interrupts.fire(COMPLETE_LINE, req)
        if req.on_complete is not None:
            req.on_complete(req)

    def _evict(self, i: int) -> None:
        slot = self.slots[i]
        if slot is not None and slot.page_keys:
            # refcount release — pinned pages outlive the slot only through
            # the table's own residency, never through this pin
            self.pages.release(slot.page_keys)
            slot.page_keys = ()
            slot.pending_snapshot = None
        self.slots[i] = None
        self._dirty.add(i)
        # shared refcount: gates only when no engine holds the bank
        self.platform.bank_release(self._slot_bank[i])

    @property
    def _bank_load(self) -> dict[str, int]:
        """This engine's live slots per bank — derived, single source of
        truth is slot occupancy (the platform refcounts span all engines)."""
        load = {b: 0 for b in set(self._slot_bank)}
        for i, s in enumerate(self.slots):
            if s is not None:
                load[self._slot_bank[i]] += 1
        return load

    # -- preemption -----------------------------------------------------------

    def preempt(self) -> list[Request]:
        """Evict every lane; re-queue in-flight requests in FIFO order.

        Greedy decode is deterministic, so replay from the journal's prompts
        reproduces the preempted requests' outputs bit-for-bit.
        """
        inflight = sorted(
            ((i, s) for i, s in enumerate(self.slots) if s is not None),
            key=lambda t: t[1].seq)
        for i, _ in inflight:
            self._evict(i)
        requeued = [s.request for _, s in inflight]
        for req in requeued:
            req.tokens = []
            req.admit_time = req.finish_time = None
        self.queue.extendleft(reversed(requeued))
        return requeued

    # -- convenience ----------------------------------------------------------

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        """Step until queue and slots drain (raises if still busy after
        ``max_steps`` — a missing-completion canary for tests)."""
        for _ in range(max_steps):
            if not self.step():
                return
        raise RuntimeError(f"engine still busy after {max_steps} steps")

    def drain_completed(self) -> list[Request]:
        """Hand off finished requests and release their retained state.

        A long-running serving loop must call this periodically (after
        delivering results) or per-request history — completed list, journal
        records, id registry — grows without bound. Drained ids become
        reusable.
        """
        done, self.completed = self.completed, []
        for req in done:
            self.journal.evict(req.id)
            self._ids.discard(req.id)
        return done

    def stats(self) -> dict:
        """Lifetime counters (monotone), plus page-table stats when the
        paged prefix cache is enabled."""
        out = {
            "steps": self.steps,
            "tokens_generated": self.tokens_generated,
            "prompt_tokens_processed": self.prompt_tokens_processed,
            "prompt_tokens_reused": self.prompt_tokens_reused,
            "prefill_chunk": self.prefill_chunk,
            "completed": len(self.completed),
            "rejected": self.rejected,
            "queued": len(self.queue),
            "active": self.active,
        }
        if self.pages is not None:
            out["pages"] = dict(self.pages.stats,
                                resident=self.pages.resident,
                                pinned=self.pages.pinned)
        return out
