"""Serving: sharded prefill/decode steps and a continuous-batching engine.

The decode step runs against donated device state (in-place HBM update —
the IMC-style "computation mode" on resident state). Completion of a
request is signaled through the XAIF interrupt analogue
(:class:`repro.core.xaif.InterruptController`), mirroring the paper's
accelerator end-of-computation interrupt, and the finished slot's
memory-bank power domains are clock-gated through the platform
:class:`~repro.core.power.PowerManager`.

Two layers live here:

* :func:`build_sharded_serve` — jit + shardings for pod-scale prefill/decode
  (used by the dry-run and the launch drivers, unchanged API).
* :class:`ContinuousBatchingEngine` — a request-level serving loop: FIFO
  admission queue with backpressure, slot-based batching where new requests
  are prefilled into free decode slots *without stopping in-flight decodes*
  (prefill is chunk-granular), and preemption-safe replay through
  :class:`repro.runtime.ft.RequestJournal`.

Two device backends serve the slots:

* **paged** (default for transformer-family configs, including
  sliding-window ones) — one global KV page pool plus per-slot block
  tables (:mod:`repro.serve.paged`), decoded by the fused paged-attention
  kernel (:mod:`repro.kernels.paged_attention`). Prefix sharing is
  block-table pointing: adopting a resident chain pins page ids (no
  copy-on-write lane materialisation), publishing a completed page is a
  refcount bump (no device gather), and two cold same-prefix prefills
  dedup — the later one stalls on the earlier one's claim, then adopts
  its published pages (mid-flight re-match). Sliding-window configs run
  the same path with **ring block tables**: a slot's table holds at most
  ``ceil(window/page_size) + 1`` entries; when the oldest page falls
  wholly outside the window its table entry is reused — a private page
  goes back to the pool's free list, an adopted shared-prefix page is
  *disowned* (pool ref + table pin released; the table's own residency
  keeps it warm for future admissions) — so a long-running windowed
  request holds O(window) device pages instead of O(seq), and prefix
  adoption is clamped to the pages the window can still see.
* **lanes** (SSM/hybrid/MoE configs, and engines sharing an external
  page table *without* a shared pool) — the PR 2 layout: one full-length
  cache lane per slot (``vmap`` over batch-1 decode), snapshot pages,
  copy-on-write at the slot's first step. Pass ``paged=False`` to force
  it (e.g. as the bit-identity baseline for windowed paged serving).

Since PR 4 the engine no longer has to own its allocation: pass ``pool``
(a cluster-owned :class:`~repro.serve.paged.PagePool`) plus a shared
``page_table`` and the engine becomes one tenant of a multi-model
:class:`~repro.serve.cluster.ServeCluster` — page-table payloads are then
globally valid pool ids, so a shared table no longer forces the lane
backend. ``namespace`` keys the engine's prefix pages (same model + same
weights = same namespace = cross-engine prefix aliasing; different models
stay isolated), ``admission_hook`` lets a scheduler veto each admission
(weighted round-robin grants, power-budget backpressure), and ``reclaim``
replaces the engine's own ``pages.clear()`` under pool pressure with the
cluster's fair cross-tenant eviction.

Dispatch is optionally **async double-buffered** (``async_dispatch=True``):
step N+1 launches before step N's next-token vector is transferred —
decoding lanes take their input token straight from the previous step's
on-device output (the ``feedback`` path), and host bookkeeping for step N
(token journaling, completion interrupts) retires while the device chews
on step N+1. The on-device output is *sampled* per the request's
:class:`~repro.serve.sampling.SamplingParams` (exact argmax at zero
temperature — greedy is the default), with per-lane PRNG keys advancing
on-device in the same launch, so the overlap is invisible in the outputs
for stochastic and greedy decode alike: tokens are bit-identical with
async on or off.

Engine invariants (the test suite holds the engine to these):

* **FIFO admission** — requests are admitted to slots, and complete among
  equal-length requests, strictly in arrival order; preemption re-queues
  in-flight work at the front in the same order.
* **Refcounts never negative** — every ``bank_acquire``/page retain is
  released exactly once (on completion, eviction, or preemption);
  over-release raises instead of corrupting shared state.
* **Replay determinism** — decode is deterministic even when stochastic:
  greedy lanes replay by argmax, sampled lanes re-seed their journaled
  per-request PRNG chain at re-admission and advance it only on emitting
  steps (chain position == produced-token count), so replay after
  ``preempt()`` reproduces every request's tokens bit-for-bit, with or
  without prefix sharing, chunked prefill, paged decode, and async
  dispatch; the journal cross-checks each replayed token and fails loudly
  on divergence.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import registry
from repro.models.config import ModelConfig
from repro.runtime.ft import RequestJournal
from repro.serve.paged import (PagePool, mesh_tp, paged_chunk_fn,
                               paged_step_fn, place_params)
from repro.serve.pages import PageTable
from repro.serve.sampling import (GREEDY, SamplingParams, sample, seed_key,
                                  zero_keys)
from repro.sharding import axes as lx_
from repro.sharding import params as P
from repro.sharding import rules as R


@dataclasses.dataclass
class ShardedServe:
    prefill_fn: Any
    decode_fn: Any
    params_abstract: Any
    params_shardings: Any
    cache_abstract: Any
    cache_shardings: Any
    token_sharding: Any
    logit_sharding: Any
    raw_decode_fn: Any = None
    raw_prefill_fn: Any = None


def build_sharded_serve(cfg: ModelConfig, mesh: Mesh, rules: R.Rules,
                        batch: int, max_len: int,
                        prefill_len: int | None = None,
                        fsdp: bool | None = None) -> ShardedServe:
    """jit + shardings for pod-scale prefill/decode of one model config
    (used by the dry-run and launch drivers; API unchanged since PR 0)."""
    from repro.train.trainer import _fsdp_auto

    decls = registry.decls(cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    p_abs = jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
                         P.abstract_tree(decls))
    p_axes = P.axes_tree(decls)
    if fsdp is None:
        fsdp = _fsdp_auto(cfg, mesh)
    param_rules = rules if fsdp else rules.override(
        name=rules.name + "+replicated-weights", **{lx_.EMBED: ()})
    p_shard = R.tree_shardings(p_abs, p_axes, param_rules, mesh)

    c_abs = registry.cache_abstract(cfg, batch, max_len)
    c_axes = registry.cache_axes(cfg)
    c_shard = R.tree_shardings(c_abs, c_axes, rules, mesh)

    tok_shard = NamedSharding(mesh, R.spec_for((batch, 1), (lx_.DECODE_BATCH, None),
                                               rules, mesh))
    logit_shard = NamedSharding(
        mesh, R.spec_for((batch, cfg.vocab), (lx_.DECODE_BATCH, lx_.VOCAB),
                         rules, mesh))

    def decode(params, cache, tokens):
        return registry.decode_step(params, cfg, cache, tokens)

    decode_fn = jax.jit(decode,
                        in_shardings=(p_shard, c_shard, tok_shard),
                        out_shardings=(logit_shard, c_shard),
                        donate_argnums=(1,))

    prefill_fn = None
    if prefill_len:
        if cfg.embed_inputs:
            in_abs = jax.ShapeDtypeStruct((batch, prefill_len), jnp.int32)
            in_shard = NamedSharding(
                mesh, R.spec_for(in_abs.shape, (lx_.DECODE_BATCH, lx_.SEQ),
                                 rules, mesh))

            def pf(params, tokens):
                return registry.prefill(params, cfg, tokens=tokens, max_len=max_len)
        else:
            in_abs = jax.ShapeDtypeStruct((batch, prefill_len, cfg.d_model),
                                          jnp.bfloat16)
            in_shard = NamedSharding(
                mesh, R.spec_for(in_abs.shape,
                                 (lx_.DECODE_BATCH, lx_.SEQ, lx_.EMBED),
                                 rules, mesh))

            def pf(params, embeds):
                return registry.prefill(params, cfg, embeds=embeds, max_len=max_len)

        prefill_fn = jax.jit(pf, in_shardings=(p_shard, in_shard),
                             out_shardings=(logit_shard, c_shard))
        prefill_fn._input_abstract = in_abs  # used by the dry-run

    return ShardedServe(prefill_fn, decode_fn, p_abs, p_shard, c_abs, c_shard,
                        tok_shard, logit_shard,
                        raw_decode_fn=decode,
                        raw_prefill_fn=pf if prefill_len else None)


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

COMPLETE_LINE = "serve.complete"     # interrupt line raised per finished request
ADMIT_LINE = "serve.admit"           # raised per slot admission


# Jitted per-slot kernels are shared across engine instances: one step
# function per model config (jax then caches compilations by slot count /
# cache shapes), one reset function globally.
_STEP_FNS: dict = {}
_CHUNK_FNS: dict = {}
_RESET_FN = None


def _slot_step_fn(cfg: ModelConfig):
    # ModelConfig is a frozen (hashable) dataclass; an unhashable config
    # must fail loudly here rather than risk a wrong-model cache collision
    if cfg not in _STEP_FNS:
        def one(params, cache, tok, fb, prev, emit, key, temp, tk, tp):
            tok = jnp.where(fb, jnp.full_like(tok, prev), tok)
            logits, cache = registry.decode_step(params, cfg, cache, tok)
            parts = jax.random.split(key)      # [0] carry, [1] use — the
            # same convention as sampling.split_keys, so lane and paged
            # backends walk bit-identical per-request sampling chains
            out = sample(logits[0], parts[1], temp, tk, tp)
            key = jnp.where(emit, parts[0], key)
            return out, cache, key

        vstep = jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0))
        _STEP_FNS[cfg] = jax.jit(vstep, donate_argnums=(1, 6))
    return _STEP_FNS[cfg]


def _chunk_step_fn(cfg: ModelConfig, chunk: int):
    """Per-slot step feeding up to ``chunk`` tokens in one launch.

    Each lane scans over its token buffer; iterations past the lane's
    ``count`` are masked out (the cache carry keeps the old values bitwise,
    so a decode lane with ``count == 1`` is untouched by the padding). The
    returned token is sampled (exact argmax at zero temperature) after the
    lane's last *fed* token — for a lane that just consumed its final
    prompt token, that is its first generated token. The lane's PRNG key
    splits once per launch (every scan iteration draws with the same
    per-launch subkey; only the last fed iteration's token survives, so
    the result is bit-identical to the unchunked path) and the split is
    kept only where ``emit`` is set.
    """
    key = (cfg, chunk)
    if key not in _CHUNK_FNS:
        def one(params, cache, toks, count, fb, prev, emit, rkey, temp,
                tk, tp):
            parts = jax.random.split(rkey)     # [0] carry, [1] use

            def body(cache, xs):
                j, tok = xs
                tok = jnp.where((j == 0) & fb, jnp.full_like(tok, prev), tok)
                logits, new_cache = registry.decode_step(params, cfg, cache, tok)
                out = sample(logits[0], parts[1], temp, tk, tp)
                keep = j < count
                cache = jax.tree.map(
                    lambda n, o: jnp.where(keep, n, o), new_cache, cache)
                return cache, out

            cache, outs = jax.lax.scan(
                body, cache, (jnp.arange(chunk, dtype=jnp.int32), toks))
            last = jax.lax.dynamic_index_in_dim(
                outs, jnp.maximum(count - 1, 0), 0, keepdims=False)
            rkey = jnp.where(emit, parts[0], rkey)
            return last, cache, rkey

        vstep = jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0))
        _CHUNK_FNS[key] = jax.jit(vstep, donate_argnums=(1, 7))
    return _CHUNK_FNS[key]


def _slot_reset_fn():
    global _RESET_FN
    if _RESET_FN is None:
        def reset(cache, slot, template):
            # reset one page to the cache family's true initial values (the
            # template), not to zeros — a future family may init non-zero
            return jax.tree.map(
                lambda leaf, init: leaf.at[slot].set(init), cache, template)

        _RESET_FN = jax.jit(reset, donate_argnums=(0,))
    return _RESET_FN


# Admission-hook verdict telling the engine to drop the queue head
# entirely (latency-SLO admission control: the request can no longer meet
# its SLO, so serving it would waste capacity). Distinct from False (skip
# this slot) and None (stop the admission scan) — see ``_place``.
SHED = object()


@dataclasses.dataclass
class Request:
    """One generation request. ``tokens`` is filled in by the engine.

    ``slo`` (optional) is a latency target the scheduler and the metrics
    layer read (see :class:`repro.serve.metrics.SLO`); the engine itself
    never interprets it. ``sampling`` (optional) selects stochastic
    decoding (:class:`~repro.serve.sampling.SamplingParams`); ``None``
    means greedy — and rides through preemption/requeue untouched, so a
    replayed admission re-seeds the identical sampling chain.
    ``first_token_time`` stamps the retire of the request's first
    generated token (TTFT = that minus ``arrival_time``);
    ``slo_preempts`` counts scheduler-driven preempt-and-requeue demotions
    (see :meth:`ContinuousBatchingEngine.preempt_slot`).
    ``energy_uj`` accumulates the joules a metered engine attributes to
    this request (prefill + decode + page holding + retention — replay
    energy after a preemption or fault is charged on top, like latency);
    ``energy_cap_uj_per_token`` is a tenant cap the energy-aware admission
    policy compares against the engine's projected marginal cost.
    """

    id: str
    prompt: Sequence[int]
    max_new_tokens: int
    on_complete: Callable[["Request"], None] | None = None
    slo: Any = None
    sampling: SamplingParams | None = None
    # engine-written bookkeeping
    tokens: list = dataclasses.field(default_factory=list)
    arrival_time: float | None = None
    admit_time: float | None = None
    first_token_time: float | None = None
    finish_time: float | None = None
    slo_preempts: int = 0
    energy_uj: float = 0.0
    energy_cap_uj_per_token: float | None = None

    def __post_init__(self):
        self.prompt = tuple(int(t) for t in self.prompt)
        if not self.prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass
class _Slot:
    """Host-side state of one decode slot (device state lives in the cache
    lane or, for the paged backend, in the slot's block-table pages)."""

    request: Request
    seq: int                 # FIFO sequence number of the request
    fed: int = 0             # tokens already consumed (prompt, then generated)
    produced: int = 0        # generated tokens so far
    next_token: int = 0      # token to feed at the next engine step
    page_keys: tuple = ()    # pinned shared-prefix pages (released on evict)
    pending_snapshot: Any = None   # lane backend: shared state to CoW at 1st step
    # paged backend: block index -> pool page id. Table entry is
    # ``block % table_width``; for windowed configs the table is a ring, so
    # the dict holds at most ``ceil(window/page_size) + 1`` live blocks
    pages_by_block: dict = dataclasses.field(default_factory=dict)
    blocks_covered: int = 0  # blocks allocated/adopted so far (next to cover)
    claims: list = dataclasses.field(default_factory=list)  # dedup claims held

    @property
    def prefilling(self) -> bool:
        return self.fed < len(self.request.prompt)


@dataclasses.dataclass
class _StepMeta:
    """Host bookkeeping deferred to a step's retire (async dispatch)."""

    emitted: list            # (lane, slot): token value lands at retire
    finished: list           # slots completing in this step, lane order


class ContinuousBatchingEngine:
    """Slot-based continuous batching over paged or per-lane device caches.

    Each of the ``slots`` decode lanes holds one request. One :meth:`step`
    advances every occupied lane: lanes still consuming their prompt are
    teacher-forced (up to ``prefill_chunk`` tokens), lanes past it decode
    under their request's sampling params (greedy by default). New
    requests are admitted into free lanes between steps;
    in-flight lanes never stop. See the module docstring for the paged vs
    lane backends and async double-buffered dispatch.

    The engine is deliberately clock-agnostic: pass ``clock`` (any
    ``() -> float``) and drive :meth:`step` from a scheduler or from the
    deterministic simulation harness in :mod:`repro.serve.sim`.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int, max_len: int,
                 platform=None, queue_capacity: int | None = None,
                 clock: Callable[[], float] = lambda: 0.0,
                 journal: RequestJournal | None = None,
                 pad_token: int = 0, prefill_chunk: int = 1,
                 page_size: int | None = None,
                 page_table: PageTable | None = None,
                 page_capacity: int | None = None,
                 paged: bool | None = None,
                 async_dispatch: bool = False,
                 lane_batch: int | None = None,
                 device_len: int | None = None,
                 pool: PagePool | None = None,
                 namespace: str = "",
                 name: str | None = None,
                 admission_hook=None,
                 reclaim=None,
                 chaos=None,
                 journal_horizon: int | None = None,
                 mesh: Mesh | None = None,
                 tp_axis: str = "model",
                 metered: bool = True,
                 operating_point: str = "max",
                 gate_idle_banks: bool = True):
        from repro.core.platform import Platform, XHeepConfig

        if slots < 1:
            raise ValueError("engine needs at least one decode slot")
        if max_len < 2:
            raise ValueError("max_len must fit a prompt token plus one output")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.cfg = cfg
        # tensor parallelism: a mesh pins this engine's decode to a device
        # slice — params land head-sharded (wq/wk/wv) via place_params,
        # the pool arena shards its KV-head axis, and the jitted step runs
        # under shard_map. All host bookkeeping (slots, block tables,
        # journal, sampling chains) is mesh-invariant, so TP changes
        # where bytes live, never which tokens come out.
        self.mesh = mesh
        self.tp_axis = tp_axis
        self.tp = mesh_tp(mesh, tp_axis) if mesh is not None else 1
        self.params = (place_params(cfg, params, mesh, tp_axis)
                       if mesh is not None else params)
        self.n_slots = slots
        self.max_len = max_len
        owns_platform = platform is None
        self.platform = platform or Platform(XHeepConfig())
        self.queue_capacity = queue_capacity
        self.clock = clock
        self.journal = journal or RequestJournal(horizon=journal_horizon)
        # fault-injection plan (repro.serve.chaos.FaultPlan or None):
        # consulted at the top of every device launch (may raise a
        # retryable DeviceStepFault) and on every retired token (may
        # corrupt the host-transferred value)
        self.chaos = chaos
        self.pad_token = pad_token
        self.prefill_chunk = prefill_chunk
        self.async_dispatch = async_dispatch
        self.namespace = namespace
        self.name = name if name is not None else (namespace or "engine")
        # scheduler callbacks (set by a ServeCluster): ``admission_hook``
        # vetoes each admission, ``reclaim`` replaces pages.clear() under
        # pool pressure with a cross-tenant policy
        self._admission_hook = admission_hook
        self._reclaim = reclaim
        # device-shape canonicalisation: lanes/cache positions may be padded
        # beyond the scheduling shape so engines of different sizes share one
        # compiled step (extra lanes ride idle; extra positions are masked)
        self.n_lanes = max(slots, lane_batch or 0)
        self.device_len = max(max_len, device_len or 0)

        # backend: a global page pool needs family support; an external
        # shared table is paged territory only when its payloads are
        # globally valid pool ids, i.e. the pool is shared (cluster-owned)
        # too — otherwise the table holds other engines' snapshots and the
        # lane backend takes over. Sliding-window configs page like any
        # other transformer config (ring block tables); only MoE routing
        # still forces lanes.
        if pool is not None and not registry.supports_paged(cfg):
            raise ValueError(
                f"{cfg.name} ({cfg.family}) cannot join a shared page pool: "
                "no paged KV decode for this family")
        can_page = registry.supports_paged(cfg) and (
            page_table is None or pool is not None)
        if paged is None:
            paged = can_page
        elif paged and not can_page:
            raise ValueError(
                "paged backend needs a transformer-family KV config (MoE "
                "still routes across lanes) and either an engine-private "
                "page table or a shared (cluster-owned) pool")
        if pool is not None and not paged:
            raise ValueError("a shared pool is a paged-backend resource; "
                             "drop it or drop paged=False")
        if mesh is not None and not paged:
            raise ValueError(
                "tensor parallelism is a paged-backend feature: the lane "
                "backend has no sharded arena to decode against")
        self.paged = paged

        # pass `page_table` to share one prefix store across engines (same
        # cfg/max_len — plus a shared `pool` to stay on the paged backend),
        # or just `page_size` for an engine-private table. The private
        # table is always bounded; build a PageTable(capacity_pages=None)
        # yourself if you really want unbounded residency.
        self._ps = (pool.page_size if pool is not None else page_size) or 16
        np_max = -(-self.device_len // self._ps)
        # sliding-window configs: the device ring modulus is the lane
        # cache length (min(window, device_len) — bit-identity with the
        # lane backend), and a slot's block table is a ring of
        # ceil(window/page_size)+1 entries: by the time an entry is
        # reused, its old block's positions fall wholly outside the window
        if cfg.sliding_window:
            self._window: int | None = min(cfg.sliding_window,
                                           self.device_len)
            self._np_slot = min(np_max, -(-self._window // self._ps) + 1)
        else:
            self._window = None
            self._np_slot = np_max
        cap = 0
        self.owns_pool = pool is None
        self._pool: PagePool | None = pool
        self._arena = None
        if self.paged:
            if self._pool is None:
                if page_size:
                    cap = (page_capacity if page_capacity is not None
                           else 16 * slots)
                # a windowed engine provisions O(window) pages per slot,
                # not O(device_len) — the ring bound is the pool budget
                self._pool = PagePool(slots * self._np_slot + cap, self._ps)
            self._arena = self._pool.arena(cfg, mesh=mesh, tp_axis=tp_axis)
        if page_table is not None:
            self.pages: PageTable | None = page_table
        elif page_size:
            self.pages = PageTable(
                page_size,
                capacity_pages=(page_capacity if page_capacity is not None
                                else 16 * slots),
                platform=self.platform,
                on_evict=(self._pool.release if self.paged else None))
        else:
            self.pages = None
        if (self.paged and self.pages is not None
                and self.pages.page_size != self._ps):
            raise ValueError(
                f"page table page_size {self.pages.page_size} != pool page "
                f"size {self._ps}: paged payloads are pool pages, the two "
                "extents must coincide")

        self.queue: collections.deque[Request] = collections.deque()
        self._ids: set[str] = set()            # every id ever submitted
        self.slots: list[_Slot | None] = [None] * slots
        self._dirty: set[int] = set()          # lanes holding a dead cache page
        self._seq = 0
        self._pending: tuple[_StepMeta, Any] | None = None  # unretired step
        self._prev_nxt = None                  # device argmax of pending step

        # throughput counters — monotone by construction
        self.steps = 0
        self.tokens_generated = 0
        self.prompt_tokens_processed = 0
        self.prompt_tokens_reused = 0
        self.stalls = 0                        # lane-steps waiting on a sibling
        self.admission_stalls = 0              # admissions vetoed by the hook
        self.rematches = 0                     # mid-flight prefix adoptions
        self.rematched_tokens = 0              # prompt tokens adopted mid-flight
        self.pages_recycled = 0                # ring entries reused (windowed)
        self.completed: list[Request] = []
        self.rejected = 0
        self.shed = 0                          # queue heads dropped by the hook
        self.token_faults = 0                  # corrupted tokens refused
        self.replays = 0                       # quarantine-driven requeues
        # corruption quarantine: slots whose retired token failed the
        # vocab range check or the journal cross-check this step — their
        # requests are evicted and replayed by _recover_faulted()
        self._faulted: list[_Slot] = []
        # slot identities a flush-retire must skip (their journal position
        # is behind the in-flight step; delivering would leave a gap)
        self._skip_retire: frozenset = frozenset()
        self._replay_counts: dict[str, int] = {}
        # livelock guard: a request quarantined this many times stops
        # being "transient corruption" and raises (a real divergence bug
        # would otherwise replay forever)
        self.max_replays = 16
        # energy meter: purely observational joule accounting over the
        # calibrated HEEPocrates domain model. It reads launch shapes and
        # page holdings after the fact and never touches tokens, PRNG
        # state, or admission order — metered outputs are bit-identical
        # to metered=False (the property suite holds the engine to that)
        if metered:
            from repro.serve.energy_meter import EnergyMeter

            self._meter: EnergyMeter | None = EnergyMeter(
                point=operating_point, gate_idle_banks=gate_idle_banks)
        else:
            self._meter = None

        if self.paged:
            self._pstep = paged_step_fn(cfg, self._window, mesh=mesh,
                                        tp_axis=tp_axis)
            self._pchunk = (paged_chunk_fn(cfg, prefill_chunk, self._window,
                                           mesh=mesh, tp_axis=tp_axis)
                            if prefill_chunk > 1 else None)
            self._cache = None
        else:
            self._step_fn = _slot_step_fn(cfg)
            self._chunk_fn = (_chunk_step_fn(cfg, prefill_chunk)
                              if prefill_chunk > 1 else None)
            self._reset_fn = _slot_reset_fn()
            self._page_template = registry.cache_init(cfg, 1, self.device_len)
            self._cache = self._init_cache()
        self._zero_prev = jnp.zeros((self.n_lanes,), jnp.int32)
        # per-lane sampling state: the PRNG keys are device state (donated
        # through the jitted step, advanced on-device on emitting steps);
        # the parameters are host arrays converted per launch. Lanes are
        # (re-)seeded at admission; greedy lanes keep temp 0 = exact argmax
        self._keys = zero_keys(self.n_lanes)
        self._temp = np.zeros((self.n_lanes,), np.float32)
        self._topk = np.zeros((self.n_lanes,), np.int32)
        self._topp = np.ones((self.n_lanes,), np.float32)
        self.sampled_requests = 0              # admissions with sampling on

        n_banks = self.platform.config.n_banks
        self._slot_bank = [f"bank{i % n_banks}" for i in range(slots)]
        # our own platform: the whole idle bank pool starts gated. A shared
        # platform's states are left untouched at construction — another
        # engine may have live slot state in any bank; all wake/gate
        # transitions go through the platform's shared bank refcounts.
        if owns_platform:
            for i in range(n_banks):
                self.platform.power.clock_gate(f"bank{i}")

    # -- device-state plumbing ----------------------------------------------

    def _init_cache(self):
        # one lane per device slot, each an exact copy of the family's
        # batch-1 initial cache (not assumed to be zeros)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.n_lanes,) + x.shape),
            self._page_template)

    # -- admission -----------------------------------------------------------

    def submit(self, request: Request) -> bool:
        """Enqueue a request; False (and counted) when backpressure rejects it."""
        if len(request.prompt) + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {request.id!r} needs "
                f"{len(request.prompt) + request.max_new_tokens} positions, "
                f"engine max_len is {self.max_len}")
        if request.id in self._ids:
            # ids key the journal; a duplicate would silently interleave two
            # requests' tokens into one record and break preemption replay
            raise ValueError(f"duplicate request id {request.id!r}")
        if (self.queue_capacity is not None
                and len(self.queue) >= self.queue_capacity):
            self.rejected += 1
            return False
        request.arrival_time = (request.arrival_time
                                if request.arrival_time is not None
                                else self.clock())
        self._ids.add(request.id)
        self.queue.append(request)
        return True

    def _admit(self) -> None:
        free = [i for i in range(self.n_slots) if self.slots[i] is None]
        while self.queue and free:
            i = self._place(free)
            if i is SHED:
                # latency-SLO admission control: the head can no longer
                # meet its SLO, so the scheduler drops it instead of
                # spending a slot on work that is already worthless
                self.queue.popleft()
                self.shed += 1
                continue
            if i is None:
                break        # head unplaceable: FIFO forbids skipping it
            free.remove(i)
            req = self.queue.popleft()              # FIFO — fairness invariant
            self._admit_into(i, req)

    def _place(self, free: list[int]):
        """First free slot the scheduler lets the queue head into (None =
        stalled this step). The hook peeks, never pops: a veto leaves the
        request at the queue head so FIFO order survives the stall. A
        veto's scope is the hook's call: False is per-slot (a later free
        slot may sit on an already-awake bank and admit the same head at
        zero budget cost — and the vetoed slot stays available to the next
        head); None is engine-global (no grant will appear mid-step); the
        ``SHED`` sentinel tells :meth:`_admit` to drop the head outright
        (the one verdict that does pop — admission control, not a stall)."""
        if self._admission_hook is None:
            return free[0]
        for i in free:
            verdict = self._admission_hook(self, i, self.queue[0])
            if verdict is SHED:
                return SHED
            if verdict:
                return i
            self.admission_stalls += 1
            if verdict is None:
                return None
        return None

    def _admit_into(self, i: int, req: Request) -> None:
        """Bind ``req`` to free slot ``i``: page-table acquisition, journal
        open, bank wake, admit interrupt."""
        match = (self.pages.acquire(req.prompt, self.namespace)
                 if self.pages is not None else None)
        if not self.paged and match is None and i in self._dirty:
            self._cache = self._reset_fn(self._cache, i,
                                         self._page_template)
            self._dirty.discard(i)
        rec = self.journal.open(
            req.id, req.prompt, req.max_new_tokens,
            sampling=req.sampling.astuple() if req.sampling else None)
        req.tokens = []
        req.admit_time = self.clock()
        # (re-)seed the lane's sampling chain: replay after any preemption
        # restarts the per-request PRNG chain from the journaled seed, and
        # emit-gated key advance makes chain position == produced count —
        # so the replayed tokens are bit-identical however many prefill
        # launches (prefix adoption, chunking, stalls) the replay takes
        sp = req.sampling or GREEDY
        self._temp[i] = sp.temperature
        self._topk[i] = sp.top_k
        self._topp[i] = sp.top_p
        self._keys = self._keys.at[i].set(jnp.asarray(seed_key(sp.seed)))
        if req.sampling is not None:
            self.sampled_requests += 1
        slot = _Slot(request=req, seq=rec.arrival_seq)
        if match is not None:
            # shared prefix admitted pre-consumed. Paged backend: pure
            # block-table pointing — the chain's pool pages are pinned
            # in place, no state is copied, ever. Lane backend: the lane
            # copy is deferred to the first step (copy-on-write), so a
            # slot preempted before it runs never pays for the copy.
            slot.fed = match.tokens_matched
            if self.paged:
                # window clamp: chain pages wholly below the window the
                # slot will ever attend from (positions < fed+1-window)
                # are never read — their tokens still count as reused
                # (nothing recomputes them), but the slot neither pins
                # them in the pool nor keeps them pinned in the table
                first_needed = 0
                if self._window is not None:
                    first_needed = max(
                        0, slot.fed + 1 - self._window) // self._ps
                kept = []
                for b, (key, idx) in enumerate(zip(match.keys, match.chain)):
                    if b < first_needed:
                        continue
                    self._pool.retain(idx)
                    slot.pages_by_block[b] = idx
                    kept.append(key)
                dropped = match.keys[:len(match.keys) - len(kept)]
                if dropped:
                    self.pages.release(dropped, self.namespace)
                slot.page_keys = tuple(kept)
                slot.blocks_covered = slot.fed // self._ps
            else:
                slot.page_keys = match.keys
                slot.pending_snapshot = match.snapshot
            self.prompt_tokens_reused += match.tokens_matched
        slot.next_token = req.prompt[slot.fed]
        self.journal.note_prefix(req.id, slot.fed, slot.page_keys)
        self.slots[i] = slot
        # shared refcount wakes the bank if idle
        self.platform.bank_acquire(self._slot_bank[i])
        self.platform.interrupts.fire(ADMIT_LINE, req)

    # -- the engine step ------------------------------------------------------

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def busy(self) -> bool:
        return (self.active > 0 or bool(self.queue)
                or self._pending is not None)

    def step(self) -> bool:
        """Admit, then advance every occupied lane one scheduling step.

        A decoding lane consumes exactly one token per step; a prefilling
        lane consumes up to ``prefill_chunk`` prompt tokens (clamped to the
        next page boundary when prefix sharing is on, so every lane state
        that completes a page is publishable), or zero while it waits on a
        sibling computing the same page (dedup stall). With async dispatch
        the launch happens before the *previous* step's host bookkeeping,
        so the device never idles on the host. Returns False when idle.
        """
        if self._faulted:
            # quarantine left by a preemption's pending-flush: recover
            # before dispatch — the faulted slot's next_token is stale
            self._recover_faulted()
        if self._meter is not None:
            residents, idle_banks = self._meter_residents()
            self._meter.tick(self.clock(), residents, idle_banks)
        self._admit()
        if self.active == 0:
            if self._pending is not None:
                self._retire(self._pending)        # drain the in-flight step
                self._pending = None
                self._prev_nxt = None
                if self._faulted:
                    self._recover_faulted()
                return True
            return False
        meta, nxt = self._dispatch()
        self.steps += 1
        if self.async_dispatch:
            prev, self._pending = self._pending, (meta, nxt)
            self._prev_nxt = nxt
            if prev is not None:
                self._retire(prev)   # host catches up while the device runs
        else:
            self._retire((meta, nxt))
        if self._faulted:
            self._recover_faulted()
        return True

    def _dispatch(self) -> tuple[_StepMeta, Any]:
        """Build this step's batch, launch it, and do all host bookkeeping
        that does not need the step's token values (those retire later)."""
        chunk = self.prefill_chunk
        n = self.n_lanes
        toks = np.full((n, chunk), self.pad_token, np.int32)
        counts = np.zeros((n,), np.int32)
        feedback = np.zeros((n,), bool)
        # emit[i]: lane i produces a token this launch (decode steps, and
        # the prefill launch consuming the last prompt token) — the gate
        # on the on-device PRNG key advance, so a lane's sampling-chain
        # position always equals its produced-token count, whatever the
        # chunking / prefix adoption / stall pattern of this particular run
        emit = np.zeros((n,), bool)
        pending_emit = ({i: s for i, s in self._pending[0].emitted}
                        if self._pending is not None else {})

        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            if slot.prefilling:
                if self.paged and self.pages is not None:
                    self._try_rematch(slot)
                prompt = slot.request.prompt
                m = min(chunk, len(prompt) - slot.fed)
                if self.pages is not None:
                    m = min(m, self.pages.page_size
                            - slot.fed % self.pages.page_size)
                if (self.paged and self.pages is not None
                        and self._stalled(slot)):
                    self.stalls += 1
                    continue               # counts[i] stays 0: wait, adopt
                toks[i, :m] = prompt[slot.fed:slot.fed + m]
                counts[i] = m
                emit[i] = slot.fed + m >= len(prompt)
            else:
                counts[i] = 1
                emit[i] = True
                if self.async_dispatch and pending_emit.get(i) is slot:
                    feedback[i] = True     # token rides on-device from step N
                else:
                    toks[i, 0] = slot.next_token
            if self.paged and counts[i]:
                self._ensure_pages(slot, slot.fed + int(counts[i]))

        nxt = self._launch(toks, counts, feedback, emit)
        meta = _StepMeta([], [])
        if self._meter is not None:
            occupied = {self._slot_bank[i]
                        for i, s in enumerate(self.slots) if s is not None}
            launch_idle_banks = len(set(self._slot_bank)) - len(occupied)
            charges = []
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            c = int(counts[i])
            was_prefilling = slot.prefilling
            if was_prefilling and c == 0:
                continue                   # stalled this step
            if self._meter is not None:
                charges.append((slot.request,
                                "prefill" if was_prefilling else "decode",
                                c, self._page_share(slot)))
            slot.fed += c
            if self.paged:
                self._recycle_dead(slot)   # window crossed: free dead blocks
            if was_prefilling:
                self.prompt_tokens_processed += c
                self._maybe_publish(i, slot)
                if slot.prefilling:
                    # still consuming the prompt: teacher-force the next token
                    slot.next_token = slot.request.prompt[slot.fed]
                    continue
                self._drop_claims(slot)    # prefill done; nothing left to claim
            meta.emitted.append((i, slot))
            slot.produced += 1
            self.tokens_generated += 1
            if slot.produced >= slot.request.max_new_tokens:
                # the lane is host-known complete the moment the step is
                # dispatched (greedy decode emits exactly one token per
                # step); free it now so the next admission overlaps with the
                # in-flight computation — the token value lands at retire
                meta.finished.append(slot)
                self._evict(i)
        if self._meter is not None:
            self._meter.charge_step(charges, launch_idle_banks)
        return meta, nxt

    def _launch(self, toks, counts, feedback, emit):
        """One batched device launch; returns the on-device next-token vec
        (sampled per lane — exact argmax for greedy lanes)."""
        if self.chaos is not None:
            # fault-injection point, deliberately before any buffer is
            # donated: a DeviceStepFault here leaves device and host
            # state exactly as they were, so the step is retryable
            # (page allocation above is idempotent-resumable)
            self.chaos.launch(self.name)
        chunk = self.prefill_chunk
        prev = (self._prev_nxt if self._prev_nxt is not None
                else self._zero_prev)
        fb = jnp.asarray(feedback)
        em = jnp.asarray(emit)
        temp = jnp.asarray(self._temp)
        tk = jnp.asarray(self._topk)
        tp = jnp.asarray(self._topp)
        if self.paged:
            arena = self._arena
            tables, lengths = self._build_tables()
            if chunk == 1 or int(counts.max()) <= 1:
                nxt, arena.k, arena.v, self._keys = self._pstep(
                    self.params, arena.k, arena.v, tables, lengths,
                    jnp.asarray(toks[:, 0]), fb, prev,
                    jnp.asarray(counts > 0), em, self._keys, temp, tk, tp)
            else:
                nxt, arena.k, arena.v, self._keys = self._pchunk(
                    self.params, arena.k, arena.v, tables, lengths,
                    jnp.asarray(toks), jnp.asarray(counts), fb, prev,
                    em, self._keys, temp, tk, tp)
            return nxt
        self._apply_pending_snapshots()
        # empty lanes still ride the batched step (pad token): their lanes
        # are garbage afterwards and must be reset before the next admission
        self._dirty.update(i for i, s in enumerate(self.slots) if s is None)
        self._dirty.update(range(self.n_slots, self.n_lanes))
        toks4 = toks.reshape(self.n_lanes, chunk, 1, 1)
        if chunk == 1 or int(counts.max()) <= 1:
            # steady-state decode: every lane feeds one token, so skip the
            # chunk scan (it would run chunk-1 masked iterations per lane)
            nxt, self._cache, self._keys = self._step_fn(
                self.params, self._cache, jnp.asarray(toks4[:, 0]), fb,
                prev, em, self._keys, temp, tk, tp)
        else:
            nxt, self._cache, self._keys = self._chunk_fn(
                self.params, self._cache, jnp.asarray(toks4),
                jnp.asarray(counts), fb, prev, em, self._keys, temp, tk, tp)
        return nxt

    def _retire(self, pending: tuple[_StepMeta, Any]) -> None:
        """Host-side completion of a dispatched step: transfer the argmax
        vector and run everything that needed the token values.

        Every delivered token runs the corruption gate: the chaos hook
        (if any) may corrupt the host-transferred value, and a token
        failing the vocab range check or the journal's replay
        cross-check is *never* journaled or appended — its slot joins
        the quarantine (``_faulted``) and the request replays from the
        journal (:meth:`_recover_faulted`). Slots in ``_skip_retire``
        (an in-flight step flushed during quarantine recovery) are
        skipped outright: their journal position is behind this step,
        so delivering would corrupt the record's sequence.
        """
        meta, nxt = pending
        vals = np.asarray(jax.device_get(nxt)).reshape(-1)
        now = self.clock()
        for i, slot in meta.emitted:
            if id(slot) in self._skip_retire:
                continue
            tok = int(vals[i])
            if self.chaos is not None:
                tok = self.chaos.deliver_token(self.name, tok)
            ok = 0 <= tok < self.cfg.vocab
            if ok:
                try:
                    self.journal.record_token(slot.request.id, tok)
                except RuntimeError:
                    # replay cross-check divergence: an in-range corrupt
                    # token caught against the journaled prior run
                    ok = False
            if not ok:
                self.token_faults += 1
                self._faulted.append(slot)
                continue
            if slot.request.first_token_time is None:
                slot.request.first_token_time = now   # TTFT stamp (at retire:
                # the token is host-visible only once the transfer lands)
            slot.request.tokens.append(tok)
            slot.next_token = tok
        faulted_ids = {id(s) for s in self._faulted}
        for slot in meta.finished:
            if id(slot) in self._skip_retire or id(slot) in faulted_ids:
                continue               # quarantined: must replay, not finish
            req = slot.request
            req.finish_time = self.clock()
            self.journal.complete(req.id)
            self.completed.append(req)
            # XAIF end-of-computation interrupt, then the per-request handler
            self.platform.interrupts.fire(COMPLETE_LINE, req)
            if req.on_complete is not None:
                req.on_complete(req)

    def _recover_faulted(self) -> None:
        """Quarantine recovery: replay every corruption-faulted request.

        The in-flight async step (if any) is flushed first with the
        quarantined slots masked out — their pending token is discarded
        (the journal stops before the corrupted position, and replay
        regenerates everything after it), while innocent lanes retire
        normally. Each faulted request is then evicted and requeued at
        the front with its bookkeeping reset; re-admission reopens the
        journal record (the pre-fault tokens become the ``prior`` run)
        and ``record_token`` cross-checks the replay token-for-token.
        Recovery is charged to the request's own latency: arrival time
        is preserved, so TTFT/TPOT absorb the replay honestly.
        """
        while self._faulted:
            batch, self._faulted = self._faulted, []
            if self._pending is not None:
                self._skip_retire = frozenset(id(s) for s in batch)
                try:
                    self._retire(self._pending)
                finally:
                    self._skip_retire = frozenset()
                self._pending = None
                self._prev_nxt = None
            requeue = []
            for slot in sorted(batch, key=lambda s: s.seq):
                req = slot.request
                n = self._replay_counts.get(req.id, 0) + 1
                self._replay_counts[req.id] = n
                if n > self.max_replays:
                    raise RuntimeError(
                        f"request {req.id!r} quarantined {n} times — "
                        "persistent divergence, not transient corruption")
                for i, s in enumerate(self.slots):
                    if s is slot:
                        self._evict(i)
                        break
                # a host-known-finished slot was already evicted at
                # dispatch; a preemption racing the quarantine may have
                # requeued the request itself — never queue it twice
                if any(r is req for r in self.queue):
                    continue
                req.tokens = []
                req.admit_time = None
                req.first_token_time = req.finish_time = None
                requeue.append(req)
                self.replays += 1
            self.queue.extendleft(reversed(requeue))

    # -- paged-backend plumbing ----------------------------------------------

    def _build_tables(self):
        t = np.full((self.n_lanes, self._np_slot), self._pool.null, np.int32)
        lengths = np.zeros((self.n_lanes,), np.int32)
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            for b, idx in slot.pages_by_block.items():
                t[i, b % self._np_slot] = idx
            lengths[i] = slot.fed
        return jnp.asarray(t), jnp.asarray(lengths)

    def _ensure_pages(self, slot: _Slot, target: int) -> None:
        """Grow the slot's block table to cover positions [0, target).

        Windowed configs: the table is a ring — covering a new block first
        recycles whatever older block occupies its entry (by then that
        block's positions fall wholly outside the window), so the slot
        never holds more than ``ceil(window/page_size) + 1`` pages.
        """
        need = -(-target // self._ps)
        while slot.blocks_covered < need:
            b = slot.blocks_covered
            self._free_entry(slot, b)
            if not self._pool.free_count:
                if self._reclaim is not None:
                    self._reclaim(self)    # cluster: fair cross-tenant evict
                elif self.pages is not None:
                    self.pages.clear()     # recycle unpinned shared residency
            slot.pages_by_block[b] = self._pool.alloc(self.name)
            slot.blocks_covered = b + 1

    def _free_entry(self, slot: _Slot, b: int) -> None:
        """Ring recycling backstop: drop whatever older block occupies
        block ``b``'s table entry. Eager recycling (:meth:`_recycle_dead`)
        normally frees dead blocks the moment they fall out of the window,
        so this fires only for blocks an entry-reuse reaches first (e.g. a
        re-match jump landing on an entry whose old block is still barely
        in-window). No-op for non-windowed slots (the full-width table
        never aliases two blocks onto one entry)."""
        if self._window is None:
            return
        width = self._np_slot
        for b_old in [o for o in slot.pages_by_block
                      if o % width == b % width and o != b]:
            self._recycle_block(slot, b_old)

    def _recycle_dead(self, slot: _Slot) -> None:
        """Eager window recycling: free (or disown) every block whose
        positions fall wholly below the slot's attention window, the
        moment ``fed`` crosses the block boundary — a slot that then
        stalls (dedup wait, scheduler preemption) holds no dead pages
        while its peers fight for the shared free list. Pool occupancy
        drops immediately at the crossing instead of lazily at the ring
        entry's next reuse."""
        if self._window is None or not slot.pages_by_block:
            return
        first_needed = max(0, slot.fed + 1 - self._window) // self._ps
        for b_old in [b for b in slot.pages_by_block if b < first_needed]:
            self._recycle_block(slot, b_old)

    def _recycle_block(self, slot: _Slot, b_old: int) -> None:
        """Release one out-of-window block: a private page returns to the
        pool's free list; an adopted shared-prefix page is *disowned* —
        the slot's pool ref and table pin are released, while the table's
        own residency keeps the page warm for future admissions."""
        self._pool.release(slot.pages_by_block.pop(b_old))
        key = slot.request.prompt[:(b_old + 1) * self._ps]
        if key in slot.page_keys:
            self.pages.release((key,), self.namespace)
            slot.page_keys = tuple(k for k in slot.page_keys if k != key)
        self.pages_recycled += 1
        self.journal.note_recycle(slot.request.id, 1)

    def _try_rematch(self, slot: _Slot) -> None:
        """Mid-flight prefix re-match: adopt a sibling's freshly published
        pages covering tokens this slot has not computed yet. Pure
        block-table surgery — any partially-written private page in the
        adopted range is released (its positions hold the same values the
        shared page does, since both ran the same prompt prefix). Windowed
        slots clamp the adoption to the blocks the window can still see
        after the jump; blocks below it are skipped outright (their tokens
        count as reused, their pages are never pinned)."""
        prompt = slot.request.prompt
        m = self.pages.lookup(prompt, self.namespace)
        if m <= slot.fed:
            return
        ps = self.pages.page_size
        from_block = slot.fed // ps
        if self._window is not None:
            from_block = max(from_block, (m + 1 - self._window) // ps)
        ext = self.pages.acquire_range(prompt, from_block, m // ps,
                                       self.namespace)
        if not ext:
            return
        adopted = m - slot.fed
        for key, idx in ext:
            self._pool.retain(idx)
            b = len(key) // ps - 1
            self._free_entry(slot, b)      # ring: evict the entry's old block
            if b in slot.pages_by_block:
                self._pool.release(slot.pages_by_block[b])
            slot.pages_by_block[b] = idx
        slot.page_keys += tuple(k for k, _ in ext)
        slot.blocks_covered = max(slot.blocks_covered, m // ps)
        slot.fed = m
        self._recycle_dead(slot)           # the jump may strand dead blocks
        slot.next_token = prompt[m]
        self.prompt_tokens_reused += adopted
        self.rematches += 1
        self.rematched_tokens += adopted
        self.journal.note_rematch(slot.request.id, adopted)

    def _stalled(self, slot: _Slot) -> bool:
        """Dedup of concurrent identical cold prefills: if another live slot
        already claimed the page this slot would compute next, wait (feed
        nothing this step) and adopt the page when it publishes. Claims
        live in the page table's claim registry (keyed by namespace, like
        the pages themselves), so the claimant may belong to *any* engine
        sharing the table — two replicas bursting the same cold prefix
        dedup across engines, not just across one engine's slots. Claims
        are per-page and dropped the moment the claimant crosses the
        boundary, so a waiter never outlives its claimant's current page."""
        prompt = slot.request.prompt
        ps = self.pages.page_size
        boundary = (slot.fed // ps + 1) * ps
        if boundary > len(prompt) - 1:
            return False                   # tail extent: never publishable
        key = prompt[:boundary]
        if self.pages.has(key, self.namespace):
            return False                   # resident: re-match handles it
        claimant = self.pages.claimant(key, self.namespace)
        if claimant is not None and claimant[1] is not slot:
            c_eng, c_slot = claimant
            alive = any(s is c_slot for s in c_eng.slots)
            if alive and c_slot.prefilling:
                return True
            self.pages.unclaim(key, self.namespace)   # stale claim: steal it
        self.pages.claim(key, (self, slot), self.namespace)
        if key not in slot.claims:
            slot.claims.append(key)
        return False

    def _drop_claims(self, slot: _Slot) -> None:
        if self.pages is not None:
            for key in slot.claims:
                claimant = self.pages.claimant(key, self.namespace)
                if claimant is not None and claimant[1] is slot:
                    self.pages.unclaim(key, self.namespace)
        slot.claims = []

    # -- lane-backend plumbing -----------------------------------------------

    def _apply_pending_snapshots(self) -> None:
        """Copy-on-write (lane backend only): a slot admitted onto shared
        pages borrows them at admission; its private lane copy materialises
        here, right before the lane writes its first divergent token."""
        for i, slot in enumerate(self.slots):
            if slot is None or slot.pending_snapshot is None:
                continue
            self._cache = self._reset_fn(self._cache, i,
                                         slot.pending_snapshot)
            slot.pending_snapshot = None
            self._dirty.discard(i)
            self.pages.note_cow(len(slot.page_keys))

    def _maybe_publish(self, i: int, slot: _Slot) -> None:
        """Publish lane ``i``'s state when prefill lands on a page boundary
        (chunk feeds are clamped so boundaries are always hit exactly).
        Paged backend: a refcount bump on the just-completed pool page —
        O(1), no device work. Lane backend: a device gather of the lane."""
        if self.pages is None:
            return
        fed = slot.fed
        if fed % self.pages.page_size != 0:
            return
        key = slot.request.prompt[:fed]
        self.pages.unclaim(key, self.namespace)   # computed: the claim is moot
        if not self.pages.wants(key, self.namespace):
            return
        if self.paged:
            idx = slot.pages_by_block[fed // self.pages.page_size - 1]
            self._pool.retain(idx)         # residency reference
            if not self.pages.publish(key, idx, self.namespace):
                self._pool.release(idx)
        else:
            snapshot = jax.tree.map(lambda x: x[i], self._cache)
            self.pages.publish(key, snapshot, self.namespace)

    def _evict(self, i: int) -> None:
        slot = self.slots[i]
        if slot is not None:
            if slot.page_keys:
                # refcount release — pinned pages outlive the slot only
                # through the table's own residency, never through this pin
                self.pages.release(slot.page_keys, self.namespace)
                slot.page_keys = ()
            slot.pending_snapshot = None
            if self.paged:
                for idx in slot.pages_by_block.values():
                    self._pool.release(idx)
                slot.pages_by_block = {}
            self._drop_claims(slot)
        self.slots[i] = None
        self._dirty.add(i)
        # shared refcount: gates only when no engine holds the bank
        self.platform.bank_release(self._slot_bank[i])

    @property
    def _bank_load(self) -> dict[str, int]:
        """This engine's live slots per bank — derived, single source of
        truth is slot occupancy (the platform refcounts span all engines)."""
        load = {b: 0 for b in set(self._slot_bank)}
        for i, s in enumerate(self.slots):
            if s is not None:
                load[self._slot_bank[i]] += 1
        return load

    # -- energy metering ------------------------------------------------------

    def _page_share(self, slot: _Slot) -> float:
        """Refcount-weighted KV pages this slot holds: shared-prefix pool
        pages split their holding energy 1/refcount across local holders;
        lane-backend slots count their pinned snapshot pages at weight 1.
        Residual shares (table residency, other engines' pins) stay
        uncharged — modeled as gated-off, never double-charged."""
        if self.paged and slot.pages_by_block:
            refs = self._pool.refcounts()
            return sum(1.0 / max(refs.get(idx, 1), 1)
                       for idx in slot.pages_by_block.values())
        return float(len(slot.page_keys))

    def _meter_residents(self):
        """(residents, idle_banks) for the meter's clock tick: every
        occupied slot with its bank-leak weight (a bank shared by k live
        slots splits its retention leakage k ways) and page share, plus
        how many of the engine's banks host no live slot."""
        load = self._bank_load
        residents = []
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            bank_weight = 1.0 / load[self._slot_bank[i]]
            residents.append((slot.request, bank_weight,
                              self._page_share(slot)))
        idle_banks = sum(1 for n in load.values() if n == 0)
        return residents, idle_banks

    def set_operating_point(self, name: str) -> None:
        """Move the engine's energy meter to a named DVFS point (see
        :data:`repro.core.energy.OPERATING_POINTS`). Accounting only:
        the throttled engine's tokens stay bit-identical — only the
        joules-per-token bookkeeping changes."""
        if self._meter is None:
            raise ValueError("engine built with metered=False has no "
                             "operating point to set")
        self._meter.set_point(name)

    # -- preemption -----------------------------------------------------------

    def preempt(self) -> list[Request]:
        """Evict every lane; re-queue in-flight requests in FIFO order.

        Decode is deterministic (greedy by argmax; sampled lanes re-seed
        their journaled PRNG chain at re-admission), so replay from the
        journal's prompts reproduces the preempted requests' outputs
        bit-for-bit. An in-flight
        async step is retired first — its tokens belong to the
        pre-preemption run and seed the journal's divergence cross-check.
        """
        if self._pending is not None:
            self._retire(self._pending)
            self._pending = None
            self._prev_nxt = None
        inflight = sorted(
            ((i, s) for i, s in enumerate(self.slots) if s is not None),
            key=lambda t: t[1].seq)
        for i, _ in inflight:
            self._evict(i)
        requeued = [s.request for _, s in inflight]
        for req in requeued:
            req.tokens = []
            req.admit_time = req.first_token_time = req.finish_time = None
        self.queue.extendleft(reversed(requeued))
        return requeued

    def preempt_slot(self, i: int, *, front: bool = True) -> Request | None:
        """Preempt one slot: evict lane ``i`` and re-queue its request —
        at the queue front (default, preserving FIFO order like
        :meth:`preempt`) or at the back (``front=False``, the scheduler's
        demote-a-tail move: an SLO-busting request gives up its slot and
        finishes after the salvageable work). Replay runs through the
        same journal machinery as :meth:`preempt`, so the requeued
        request's tokens are reproduced bit-for-bit; an in-flight async
        step is retired first, seeding the journal's divergence
        cross-check. Returns the requeued request, or None when the slot
        is empty (possibly because the flush just completed it)."""
        if self._pending is not None:
            self._retire(self._pending)
            self._pending = None
            self._prev_nxt = None
        slot = self.slots[i]
        if slot is None:
            return None
        self._evict(i)
        req = slot.request
        req.tokens = []
        req.admit_time = req.first_token_time = req.finish_time = None
        if front:
            self.queue.appendleft(req)
        else:
            self.queue.append(req)
        return req

    # -- convenience ----------------------------------------------------------

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        """Step until queue and slots drain (raises if still busy after
        ``max_steps`` — a missing-completion canary for tests)."""
        for _ in range(max_steps):
            if not self.step():
                return
        raise RuntimeError(f"engine still busy after {max_steps} steps")

    def drain_completed(self) -> list[Request]:
        """Hand off finished requests and release their retained state.

        A long-running serving loop must call this periodically (after
        delivering results) or per-request history — completed list, journal
        records, id registry — grows without bound. Drained ids become
        reusable.
        """
        done, self.completed = self.completed, []
        for req in done:
            self.journal.evict(req.id)
            self._ids.discard(req.id)
            self._replay_counts.pop(req.id, None)
        return done

    def occupancy(self) -> dict:
        """Point-in-time load for a scheduler to arbitrate on: slot and
        queue occupancy plus this engine's slice of the (possibly shared)
        page pool. One source of truth — :meth:`stats` embeds the same
        numbers for benchmarks."""
        out = {
            "slots": self.n_slots,
            "active": self.active,
            "slots_free": self.n_slots - self.active,
            "queued": len(self.queue),
        }
        if self._pool is not None:
            out.update(pool_free=self._pool.free_count,
                       pool_in_use=self._pool.in_use,
                       pool_pages_held=self._pool.in_use_by(self.name))
        return out

    def step_cost(self) -> int:
        """Tokens the next :meth:`step` would feed the device (decode lanes
        at one each, prefilling lanes up to ``prefill_chunk``) — the
        per-step cost signal a cluster scheduler weighs admissions with."""
        cost = 0
        for slot in self.slots:
            if slot is None:
                continue
            if slot.prefilling:
                cost += min(self.prefill_chunk,
                            len(slot.request.prompt) - slot.fed)
            else:
                cost += 1
        return cost

    def stats(self) -> dict:
        """Lifetime counters (monotone), plus page-table/pool stats when the
        paged prefix cache is enabled. The ``pool`` entry reports occupancy
        and free-list length — the cluster scheduler and the benchmarks
        read the same numbers."""
        out = {
            "steps": self.steps,
            "tokens_generated": self.tokens_generated,
            "prompt_tokens_processed": self.prompt_tokens_processed,
            "prompt_tokens_reused": self.prompt_tokens_reused,
            "prefill_chunk": self.prefill_chunk,
            "backend": "paged" if self.paged else "lanes",
            "async_dispatch": self.async_dispatch,
            "tp": self.tp,
            "window": self._window,
            "table_entries_per_slot": self._np_slot if self.paged else None,
            "pages_recycled": self.pages_recycled,
            "stalls": self.stalls,
            "admission_stalls": self.admission_stalls,
            "rematches": self.rematches,
            "rematched_tokens": self.rematched_tokens,
            "completed": len(self.completed),
            "sampled_requests": self.sampled_requests,
            "rejected": self.rejected,
            "shed": self.shed,
            "token_faults": self.token_faults,
            "replays": self.replays,
            "queued": len(self.queue),
            "active": self.active,
            "journal": self.journal.size(),
        }
        if self._meter is not None:
            out["energy"] = self._meter.stats()
        if self.pages is not None:
            out["pages"] = dict(self.pages.stats,
                                resident=self.pages.resident,
                                pinned=self.pages.pinned)
        if self._pool is not None:
            out["pool"] = dict(self._pool.stats,
                               pages=self._pool.n_pages,
                               in_use=self._pool.in_use,
                               free=self._pool.free_count,
                               occupancy=round(
                                   self._pool.in_use / self._pool.n_pages, 4),
                               held_by_engine=self._pool.in_use_by(self.name),
                               shared=not self.owns_pool)
        return out
