"""Serving: sharded prefill + decode steps and a batched generation engine.

The decode step donates the cache (in-place HBM update — the IMC-style
"computation mode" on resident state). Completion of a request batch is
signaled through the XAIF interrupt analogue: a host callback the engine
polls, mirroring the paper's accelerator end-of-computation interrupt."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import registry
from repro.models.config import ModelConfig
from repro.sharding import axes as lx_
from repro.sharding import params as P
from repro.sharding import rules as R


@dataclasses.dataclass
class ShardedServe:
    prefill_fn: Any
    decode_fn: Any
    params_abstract: Any
    params_shardings: Any
    cache_abstract: Any
    cache_shardings: Any
    token_sharding: Any
    logit_sharding: Any
    raw_decode_fn: Any = None
    raw_prefill_fn: Any = None


def build_sharded_serve(cfg: ModelConfig, mesh: Mesh, rules: R.Rules,
                        batch: int, max_len: int,
                        prefill_len: int | None = None,
                        fsdp: bool | None = None) -> ShardedServe:
    from repro.train.trainer import _fsdp_auto

    decls = registry.decls(cfg)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    p_abs = jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
                         P.abstract_tree(decls))
    p_axes = P.axes_tree(decls)
    if fsdp is None:
        fsdp = _fsdp_auto(cfg, mesh)
    param_rules = rules if fsdp else rules.override(
        name=rules.name + "+replicated-weights", **{lx_.EMBED: ()})
    p_shard = R.tree_shardings(p_abs, p_axes, param_rules, mesh)

    c_abs = registry.cache_abstract(cfg, batch, max_len)
    c_axes = registry.cache_axes(cfg)
    c_shard = R.tree_shardings(c_abs, c_axes, rules, mesh)

    tok_shard = NamedSharding(mesh, R.spec_for((batch, 1), (lx_.DECODE_BATCH, None),
                                               rules, mesh))
    logit_shard = NamedSharding(
        mesh, R.spec_for((batch, cfg.vocab), (lx_.DECODE_BATCH, lx_.VOCAB),
                         rules, mesh))

    def decode(params, cache, tokens):
        return registry.decode_step(params, cfg, cache, tokens)

    decode_fn = jax.jit(decode,
                        in_shardings=(p_shard, c_shard, tok_shard),
                        out_shardings=(logit_shard, c_shard),
                        donate_argnums=(1,))

    prefill_fn = None
    if prefill_len:
        if cfg.embed_inputs:
            in_abs = jax.ShapeDtypeStruct((batch, prefill_len), jnp.int32)
            in_shard = NamedSharding(
                mesh, R.spec_for(in_abs.shape, (lx_.DECODE_BATCH, lx_.SEQ),
                                 rules, mesh))

            def pf(params, tokens):
                return registry.prefill(params, cfg, tokens=tokens, max_len=max_len)
        else:
            in_abs = jax.ShapeDtypeStruct((batch, prefill_len, cfg.d_model),
                                          jnp.bfloat16)
            in_shard = NamedSharding(
                mesh, R.spec_for(in_abs.shape,
                                 (lx_.DECODE_BATCH, lx_.SEQ, lx_.EMBED),
                                 rules, mesh))

            def pf(params, embeds):
                return registry.prefill(params, cfg, embeds=embeds, max_len=max_len)

        prefill_fn = jax.jit(pf, in_shardings=(p_shard, in_shard),
                             out_shardings=(logit_shard, c_shard))
        prefill_fn._input_abstract = in_abs  # used by the dry-run

    return ShardedServe(prefill_fn, decode_fn, p_abs, p_shard, c_abs, c_shard,
                        tok_shard, logit_shard,
                        raw_decode_fn=decode,
                        raw_prefill_fn=pf if prefill_len else None)


# ---------------------------------------------------------------------------
# Simple engine loop (examples / CPU-scale serving)
# ---------------------------------------------------------------------------


class Engine:
    """Greedy batched generation with an interrupt-style completion callback."""

    def __init__(self, cfg: ModelConfig, params, mesh: Mesh, rules: R.Rules,
                 batch: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.sv = build_sharded_serve(cfg, mesh, rules, batch, max_len,
                                      prefill_len=None)
        self.batch = batch
        self.max_len = max_len

    def generate(self, prompt_tokens, steps: int, on_complete=None):
        cache = registry.cache_init(self.cfg, self.batch, self.max_len)
        toks = prompt_tokens
        out = []
        # teacher-forced prompt consumption (simple engine: token-by-token)
        for t in range(prompt_tokens.shape[1]):
            logits, cache = self.sv.decode_fn(self.params, cache, toks[:, t:t + 1])
        nxt = jnp.argmax(logits, -1)[:, None]
        for _ in range(steps):
            out.append(nxt)
            logits, cache = self.sv.decode_fn(self.params, cache, nxt)
            nxt = jnp.argmax(logits, -1)[:, None]
        result = jnp.concatenate(out, axis=1)
        if on_complete is not None:
            on_complete(result)   # XAIF interrupt analogue
        return result
