"""Multi-model serving cluster: N engines, one pool, one power budget.

This is the serving rendition of the paper's HEEPocrates example — several
heterogeneous compute units (there: CGRA, IMC, crypto accelerators; here:
per-model :class:`~repro.serve.engine.ContinuousBatchingEngine` instances)
running concurrently against **one** bus/memory pool (here: one
:class:`~repro.serve.paged.PagePool` + one
:class:`~repro.serve.pages.PageTable`) and **one** power manager budget
(here: a :class:`PowerBudget` over the shared
:class:`~repro.core.power.PowerManager`). The cluster owns allocation; the
engines are tenants.

What the :class:`ServeCluster` arbitrates:

* **Admission (weighted round-robin).** Every cluster step opens a round
  of per-engine admission grants equal to each tenant's ``weight``
  (default: its slot count, i.e. unthrottled); an engine that spent its
  grants waits for the next round, so a down-weighted tenant's burst
  admits at a bounded rate instead of starving its peers' share of the
  power/pool budget. Engine order rotates per step so ties break fairly.
* **SLO-aware scheduling (opt-in via** :class:`SchedPolicy` **).** The
  ``"drr"`` scheduler replaces flat per-round grants with deficit-weighted
  round-robin over each engine's actual :meth:`step_cost` — lightly loaded
  tenants bank credit and admit; saturated ones wait. ``shed_busted``
  drops queue heads that have already blown their TTFT target (open-loop
  overload serves *fresh* work instead of a stale backlog), and
  ``preempt_busted`` demotes decoding requests past their end-to-end
  deadline to the back of the queue — they replay bit-identically from
  the journal, so SLO enforcement never changes any request's tokens.
* **Power-budget backpressure.** Before an engine admits into a slot, the
  cluster checks whether waking that slot's memory bank would exceed the
  :class:`PowerBudget`. If it would, the admission *stalls* (the request
  stays at the queue head, FIFO intact) instead of exceeding the budget —
  the scheduling analogue of X-HEEP refusing to power up a domain the
  envelope cannot carry. Slots whose bank is already awake ride for free
  (banks are refcount-shared across engines).
* **Fair cross-tenant reclaim.** When the shared pool runs dry, the
  cluster evicts unpinned prefix residency LRU-first from the *namespace
  holding the most evictable pages*, instead of wiping every tenant's
  warm cache at once. (Unlike an engine-private table, the cluster table
  is not platform-wired: resident pages do not hold banks awake, so a
  warm cache can never carry the platform past the power budget — the
  budget governs slot-driven wakes only.)
* **Prefix sharing across engines.** Engines serving the same model (same
  config + weights) declare the same ``namespace`` and alias each other's
  published prefix pages — pool ids are globally valid, so adoption is
  block-table pointing even across engines. Different namespaces never
  alias (same token ids under different weights are different states).
  Sliding-window tenants participate like any other engine (ring block
  tables, PR 5): their recycled pages return to the *shared* free list,
  so an SWA tenant's O(window) footprint frees budget for its peers.
* **Data-parallel replica groups.** :meth:`add_replica_group` builds N
  same-model engines pinned to disjoint mesh slices (each member's page
  arena and sharded params live only on its own devices — see
  :mod:`repro.serve.paged`), addressable under one group name:
  :meth:`submit` routes group traffic with prefix affinity (requests
  sharing a first page land on the same member, so intra-replica prefix
  dedup keeps working), falling back to least-loaded with round-robin
  tie-breaks. Members get per-replica table namespaces (``ns@r0``,
  ``ns@r1``, …) because page *bytes* live on the owning replica's
  devices — a sibling cannot adopt them by block-table pointing, so
  cross-replica aliasing is deliberately off. :meth:`drain_replica`
  live-migrates a member's work onto its siblings (elastic scale-in):
  preempt flushes its tokens to the journal, each in-flight record is
  :meth:`~repro.runtime.ft.RequestJournal.transfer`-red into a sibling's
  journal, and replay there is cross-checked token-for-token against the
  drained member's output — migration meets the same bit-identity bar as
  crash rebuild.

Invariants (held by ``tests/test_cluster.py``):

* **Per-engine bit-identity.** A request's tokens are identical whether
  its engine runs alone or as a cluster tenant — sharing, stalls, and
  reclaim are scheduling/memory effects only, never numerical ones.
* **The budget is never exceeded.** Admissions stall rather than wake a
  bank past the budget; a budget so tight that no progress is possible
  raises loudly instead of spinning.
* **Preempt/replay stays per-engine deterministic.** ``preempt()``
  flushes and requeues every tenant; each engine's journal cross-checks
  its own replay tokens (the :class:`~repro.runtime.ft.ClusterJournal`
  keeps them separate). This holds for stochastic traffic too: a
  request's :class:`~repro.serve.sampling.SamplingParams` ride on the
  :class:`~repro.serve.engine.Request` through every scheduler move
  (shed exemption, ``preempt_busted`` demotion, full preemption), and
  re-admission re-seeds the journaled per-request PRNG chain — so
  sampled tokens, like greedy ones, are bit-identical whichever policy
  served them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.power import PowerState
from repro.models import registry
from repro.models.config import ModelConfig
from repro.runtime.ft import ClusterJournal, FTConfig, FTController
from repro.serve.chaos import AllocFault, DeviceStepFault
from repro.serve.engine import SHED, ContinuousBatchingEngine, Request
from repro.serve.paged import PagePool, pool_signature
from repro.serve.pages import PageTable
from repro.serve.sampling import SamplingParams

__all__ = ["PowerBudget", "SchedPolicy", "ServeCluster", "awake_banks"]

# XAIF interrupt lines the fault-recovery layer raises on the platform
CRASH_LINE = "chaos.engine_crash"    # payload: engine name
BANK_FAULT_LINE = "chaos.bank_fault"  # payload: (engine name, bank name)


def awake_banks(platform) -> int:
    """Bank domains currently ``ON`` — the one predicate both the budget
    enforcement and the cluster's introspection count with (a single
    definition keeps the enforced and the reported quantity identical)."""
    return sum(1 for name, state in platform.power.states.items()
               if name.startswith("bank") and state is PowerState.ON)


@dataclasses.dataclass(frozen=True)
class PowerBudget:
    """Envelope the cluster must stay inside when waking memory banks.

    ``max_awake_banks`` caps the number of bank domains in the ``ON``
    state at once (the paper's power-gating view: only so many domains may
    be powered). ``budget_uw`` caps the platform's total µW at
    ``freq_mhz`` instead (meaningful when the platform's domains carry
    real leakage/dynamic coefficients). Either or both may be set; a bank
    that is already awake never re-charges the budget.

    Two energy-aware levers richer than a stall (PR 10):

    * ``throttle_point`` — the DVFS analogue of the paper's §IV-D curve:
      instead of stalling the first admission that would bust the
      envelope, drop the target engine's metered operating point to this
      name (e.g. ``"nominal"`` — calibrated ~5.9× lower power than
      ``"max"``) and admit. An engine already throttled to the point
      stalls as before, so the budget still binds.
    * ``max_uj_per_token`` — energy-aware admission control: shed a
      queue head when the engine's projected marginal joules/token
      exceeds the cap (a per-request ``energy_cap_uj_per_token``
      overrides this cluster-wide default).
    """

    max_awake_banks: int | None = None
    budget_uw: float | None = None
    freq_mhz: float = 100.0
    throttle_point: str | None = None
    max_uj_per_token: float | None = None

    def __post_init__(self):
        if (self.max_awake_banks is None and self.budget_uw is None
                and self.max_uj_per_token is None):
            raise ValueError("budget needs max_awake_banks, budget_uw, or "
                             "max_uj_per_token")
        if self.max_awake_banks is not None and self.max_awake_banks < 1:
            raise ValueError("max_awake_banks must be >= 1 (0 can never "
                             "admit anything)")
        if self.max_uj_per_token is not None and self.max_uj_per_token <= 0:
            raise ValueError("max_uj_per_token must be > 0")
        if self.throttle_point is not None:
            from repro.core.energy import operating_point

            operating_point(self.throttle_point)   # fail fast on typos

    def would_exceed(self, platform, bank: str) -> bool:
        """True when waking ``bank`` (if it is not already ``ON``) would
        push the platform past this budget. Pure query — no state is
        touched."""
        power = platform.power
        if power.state(bank) is PowerState.ON:
            return False
        if self.max_awake_banks is not None:
            if awake_banks(platform) + 1 > self.max_awake_banks:
                return True
        if self.budget_uw is not None:
            dom = power.domains[bank]
            now = power.power_uw(self.freq_mhz)
            delta = (dom.power_uw(PowerState.ON, 0.0, self.freq_mhz)
                     - dom.power_uw(power.state(bank), 0.0, self.freq_mhz))
            if now + delta > self.budget_uw:
                return True
        return False


@dataclasses.dataclass(frozen=True)
class SchedPolicy:
    """How the cluster arbitrates admission and slot tenure.

    ``scheduler`` selects the grant discipline per scheduling round:

    * ``"wrr"`` (default) — flat weighted round-robin: each tenant gets
      ``weight`` admission grants per round, regardless of how much work
      its slots already carry. This is the PR 4 behaviour.
    * ``"drr"`` — deficit-weighted round-robin over
      :meth:`~repro.serve.engine.ContinuousBatchingEngine.step_cost`:
      each round a tenant banks ``max(0, quantum·weight − step_cost())``
      *token* credits (a loaded engine accrues slowly, an idle one fast),
      capped at ``deficit_cap·quantum·weight``, and an admission charges
      the request's full token cost (prompt + max_new_tokens). Admission
      pace thus follows committed device work, not just slot counts.

    The two SLO levers are independent of the grant discipline:

    * ``shed_busted`` — latency-SLO admission control: a queue head whose
      TTFT target is already blown is dropped (shed) instead of admitted;
      under overload, capacity goes to requests that can still meet their
      SLO. A request the scheduler itself previously demoted is exempt —
      it already holds journal state and must finish.
    * ``preempt_busted`` — preempt-and-requeue of SLO-busting long tails:
      a decoding request whose :meth:`~repro.serve.metrics.SLO.deadline`
      has passed while peers queue is evicted and re-queued at the *back*
      (at most once per request; journal replay reproduces its tokens
      bit-for-bit), freeing the slot for salvageable work.
    """

    scheduler: str = "wrr"
    quantum: int = 16        # drr: token credits banked per weight per round
    deficit_cap: int = 4     # drr: max rounds of unspent credit banked
    shed_busted: bool = False
    preempt_busted: bool = False

    def __post_init__(self):
        if self.scheduler not in ("wrr", "drr"):
            raise ValueError(f"unknown scheduler {self.scheduler!r} "
                             "(one of 'wrr', 'drr')")
        if self.quantum < 1:
            raise ValueError("quantum must be >= 1 token")
        if self.deficit_cap < 1:
            raise ValueError("deficit_cap must be >= 1 round")


class ServeCluster:
    """N continuous-batching engines over one pool, table, and platform.

    The cluster owns the shared resources (``pool_pages`` KV pages of
    ``page_size`` tokens, one prefix :class:`PageTable`, one
    :class:`~repro.core.platform.Platform`) and constructs its tenant
    engines via :meth:`add_engine` — engines never allocate for
    themselves. :meth:`step` advances every tenant once on the shared
    clock; admission inside each engine step is arbitrated by the
    cluster's weighted-round-robin grants and the optional
    :class:`PowerBudget`.
    """

    def __init__(self, *, pool_pages: int, page_size: int = 16,
                 platform=None, clock: Callable[[], float] = lambda: 0.0,
                 capacity_pages: int | None = None,
                 power_budget: PowerBudget | None = None,
                 journal: ClusterJournal | None = None,
                 policy: SchedPolicy | None = None,
                 chaos=None,
                 watchdog: FTConfig | None = None,
                 journal_horizon: int | None = None,
                 max_fault_streak: int = 8,
                 degrade_streak: int = 3):
        from repro.core.platform import Platform, XHeepConfig

        owns_platform = platform is None
        self.platform = platform or Platform(XHeepConfig())
        self.clock = clock
        self.budget = power_budget
        self.pool = PagePool(pool_pages, page_size)
        # deliberately NOT platform-wired: an engine-private table holds
        # its resident pages' banks awake (the SRAM-retention analogue),
        # but here bank wakes are governed by the admission-time power
        # budget, and residency waking banks behind the budget's back
        # would let warm caches exceed the envelope. Cluster residency is
        # power-free; the budget caps compute-driven (slot) wakes only.
        self.table = PageTable(
            page_size,
            capacity_pages=(capacity_pages if capacity_pages is not None
                            else pool_pages),
            on_evict=self.pool.release)
        self.journal = journal or ClusterJournal(horizon=journal_horizon)
        self.policy = policy or SchedPolicy()
        self.engines: dict[str, ContinuousBatchingEngine] = {}
        self._weights: dict[str, int] = {}
        self._grants: dict[str, int] = {}
        self._deficit: dict[str, float] = {}  # drr: banked token credits
        self._ns_identity: dict[str, tuple] = {}
        self._rr_offset = 0
        self.steps = 0
        self.power_stalls = 0          # admissions stalled by the budget
        self.dvfs_throttles = 0        # engines dropped to the throttle point
        self.energy_sheds = 0          # heads shed by the joules/token cap
        self.wrr_stalls = 0            # admissions deferred to the next round
        self.sheds = 0                 # SLO-busted heads dropped at admission
        self.slo_preempts = 0          # SLO-busting tails demoted to the back
        self.reclaims: dict[str, int] = {}   # namespace -> pages reclaimed
        # -- fault injection + recovery --------------------------------------
        # chaos (a repro.serve.chaos.FaultPlan, or None) is shared with
        # every tenant engine; the cluster additionally draws per-step
        # crash and bank faults and wires the pool/table hooks
        self.chaos = chaos
        if chaos is not None:
            self.pool.fault_hook = chaos.alloc
            self.table.fault_hook = chaos.drop_prefix
        # per-engine watchdog: each tenant is one FTController worker —
        # heartbeats on every successful step, coordinator-observed
        # failures on crash, restart_delay() gating every rebuild. Built
        # whenever fault handling is live (explicit config or any chaos)
        self.watchdog = (FTController(0, watchdog or FTConfig(),
                                      clock=clock)
                         if watchdog is not None or chaos is not None
                         else None)
        self.max_fault_streak = max_fault_streak
        self.degrade_streak = degrade_streak
        self._watch_ids: dict[str, int] = {}    # engine name -> worker id
        self._fault_streak: dict[str, int] = {}  # consecutive step faults
        self._backoff: dict[str, int] = {}      # rounds left to sit out
        self._down: dict[str, int] = {}         # crashed: rounds to restart
        self._lost: dict[str, list[Request]] = {}   # queue at crash time
        self._tenants: dict[str, tuple] = {}    # rebuild recipe per engine
        # submission log (request handles for crash re-admission); only
        # kept while fault handling is live, pruned of finished work at
        # every rebuild
        self._requests: dict[str, dict[str, Request]] = {}
        # -- data-parallel replica groups -------------------------------------
        self._groups: dict[str, list[str]] = {}   # group -> member engines
        self._group_rr: dict[str, int] = {}       # routing tie-break cursor
        self._group_hint: dict[str, dict[tuple, str]] = {}  # first page->home
        self.migrations = 0            # journal records handed to siblings
        self.step_faults = 0           # device launches that raised
        self.alloc_faults = 0          # pool allocations that raised
        self.retries = 0               # engine steps retried after a fault
        self.crashes = 0               # engines that lost host state
        self.bank_faults = 0           # bank power-faults applied
        self.rebuilds = 0              # engines rebuilt from the journal
        if owns_platform:
            # our own platform: the idle bank pool starts gated (same rule
            # the engine applies when it owns its platform)
            for i in range(self.platform.config.n_banks):
                self.platform.power.clock_gate(f"bank{i}")

    # -- tenancy ---------------------------------------------------------------

    def add_engine(self, cfg: ModelConfig, params, *, name: str, slots: int,
                   max_len: int, namespace: str | None = None,
                   weight: int | None = None,
                   **engine_kwargs) -> ContinuousBatchingEngine:
        """Construct a tenant engine on the cluster's shared resources.

        ``namespace`` defaults to ``cfg.name``; engines may share one
        namespace **only** when they serve the same model — same config
        *and* the **same parameter tree object** — because namespace peers
        alias each other's prefix pages bitwise. Replicas must be handed
        one shared params tree (load the checkpoint once, pass it to every
        replica): identity is checked by object, since shape-equal trees
        with different weights would silently corrupt aliased pages, and
        sharing the host copy is the memory-sane layout anyway. ``weight``
        is the engine's admission grants per scheduling round; the default
        (``slots``) lets a tenant fill every free slot each round, exactly
        like an isolated engine — lower it to pace a tenant's admissions
        relative to its peers.
        """
        if name in self.engines or name in self._groups:
            raise ValueError(f"duplicate target name {name!r}")
        if not registry.supports_paged(cfg):
            raise ValueError(
                f"{cfg.name} ({cfg.family}) cannot join the cluster: the "
                "shared pool/table requires the paged backend")
        if weight is None:
            weight = slots
        if weight < 1:
            raise ValueError("weight must be >= 1")
        ns = cfg.name if namespace is None else namespace
        identity = (pool_signature(cfg), cfg, id(params))
        prior = self._ns_identity.get(ns)
        if prior is not None and prior != identity:
            raise ValueError(
                f"namespace {ns!r} already serves a different model: "
                "namespace peers alias each other's prefix pages, so they "
                "must share config and weights exactly")
        self._ns_identity[ns] = identity
        eng = self._build_engine(cfg, params, ns, name,
                                 dict(slots=slots, max_len=max_len,
                                      **engine_kwargs))
        self.engines[name] = eng
        self._weights[name] = weight
        self._deficit[name] = 0.0
        self._tenants[name] = (cfg, params, ns,
                               dict(slots=slots, max_len=max_len,
                                    **engine_kwargs))
        if self.watchdog is not None:
            self._watch_ids[name] = self.watchdog.add_worker()
        return eng

    def _build_engine(self, cfg, params, ns: str, name: str,
                      kwargs: dict) -> ContinuousBatchingEngine:
        """One construction path for both first build and crash rebuild:
        the tenant always lands on the cluster's shared resources."""
        return ContinuousBatchingEngine(
            cfg, params,
            platform=self.platform, clock=self.clock,
            journal=self.journal.journal(name),
            pool=self.pool, page_table=self.table,
            namespace=ns, name=name,
            admission_hook=self._admission_hook,
            reclaim=self._reclaim,
            chaos=self.chaos,
            **kwargs)

    def add_replica_group(self, cfg: ModelConfig, params, *, name: str,
                          slots: int, max_len: int, meshes,
                          namespace: str | None = None,
                          weight: int | None = None,
                          **engine_kwargs) -> list[str]:
        """Construct a data-parallel replica group: one member engine per
        entry of ``meshes`` (a :class:`jax.sharding.Mesh` pins that
        member's arena + sharded params to its devices — build disjoint
        slices with :func:`repro.launch.mesh.replica_meshes`; ``None``
        means an unsharded member on the default device). Members are
        named ``{name}/r{i}`` and land in per-replica table namespaces
        ``{ns}@r{i}``: page bytes live only on the owning replica's mesh
        slice, so siblings must not alias each other's prefix pages —
        sharing happens *within* a replica, steered there by the group
        router's prefix affinity. Submit to the group name; the cluster
        routes (:meth:`route`). Returns the member names."""
        meshes = list(meshes)
        if not meshes:
            raise ValueError("a replica group needs at least one mesh "
                             "(use None entries for unsharded members)")
        if name in self.engines or name in self._groups:
            raise ValueError(f"duplicate target name {name!r}")
        ns = cfg.name if namespace is None else namespace
        members = []
        for i, mesh in enumerate(meshes):
            member = f"{name}/r{i}"
            self.add_engine(cfg, params, name=member, slots=slots,
                            max_len=max_len, namespace=f"{ns}@r{i}",
                            weight=weight, mesh=mesh, **engine_kwargs)
            members.append(member)
        self._groups[name] = members
        self._group_rr[name] = 0
        self._group_hint[name] = {}
        return list(members)           # a copy: the group's own roster mutates

    @property
    def targets(self) -> set[str]:
        """Every name :meth:`submit` accepts: engines plus replica groups
        (what a trace may tag — the simulator validates against this)."""
        return set(self.engines) | set(self._groups)

    def route(self, group: str, request: Request) -> str:
        """Pick the member of ``group`` that serves ``request``.

        Deterministic three-step policy: (1) **prefix affinity** — the
        prompt's first page of tokens looks up the member that last homed
        that prefix, so shared-prefix traffic co-locates and the member's
        intra-namespace dedup/adoption machinery fires exactly as it
        would on a single engine; (2) a cold prefix goes to the **least
        loaded** member (queued + active), (3) ties broken **round-robin**
        so a cold burst spreads instead of piling onto member 0. The
        winner becomes the prefix's home for subsequent arrivals."""
        members = self._groups[group]
        hints = self._group_hint[group]
        key = tuple(request.prompt[:self.pool.page_size])
        target = hints.get(key)
        if target is None:
            off = self._group_rr[group] % len(members)
            order = members[off:] + members[:off]
            self._group_rr[group] += 1
            target = min(order, key=lambda m: (len(self.engines[m].queue)
                                               + self.engines[m].active))
            hints[key] = target
        return target

    def submit(self, name: str, request: Request) -> bool:
        """Enqueue ``request`` on engine ``name`` — or, when ``name`` is a
        replica group, on the member :meth:`route` picks. Engine
        backpressure applies: False = rejected and counted there."""
        if name in self._groups:
            name = self.route(name, request)
        ok = self.engines[name].submit(request)
        if ok and self.watchdog is not None:
            # keep the client's handle: after a crash the rebuild re-admits
            # in-flight work onto these exact objects, so arrival times and
            # completion callbacks survive the engine's death
            self._requests.setdefault(name, {})[request.id] = request
        return ok

    def drain_replica(self, group: str, member: str) -> dict[str, list[str]]:
        """Live-migrate every request on ``member`` onto its group
        siblings and retire the member (elastic scale-in).

        The drain reuses the crash-recovery plumbing, but *losslessly*:
        ``preempt()`` first retires any in-flight device step (its tokens
        are journaled, not dropped) and requeues the member's residents in
        FIFO order; each journaled record is then
        :meth:`~repro.runtime.ft.RequestJournal.transfer`-red into a
        sibling's journal (round-robin over siblings, FIFO preserved
        per destination) and the request resubmitted there — the sibling
        replays it with every regenerated token cross-checked against
        the drained member's output, so migration is bit-identical by
        construction, not by luck. The member's table namespace is then
        evicted (its page bytes live on devices we are giving up) and the
        engine removed from every cluster registry and the group. Returns
        ``{sibling: [migrated request ids]}``."""
        if group not in self._groups:
            raise ValueError(f"unknown replica group {group!r}")
        members = self._groups[group]
        if member not in members:
            raise ValueError(f"{member!r} is not a member of {group!r}")
        siblings = [m for m in members if m != member and m in self.engines]
        if not siblings:
            raise ValueError(f"cannot drain {member!r}: it is the last "
                             f"replica of {group!r}")
        if member in self._down:
            raise ValueError(f"{member!r} is down — crashed members go "
                             "through rebuild_engine, not a live drain")
        eng = self.engines[member]
        eng.preempt()                  # flush in-flight tokens to the journal
        moving = list(eng.queue)
        eng.queue.clear()
        src = self.journal.journal(member)
        moved: dict[str, list[str]] = {m: [] for m in siblings}
        for i, req in enumerate(moving):
            dest = siblings[i % len(siblings)]
            if src.has(req.id):
                self.journal.journal(dest).adopt(src.transfer(req.id))
                self.migrations += 1
            if not self.engines[dest].submit(req):
                raise RuntimeError(
                    f"drain of {member!r} would drop {req.id!r}: sibling "
                    f"{dest!r} rejected it (queue capacity) — migration "
                    "must be lossless, raise capacity or drain later")
            if self.watchdog is not None:
                self._requests.setdefault(dest, {})[req.id] = req
            moved[dest].append(req.id)
        # the member's prefix pages live on devices we are releasing:
        # evict its namespace (unpinned now — preempt dropped every pin)
        ns = eng.namespace
        while self.table.evict_lru(1, ns=ns):
            pass
        # re-home future traffic: hints that pointed at the member re-route
        hints = self._group_hint[group]
        for k in [k for k, v in hints.items() if v == member]:
            del hints[k]
        members.remove(member)
        del self.engines[member]
        for reg in (self._weights, self._grants, self._deficit,
                    self._tenants, self._requests, self._fault_streak,
                    self._backoff, self._lost, self._watch_ids):
            reg.pop(member, None)
        self._ns_identity.pop(ns, None)
        return moved

    # -- arbitration -----------------------------------------------------------

    def _admission_hook(self, eng, slot_idx: int, request):
        """Per-admission veto, called from inside each engine's step:
        latency-SLO admission control first (``SHED`` drops a head that
        can no longer meet its TTFT target), then the scheduler budget
        (one WRR grant, or the request's token cost against the engine's
        DRR deficit), then the power budget for the slot's bank. Returns
        True to admit, False to skip this slot (power vetoes are per-slot
        — another slot's bank may already be awake), None to end the
        engine's admission scan (a spent budget is engine-global), or
        ``SHED`` to drop the head outright.

        Graceful degradation under sustained faults: an engine whose
        fault streak reached ``degrade_streak`` sheds SLO-blown heads
        even when the policy's ``shed_busted`` is off — recovery steps
        already charged the backlog's TTFT, so serving a head that can
        no longer make its target would spend post-fault capacity on
        worthless work."""
        degraded = self._fault_streak.get(eng.name, 0) >= self.degrade_streak
        if self.policy.shed_busted or degraded:
            slo = getattr(request, "slo", None)
            # a head holding journal state (scheduler-demoted, crash-
            # recovered, or corruption-replayed) must finish — shedding it
            # would leave an in-flight record that the next crash rebuild
            # resurrects, double-accounting the request. Shedding applies
            # to fresh heads only
            if (slo is not None and slo.ttft is not None
                    and request.slo_preempts == 0
                    and not eng.journal.has(request.id)
                    and request.arrival_time is not None
                    and self.clock() - request.arrival_time > slo.ttft):
                self.sheds += 1
                return SHED
        if self.budget is not None or getattr(
                request, "energy_cap_uj_per_token", None) is not None:
            # energy-aware admission control: shed a head whose projected
            # marginal joules/token busts its cap (per-request cap wins
            # over the cluster-wide budget default). Same journal-state
            # exemption as the TTFT shed: demoted/replayed heads must
            # finish, so only fresh heads are sheddable
            cap = getattr(request, "energy_cap_uj_per_token", None)
            if cap is None and self.budget is not None:
                cap = self.budget.max_uj_per_token
            meter = getattr(eng, "_meter", None)
            if (cap is not None and meter is not None
                    and request.slo_preempts == 0
                    and not eng.journal.has(request.id)
                    and meter.projected_uj_per_token() > cap):
                self.energy_sheds += 1
                return SHED
        if self.policy.scheduler == "drr":
            cost = len(request.prompt) + request.max_new_tokens
            if self._deficit.get(eng.name, 0.0) < cost:
                self.wrr_stalls += 1
                return None
        elif self._grants.get(eng.name, 0) <= 0:
            self.wrr_stalls += 1
            return None
        bank = eng._slot_bank[slot_idx]
        if self.budget is not None and self.budget.would_exceed(
                self.platform, bank):
            # DVFS throttle: the first violation on a metered engine that
            # is not yet at the throttle point drops it there (the paper's
            # §IV-D move — calibrated ~5.9× platform power) and admits;
            # an already-throttled engine stalls as before, so the
            # envelope still binds
            meter = getattr(eng, "_meter", None)
            if (self.budget.throttle_point is not None and meter is not None
                    and meter.point.name != self.budget.throttle_point):
                eng.set_operating_point(self.budget.throttle_point)
                self.dvfs_throttles += 1
            else:
                self.power_stalls += 1
                return False
        if self.policy.scheduler == "drr":
            self._deficit[eng.name] -= (len(request.prompt)
                                        + request.max_new_tokens)
        else:
            self._grants[eng.name] -= 1
        return True

    def _reclaim(self, eng) -> None:
        """Pool pressure: evict unpinned prefix residency, LRU within the
        namespace currently holding the most evictable pages (fair across
        tenants — the heaviest idle footprint pays first). One page per
        iteration is deliberate: eviction stops the moment a pool page
        actually frees, so the warm cache loses the minimum — the rescan
        per evicted page is the price of that minimality, fine at this
        pool's scale."""
        while not self.pool.free_count:
            evictable = self.table.unpinned_by_ns()
            if not evictable:
                return                 # nothing reclaimable: alloc will raise
            ns = max(sorted(evictable), key=lambda n: evictable[n])
            if not self.table.evict_lru(1, ns=ns):
                return
            self.reclaims[ns] = self.reclaims.get(ns, 0) + 1

    # -- the cluster step ------------------------------------------------------

    @property
    def busy(self) -> bool:
        """True while any tenant has queued or in-flight work — including
        a crashed tenant whose journaled work is waiting out its restart
        backoff (its slots and queue are empty, but the work is not)."""
        return bool(self._down) or any(e.busy for e in self.engines.values())

    def _preempt_busted(self) -> None:
        """SLO enforcement: demote any decoding request that has already
        blown past its end-to-end deadline while fresh work waits in its
        engine's queue. The long tail goes to the *back* of the queue (it
        already missed; the fresh head may still make its target) and is
        replayed bit-identically from the journal when re-admitted. At
        most once per request — a second demotion could livelock."""
        for name, eng in self.engines.items():
            if not eng.queue:
                continue
            now = self.clock()
            for i, slot in enumerate(eng.slots):
                if slot is None or slot.prefilling or slot.produced < 1:
                    continue
                req = slot.request
                slo = getattr(req, "slo", None)
                if (slo is None or req.slo_preempts > 0
                        or req.arrival_time is None
                        or now <= slo.deadline(req.arrival_time,
                                               req.max_new_tokens)):
                    continue
                if eng.preempt_slot(i, front=False) is not None:
                    req.slo_preempts += 1
                    self.slo_preempts += 1
                    self.journal.journal(name).note_slo_preempt(req.id)

    def step(self) -> bool:
        """One scheduling round: inject any scheduled cluster faults
        (chaos), preempt SLO-busted long tails (if the policy says so),
        refill every tenant's admission budget — flat WRR grants, or DRR
        deficits accumulated against each engine's actual
        ``step_cost()`` — then advance each engine one step (order
        rotates per round). A tenant sitting out a fault backoff or a
        crash-restart delay counts as progress (deliberate idling, not a
        deadlock); a transient :class:`~repro.serve.chaos.
        DeviceStepFault` / :class:`~repro.serve.chaos.AllocFault` from an
        engine step is counted, backed off exponentially (in rounds),
        and retried — past ``max_fault_streak`` consecutive faults it
        raises. Returns False when every tenant is idle; raises when
        queued work exists but the power budget lets nothing run (a
        budget deadlock — stalling forever would spin silently)."""
        if self.chaos is not None:
            self._inject_cluster_faults()
        if self.policy.preempt_busted:
            self._preempt_busted()
        if self.policy.scheduler == "drr":
            q = self.policy.quantum
            for name, eng in self.engines.items():
                if not eng.busy:
                    # idle tenants bank no deficit: DRR shares the *busy*
                    # period, it does not let an idle tenant hoard credit
                    self._deficit[name] = 0.0
                    continue
                w = self._weights.get(name, 1)
                gain = max(0.0, q * w - eng.step_cost())
                cap = self.policy.deficit_cap * q * w
                self._deficit[name] = min(cap, self._deficit[name] + gain)
        else:
            self._grants = dict(self._weights)
        names = list(self.engines)
        if names:
            off = self._rr_offset % len(names)
            names = names[off:] + names[:off]
            self._rr_offset += 1
        launched = False
        for name in names:
            if name in self._down:
                self._down[name] -= 1
                if self._down[name] <= 0:
                    self.rebuild_engine(name)
                launched = True        # restart progress, not a deadlock
                continue
            if self._backoff.get(name, 0) > 0:
                self._backoff[name] -= 1
                launched = True        # deliberate fault backoff
                continue
            eng = self.engines[name]
            try:
                stepped = eng.step()
            except (DeviceStepFault, AllocFault) as e:
                self._note_fault(name, e)
                launched = True        # the retry is scheduled work
                continue
            if stepped:
                self._fault_streak[name] = 0
            if self.watchdog is not None:
                # liveness, not throughput: an idle engine heartbeats too
                self.watchdog.report_heartbeat(self._watch_ids[name])
            launched |= stepped
        if launched:
            self.steps += 1
        elif self.busy:
            raise RuntimeError(
                "cluster stalled: queued work but no engine can run — the "
                "power budget admits nothing (budget deadlock)")
        if self.watchdog is not None:
            self._watchdog_tick()
        return launched

    # -- fault injection + recovery --------------------------------------------

    def _inject_cluster_faults(self) -> None:
        """Draw this round's cluster-level faults (engine crash, bank
        power-fault) for every live tenant, in registration order — the
        draw order is deterministic, so two same-seed chaos runs inject
        the identical schedule."""
        for name in list(self.engines):
            if name in self._down:
                continue
            if self.chaos.crash(name):
                self._crash(name, reason="injected crash")
                continue
            if self.chaos.bank(name):
                self._apply_bank_fault(name)

    def _note_fault(self, name: str, exc: Exception) -> None:
        """Account a transient step/alloc fault and set the engine's
        exponential backoff (in scheduling rounds — driver-agnostic, so
        the same recovery runs under a frozen or a simulated clock).
        Raises once the consecutive-fault streak exceeds
        ``max_fault_streak``: at that point the fault is not transient
        and silent spinning would hide it."""
        if isinstance(exc, DeviceStepFault):
            self.step_faults += 1
        else:
            self.alloc_faults += 1
        self.retries += 1
        streak = self._fault_streak.get(name, 0) + 1
        self._fault_streak[name] = streak
        if streak > self.max_fault_streak:
            raise RuntimeError(
                f"engine {name!r}: {streak} consecutive step faults — "
                "beyond the transient-retry budget") from exc
        self._backoff[name] = min(2 ** (streak - 1), 16)

    def _crash(self, name: str, reason: str) -> None:
        """Kill engine ``name``: all host-side slot state is lost.

        What a real crash loses is the engine process's bookkeeping; the
        cluster (the coordinator) survives and still owns the shared
        pool/table/platform, so it sweeps the dead tenant's references —
        the unretired in-flight step is dropped (its tokens die with the
        host), every occupied slot is evicted (pool refs, table pins,
        dedup claims, bank refs all released), and the engine's queue is
        set aside for re-submission. The watchdog records the death and
        its ``restart_delay()`` (exponential backoff) gates the rebuild;
        an exhausted restart budget raises instead of retrying forever.
        """
        eng = self.engines[name]
        self.crashes += 1
        # the in-flight async step dies with the host process — its token
        # values were never journaled, so replay regenerates them
        eng._pending = None
        eng._prev_nxt = None
        eng._faulted = []
        for i, s in enumerate(eng.slots):
            if s is not None:
                eng._evict(i)
        self._lost[name] = list(eng.queue)
        eng.queue.clear()
        self.platform.interrupts.fire(CRASH_LINE, name)
        rounds = 1
        if self.watchdog is not None:
            self.watchdog.report_failure(self._watch_ids[name], reason)
            delay = self.watchdog.restart_delay()
            if delay is None:
                raise RuntimeError(
                    f"engine {name!r}: restart budget exhausted "
                    f"({self.watchdog.cfg.max_restarts} restarts)")
            rounds = max(1, int(delay))
        self._down[name] = rounds
        self._fault_streak.pop(name, None)
        self._backoff.pop(name, None)

    def crash_engine(self, name: str, *,
                     rebuild: bool = True) -> ContinuousBatchingEngine | None:
        """Kill engine ``name`` (loss of all host-side slot state) and —
        by default — rebuild it immediately from the journal. Pass
        ``rebuild=False`` to leave the tenant down and let the cluster
        step loop restart it after the watchdog backoff. The test
        entrypoint for crash-recovery scenarios; chaos-injected crashes
        run the same two halves."""
        self._crash(name, reason="crash_engine()")
        if rebuild:
            return self.rebuild_engine(name)
        return None

    def rebuild_engine(self, name: str) -> ContinuousBatchingEngine:
        """Rebuild a crashed tenant and re-admit its in-flight work.

        The new engine lands on the same shared pool/table/platform and
        the same per-engine journal (same name); its monotone counters,
        completed list, and id registry carry over from the dead object
        so cluster-level accounting (and the simulator's per-name delta
        tracking) stays continuous. Every record in
        ``journal.incomplete()`` is re-admitted in original
        ``arrival_seq`` order — onto the client's tracked
        :class:`~repro.serve.engine.Request` handles where available
        (arrival times and completion callbacks survive), else onto
        reconstructed requests — and replays through ``journal.open`` /
        ``record_token``, which cross-checks every regenerated token
        against the pre-crash run. Queue residents that were never
        admitted (no journal record) are re-queued behind them. Shared
        prefix pages the dead engine published are still table-resident,
        so replay re-adopts them instead of recomputing."""
        cfg, params, ns, kwargs = self._tenants[name]
        old = self.engines[name]
        eng = self._build_engine(cfg, params, ns, name, dict(kwargs))
        for attr in ("steps", "tokens_generated", "prompt_tokens_processed",
                     "prompt_tokens_reused", "stalls", "admission_stalls",
                     "rematches", "rematched_tokens", "pages_recycled",
                     "rejected", "shed", "sampled_requests", "token_faults",
                     "replays"):
            setattr(eng, attr, getattr(old, attr))
        eng.completed = old.completed
        eng._ids = old._ids
        eng._replay_counts = old._replay_counts
        # accumulated joules survive the crash — the meter is host-side
        # accounting the coordinator keeps, like the monotone counters
        # above (the fresh engine's own meter is discarded)
        eng._meter = old._meter
        self.engines[name] = eng       # same key: dict/rotation order kept
        tracked = self._requests.get(name, {})
        for rid in [r for r, req in tracked.items()
                    if req.finish_time is not None]:
            del tracked[rid]           # acknowledged: replay never needs it
        requeued = set()
        journal = self.journal.journal(name)
        for rec in journal.incomplete():
            req = tracked.get(rec.request_id)
            if req is None:
                # untracked submission: reconstruct what replay needs from
                # the journal (the callback/arrival context is gone)
                req = Request(rec.request_id, rec.prompt, rec.max_new_tokens,
                              sampling=(SamplingParams(*rec.sampling)
                                        if rec.sampling else None))
            req.tokens = []
            req.admit_time = req.first_token_time = req.finish_time = None
            if req.arrival_time is None:
                req.arrival_time = self.clock()
            eng._ids.add(req.id)
            eng.queue.append(req)
            requeued.add(req.id)
        for req in self._lost.pop(name, []):
            if req.id in requeued:
                continue               # preempted resident: already queued
            eng._ids.add(req.id)
            eng.queue.append(req)
        self._down.pop(name, None)
        self.rebuilds += 1
        if self.watchdog is not None:
            # the rebuilt engine's first heartbeat is its rejoin
            self.watchdog.report_heartbeat(self._watch_ids[name])
        return eng

    def _apply_bank_fault(self, name: str) -> None:
        """Power-fault one occupied memory bank of engine ``name``: every
        slot on it is preempted and requeued at the front (the pre-fault
        tokens are valid journal state — the flush retires them first),
        the slots' bank references drop so the domain gates, and a
        ``chaos.bank_fault`` interrupt fires on the platform fabric. A
        tenant with no occupied slots absorbs the fault as a no-op."""
        eng = self.engines[name]
        occupied = [i for i, s in enumerate(eng.slots) if s is not None]
        if not occupied:
            return
        bank = eng._slot_bank[occupied[0]]
        victims = [i for i in occupied if eng._slot_bank[i] == bank]
        # descending seq + front requeue => ascending FIFO order in queue
        for i in sorted(victims,
                        key=lambda i: -(eng.slots[i].seq
                                        if eng.slots[i] is not None else 0)):
            if eng.slots[i] is not None:
                eng.preempt_slot(i, front=True)
        self.bank_faults += 1
        self.platform.interrupts.fire(BANK_FAULT_LINE, (name, bank))

    def _watchdog_tick(self) -> None:
        """Run watchdog detection; a tenant declared dead (heartbeat
        timeout under an advancing clock — e.g. stuck in backoff for
        longer than the timeout) escalates to the crash path, whose
        journal rebuild is the recovery for lost liveness too."""
        result = self.watchdog.tick()
        by_wid = {wid: n for n, wid in self._watch_ids.items()}
        for wid in result["dead"]:
            name = by_wid.get(wid)
            if name is not None and name not in self._down:
                self._crash(name, reason="heartbeat timeout")

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        """Step until every tenant drains (raises after ``max_steps``)."""
        for _ in range(max_steps):
            if not self.step():
                return
        raise RuntimeError(f"cluster still busy after {max_steps} steps")

    # -- preemption ------------------------------------------------------------

    def preempt(self) -> dict[str, list[Request]]:
        """Preempt every tenant: in-flight work is requeued FIFO per
        engine. Replay is bit-identical per engine (each engine's journal
        cross-checks its own tokens on the way back)."""
        return {name: eng.preempt() for name, eng in self.engines.items()}

    # -- introspection ---------------------------------------------------------

    def awake_banks(self) -> int:
        """Bank domains currently ``ON`` — what the budget caps."""
        return awake_banks(self.platform)

    def stats(self) -> dict:
        """Cluster counters plus every tenant's ``engine.stats()`` (one
        source of truth: the pool/table numbers inside each tenant's entry
        describe the same shared objects)."""
        meters = [e._meter for e in self.engines.values()
                  if e._meter is not None]
        return {
            "steps": self.steps,
            "power_stalls": self.power_stalls,
            "dvfs_throttles": self.dvfs_throttles,
            "energy_sheds": self.energy_sheds,
            "wrr_stalls": self.wrr_stalls,
            "scheduler": self.policy.scheduler,
            "sheds": self.sheds,
            "slo_preempts": self.slo_preempts,
            "reclaims": dict(self.reclaims),
            "groups": {g: list(ms) for g, ms in self._groups.items()},
            "migrations": self.migrations,
            "awake_banks": self.awake_banks(),
            "energy": {
                "total_uj": sum(m.total_uj for m in meters),
                "attributed_uj": sum(m.attributed_uj for m in meters),
                "overhead_uj": sum(m.overhead_uj for m in meters),
                "metered_engines": len(meters),
            },
            "faults": {
                "step_faults": self.step_faults,
                "alloc_faults": self.alloc_faults,
                "token_faults": sum(e.token_faults
                                    for e in self.engines.values()),
                "replays": sum(e.replays for e in self.engines.values()),
                "retries": self.retries,
                "crashes": self.crashes,
                "bank_faults": self.bank_faults,
                "rebuilds": self.rebuilds,
                "down": sorted(self._down),
                "injected": (dict(self.chaos.counts)
                             if self.chaos is not None else None),
                "watchdog_events": (len(self.watchdog.events)
                                    if self.watchdog is not None else 0),
            },
            "pool": dict(self.pool.stats, pages=self.pool.n_pages,
                         in_use=self.pool.in_use, free=self.pool.free_count,
                         by_owner={str(k): v
                                   for k, v in self.pool.owners().items()}),
            "table": dict(self.table.stats, resident=self.table.resident,
                          pinned=self.table.pinned,
                          by_namespace=self.table.resident_by_ns()),
            "engines": {name: eng.stats()
                        for name, eng in self.engines.items()},
        }
