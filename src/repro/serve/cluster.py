"""Multi-model serving cluster: N engines, one pool, one power budget.

This is the serving rendition of the paper's HEEPocrates example — several
heterogeneous compute units (there: CGRA, IMC, crypto accelerators; here:
per-model :class:`~repro.serve.engine.ContinuousBatchingEngine` instances)
running concurrently against **one** bus/memory pool (here: one
:class:`~repro.serve.paged.PagePool` + one
:class:`~repro.serve.pages.PageTable`) and **one** power manager budget
(here: a :class:`PowerBudget` over the shared
:class:`~repro.core.power.PowerManager`). The cluster owns allocation; the
engines are tenants.

What the :class:`ServeCluster` arbitrates:

* **Admission (weighted round-robin).** Every cluster step opens a round
  of per-engine admission grants equal to each tenant's ``weight``
  (default: its slot count, i.e. unthrottled); an engine that spent its
  grants waits for the next round, so a down-weighted tenant's burst
  admits at a bounded rate instead of starving its peers' share of the
  power/pool budget. Engine order rotates per step so ties break fairly.
* **SLO-aware scheduling (opt-in via** :class:`SchedPolicy` **).** The
  ``"drr"`` scheduler replaces flat per-round grants with deficit-weighted
  round-robin over each engine's actual :meth:`step_cost` — lightly loaded
  tenants bank credit and admit; saturated ones wait. ``shed_busted``
  drops queue heads that have already blown their TTFT target (open-loop
  overload serves *fresh* work instead of a stale backlog), and
  ``preempt_busted`` demotes decoding requests past their end-to-end
  deadline to the back of the queue — they replay bit-identically from
  the journal, so SLO enforcement never changes any request's tokens.
* **Power-budget backpressure.** Before an engine admits into a slot, the
  cluster checks whether waking that slot's memory bank would exceed the
  :class:`PowerBudget`. If it would, the admission *stalls* (the request
  stays at the queue head, FIFO intact) instead of exceeding the budget —
  the scheduling analogue of X-HEEP refusing to power up a domain the
  envelope cannot carry. Slots whose bank is already awake ride for free
  (banks are refcount-shared across engines).
* **Fair cross-tenant reclaim.** When the shared pool runs dry, the
  cluster evicts unpinned prefix residency LRU-first from the *namespace
  holding the most evictable pages*, instead of wiping every tenant's
  warm cache at once. (Unlike an engine-private table, the cluster table
  is not platform-wired: resident pages do not hold banks awake, so a
  warm cache can never carry the platform past the power budget — the
  budget governs slot-driven wakes only.)
* **Prefix sharing across engines.** Engines serving the same model (same
  config + weights) declare the same ``namespace`` and alias each other's
  published prefix pages — pool ids are globally valid, so adoption is
  block-table pointing even across engines. Different namespaces never
  alias (same token ids under different weights are different states).
  Sliding-window tenants participate like any other engine (ring block
  tables, PR 5): their recycled pages return to the *shared* free list,
  so an SWA tenant's O(window) footprint frees budget for its peers.

Invariants (held by ``tests/test_cluster.py``):

* **Per-engine bit-identity.** A request's tokens are identical whether
  its engine runs alone or as a cluster tenant — sharing, stalls, and
  reclaim are scheduling/memory effects only, never numerical ones.
* **The budget is never exceeded.** Admissions stall rather than wake a
  bank past the budget; a budget so tight that no progress is possible
  raises loudly instead of spinning.
* **Preempt/replay stays per-engine deterministic.** ``preempt()``
  flushes and requeues every tenant; each engine's journal cross-checks
  its own replay tokens (the :class:`~repro.runtime.ft.ClusterJournal`
  keeps them separate). This holds for stochastic traffic too: a
  request's :class:`~repro.serve.sampling.SamplingParams` ride on the
  :class:`~repro.serve.engine.Request` through every scheduler move
  (shed exemption, ``preempt_busted`` demotion, full preemption), and
  re-admission re-seeds the journaled per-request PRNG chain — so
  sampled tokens, like greedy ones, are bit-identical whichever policy
  served them.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.power import PowerState
from repro.models import registry
from repro.models.config import ModelConfig
from repro.runtime.ft import ClusterJournal
from repro.serve.engine import SHED, ContinuousBatchingEngine, Request
from repro.serve.paged import PagePool, pool_signature
from repro.serve.pages import PageTable

__all__ = ["PowerBudget", "SchedPolicy", "ServeCluster", "awake_banks"]


def awake_banks(platform) -> int:
    """Bank domains currently ``ON`` — the one predicate both the budget
    enforcement and the cluster's introspection count with (a single
    definition keeps the enforced and the reported quantity identical)."""
    return sum(1 for name, state in platform.power.states.items()
               if name.startswith("bank") and state is PowerState.ON)


@dataclasses.dataclass(frozen=True)
class PowerBudget:
    """Envelope the cluster must stay inside when waking memory banks.

    ``max_awake_banks`` caps the number of bank domains in the ``ON``
    state at once (the paper's power-gating view: only so many domains may
    be powered). ``budget_uw`` caps the platform's total µW at
    ``freq_mhz`` instead (meaningful when the platform's domains carry
    real leakage/dynamic coefficients). Either or both may be set; a bank
    that is already awake never re-charges the budget.
    """

    max_awake_banks: int | None = None
    budget_uw: float | None = None
    freq_mhz: float = 100.0

    def __post_init__(self):
        if self.max_awake_banks is None and self.budget_uw is None:
            raise ValueError("budget needs max_awake_banks or budget_uw")
        if self.max_awake_banks is not None and self.max_awake_banks < 1:
            raise ValueError("max_awake_banks must be >= 1 (0 can never "
                             "admit anything)")

    def would_exceed(self, platform, bank: str) -> bool:
        """True when waking ``bank`` (if it is not already ``ON``) would
        push the platform past this budget. Pure query — no state is
        touched."""
        power = platform.power
        if power.state(bank) is PowerState.ON:
            return False
        if self.max_awake_banks is not None:
            if awake_banks(platform) + 1 > self.max_awake_banks:
                return True
        if self.budget_uw is not None:
            dom = power.domains[bank]
            now = power.power_uw(self.freq_mhz)
            delta = (dom.power_uw(PowerState.ON, 0.0, self.freq_mhz)
                     - dom.power_uw(power.state(bank), 0.0, self.freq_mhz))
            if now + delta > self.budget_uw:
                return True
        return False


@dataclasses.dataclass(frozen=True)
class SchedPolicy:
    """How the cluster arbitrates admission and slot tenure.

    ``scheduler`` selects the grant discipline per scheduling round:

    * ``"wrr"`` (default) — flat weighted round-robin: each tenant gets
      ``weight`` admission grants per round, regardless of how much work
      its slots already carry. This is the PR 4 behaviour.
    * ``"drr"`` — deficit-weighted round-robin over
      :meth:`~repro.serve.engine.ContinuousBatchingEngine.step_cost`:
      each round a tenant banks ``max(0, quantum·weight − step_cost())``
      *token* credits (a loaded engine accrues slowly, an idle one fast),
      capped at ``deficit_cap·quantum·weight``, and an admission charges
      the request's full token cost (prompt + max_new_tokens). Admission
      pace thus follows committed device work, not just slot counts.

    The two SLO levers are independent of the grant discipline:

    * ``shed_busted`` — latency-SLO admission control: a queue head whose
      TTFT target is already blown is dropped (shed) instead of admitted;
      under overload, capacity goes to requests that can still meet their
      SLO. A request the scheduler itself previously demoted is exempt —
      it already holds journal state and must finish.
    * ``preempt_busted`` — preempt-and-requeue of SLO-busting long tails:
      a decoding request whose :meth:`~repro.serve.metrics.SLO.deadline`
      has passed while peers queue is evicted and re-queued at the *back*
      (at most once per request; journal replay reproduces its tokens
      bit-for-bit), freeing the slot for salvageable work.
    """

    scheduler: str = "wrr"
    quantum: int = 16        # drr: token credits banked per weight per round
    deficit_cap: int = 4     # drr: max rounds of unspent credit banked
    shed_busted: bool = False
    preempt_busted: bool = False

    def __post_init__(self):
        if self.scheduler not in ("wrr", "drr"):
            raise ValueError(f"unknown scheduler {self.scheduler!r} "
                             "(one of 'wrr', 'drr')")
        if self.quantum < 1:
            raise ValueError("quantum must be >= 1 token")
        if self.deficit_cap < 1:
            raise ValueError("deficit_cap must be >= 1 round")


class ServeCluster:
    """N continuous-batching engines over one pool, table, and platform.

    The cluster owns the shared resources (``pool_pages`` KV pages of
    ``page_size`` tokens, one prefix :class:`PageTable`, one
    :class:`~repro.core.platform.Platform`) and constructs its tenant
    engines via :meth:`add_engine` — engines never allocate for
    themselves. :meth:`step` advances every tenant once on the shared
    clock; admission inside each engine step is arbitrated by the
    cluster's weighted-round-robin grants and the optional
    :class:`PowerBudget`.
    """

    def __init__(self, *, pool_pages: int, page_size: int = 16,
                 platform=None, clock: Callable[[], float] = lambda: 0.0,
                 capacity_pages: int | None = None,
                 power_budget: PowerBudget | None = None,
                 journal: ClusterJournal | None = None,
                 policy: SchedPolicy | None = None):
        from repro.core.platform import Platform, XHeepConfig

        owns_platform = platform is None
        self.platform = platform or Platform(XHeepConfig())
        self.clock = clock
        self.budget = power_budget
        self.pool = PagePool(pool_pages, page_size)
        # deliberately NOT platform-wired: an engine-private table holds
        # its resident pages' banks awake (the SRAM-retention analogue),
        # but here bank wakes are governed by the admission-time power
        # budget, and residency waking banks behind the budget's back
        # would let warm caches exceed the envelope. Cluster residency is
        # power-free; the budget caps compute-driven (slot) wakes only.
        self.table = PageTable(
            page_size,
            capacity_pages=(capacity_pages if capacity_pages is not None
                            else pool_pages),
            on_evict=self.pool.release)
        self.journal = journal or ClusterJournal()
        self.policy = policy or SchedPolicy()
        self.engines: dict[str, ContinuousBatchingEngine] = {}
        self._weights: dict[str, int] = {}
        self._grants: dict[str, int] = {}
        self._deficit: dict[str, float] = {}  # drr: banked token credits
        self._ns_identity: dict[str, tuple] = {}
        self._rr_offset = 0
        self.steps = 0
        self.power_stalls = 0          # admissions stalled by the budget
        self.wrr_stalls = 0            # admissions deferred to the next round
        self.sheds = 0                 # SLO-busted heads dropped at admission
        self.slo_preempts = 0          # SLO-busting tails demoted to the back
        self.reclaims: dict[str, int] = {}   # namespace -> pages reclaimed
        if owns_platform:
            # our own platform: the idle bank pool starts gated (same rule
            # the engine applies when it owns its platform)
            for i in range(self.platform.config.n_banks):
                self.platform.power.clock_gate(f"bank{i}")

    # -- tenancy ---------------------------------------------------------------

    def add_engine(self, cfg: ModelConfig, params, *, name: str, slots: int,
                   max_len: int, namespace: str | None = None,
                   weight: int | None = None,
                   **engine_kwargs) -> ContinuousBatchingEngine:
        """Construct a tenant engine on the cluster's shared resources.

        ``namespace`` defaults to ``cfg.name``; engines may share one
        namespace **only** when they serve the same model — same config
        *and* the **same parameter tree object** — because namespace peers
        alias each other's prefix pages bitwise. Replicas must be handed
        one shared params tree (load the checkpoint once, pass it to every
        replica): identity is checked by object, since shape-equal trees
        with different weights would silently corrupt aliased pages, and
        sharing the host copy is the memory-sane layout anyway. ``weight``
        is the engine's admission grants per scheduling round; the default
        (``slots``) lets a tenant fill every free slot each round, exactly
        like an isolated engine — lower it to pace a tenant's admissions
        relative to its peers.
        """
        if name in self.engines:
            raise ValueError(f"duplicate engine name {name!r}")
        if not registry.supports_paged(cfg):
            raise ValueError(
                f"{cfg.name} ({cfg.family}) cannot join the cluster: the "
                "shared pool/table requires the paged backend")
        if weight is None:
            weight = slots
        if weight < 1:
            raise ValueError("weight must be >= 1")
        ns = cfg.name if namespace is None else namespace
        identity = (pool_signature(cfg), cfg, id(params))
        prior = self._ns_identity.get(ns)
        if prior is not None and prior != identity:
            raise ValueError(
                f"namespace {ns!r} already serves a different model: "
                "namespace peers alias each other's prefix pages, so they "
                "must share config and weights exactly")
        self._ns_identity[ns] = identity
        eng = ContinuousBatchingEngine(
            cfg, params, slots=slots, max_len=max_len,
            platform=self.platform, clock=self.clock,
            journal=self.journal.journal(name),
            pool=self.pool, page_table=self.table,
            namespace=ns, name=name,
            admission_hook=self._admission_hook,
            reclaim=self._reclaim,
            **engine_kwargs)
        self.engines[name] = eng
        self._weights[name] = weight
        self._deficit[name] = 0.0
        return eng

    def submit(self, name: str, request: Request) -> bool:
        """Enqueue ``request`` on engine ``name`` (engine backpressure
        applies: False = rejected and counted there)."""
        return self.engines[name].submit(request)

    # -- arbitration -----------------------------------------------------------

    def _admission_hook(self, eng, slot_idx: int, request):
        """Per-admission veto, called from inside each engine's step:
        latency-SLO admission control first (``SHED`` drops a head that
        can no longer meet its TTFT target), then the scheduler budget
        (one WRR grant, or the request's token cost against the engine's
        DRR deficit), then the power budget for the slot's bank. Returns
        True to admit, False to skip this slot (power vetoes are per-slot
        — another slot's bank may already be awake), None to end the
        engine's admission scan (a spent budget is engine-global), or
        ``SHED`` to drop the head outright."""
        if self.policy.shed_busted:
            slo = getattr(request, "slo", None)
            # a request the scheduler itself demoted already holds journal
            # state and must finish — shedding applies to fresh heads only
            if (slo is not None and slo.ttft is not None
                    and request.slo_preempts == 0
                    and request.arrival_time is not None
                    and self.clock() - request.arrival_time > slo.ttft):
                self.sheds += 1
                return SHED
        if self.policy.scheduler == "drr":
            cost = len(request.prompt) + request.max_new_tokens
            if self._deficit.get(eng.name, 0.0) < cost:
                self.wrr_stalls += 1
                return None
        elif self._grants.get(eng.name, 0) <= 0:
            self.wrr_stalls += 1
            return None
        bank = eng._slot_bank[slot_idx]
        if self.budget is not None and self.budget.would_exceed(
                self.platform, bank):
            self.power_stalls += 1
            return False
        if self.policy.scheduler == "drr":
            self._deficit[eng.name] -= (len(request.prompt)
                                        + request.max_new_tokens)
        else:
            self._grants[eng.name] -= 1
        return True

    def _reclaim(self, eng) -> None:
        """Pool pressure: evict unpinned prefix residency, LRU within the
        namespace currently holding the most evictable pages (fair across
        tenants — the heaviest idle footprint pays first). One page per
        iteration is deliberate: eviction stops the moment a pool page
        actually frees, so the warm cache loses the minimum — the rescan
        per evicted page is the price of that minimality, fine at this
        pool's scale."""
        while not self.pool.free_count:
            evictable = self.table.unpinned_by_ns()
            if not evictable:
                return                 # nothing reclaimable: alloc will raise
            ns = max(sorted(evictable), key=lambda n: evictable[n])
            if not self.table.evict_lru(1, ns=ns):
                return
            self.reclaims[ns] = self.reclaims.get(ns, 0) + 1

    # -- the cluster step ------------------------------------------------------

    @property
    def busy(self) -> bool:
        """True while any tenant has queued or in-flight work."""
        return any(e.busy for e in self.engines.values())

    def _preempt_busted(self) -> None:
        """SLO enforcement: demote any decoding request that has already
        blown past its end-to-end deadline while fresh work waits in its
        engine's queue. The long tail goes to the *back* of the queue (it
        already missed; the fresh head may still make its target) and is
        replayed bit-identically from the journal when re-admitted. At
        most once per request — a second demotion could livelock."""
        for name, eng in self.engines.items():
            if not eng.queue:
                continue
            now = self.clock()
            for i, slot in enumerate(eng.slots):
                if slot is None or slot.prefilling or slot.produced < 1:
                    continue
                req = slot.request
                slo = getattr(req, "slo", None)
                if (slo is None or req.slo_preempts > 0
                        or req.arrival_time is None
                        or now <= slo.deadline(req.arrival_time,
                                               req.max_new_tokens)):
                    continue
                if eng.preempt_slot(i, front=False) is not None:
                    req.slo_preempts += 1
                    self.slo_preempts += 1
                    self.journal.journal(name).note_slo_preempt(req.id)

    def step(self) -> bool:
        """One scheduling round: preempt SLO-busted long tails (if the
        policy says so), refill every tenant's admission budget — flat
        WRR grants, or DRR deficits accumulated against each engine's
        actual ``step_cost()`` — then advance each engine one step (order
        rotates per round). Returns False when every tenant is idle;
        raises when queued work exists but the power budget lets nothing
        run (a budget deadlock — stalling forever would spin silently)."""
        if self.policy.preempt_busted:
            self._preempt_busted()
        if self.policy.scheduler == "drr":
            q = self.policy.quantum
            for name, eng in self.engines.items():
                if not eng.busy:
                    # idle tenants bank no deficit: DRR shares the *busy*
                    # period, it does not let an idle tenant hoard credit
                    self._deficit[name] = 0.0
                    continue
                w = self._weights.get(name, 1)
                gain = max(0.0, q * w - eng.step_cost())
                cap = self.policy.deficit_cap * q * w
                self._deficit[name] = min(cap, self._deficit[name] + gain)
        else:
            self._grants = dict(self._weights)
        names = list(self.engines)
        if names:
            off = self._rr_offset % len(names)
            names = names[off:] + names[:off]
            self._rr_offset += 1
        launched = False
        for name in names:
            launched |= self.engines[name].step()
        if launched:
            self.steps += 1
        elif self.busy:
            raise RuntimeError(
                "cluster stalled: queued work but no engine can run — the "
                "power budget admits nothing (budget deadlock)")
        return launched

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        """Step until every tenant drains (raises after ``max_steps``)."""
        for _ in range(max_steps):
            if not self.step():
                return
        raise RuntimeError(f"cluster still busy after {max_steps} steps")

    # -- preemption ------------------------------------------------------------

    def preempt(self) -> dict[str, list[Request]]:
        """Preempt every tenant: in-flight work is requeued FIFO per
        engine. Replay is bit-identical per engine (each engine's journal
        cross-checks its own tokens on the way back)."""
        return {name: eng.preempt() for name, eng in self.engines.items()}

    # -- introspection ---------------------------------------------------------

    def awake_banks(self) -> int:
        """Bank domains currently ``ON`` — what the budget caps."""
        return awake_banks(self.platform)

    def stats(self) -> dict:
        """Cluster counters plus every tenant's ``engine.stats()`` (one
        source of truth: the pool/table numbers inside each tenant's entry
        describe the same shared objects)."""
        return {
            "steps": self.steps,
            "power_stalls": self.power_stalls,
            "wrr_stalls": self.wrr_stalls,
            "scheduler": self.policy.scheduler,
            "sheds": self.sheds,
            "slo_preempts": self.slo_preempts,
            "reclaims": dict(self.reclaims),
            "awake_banks": self.awake_banks(),
            "pool": dict(self.pool.stats, pages=self.pool.n_pages,
                         in_use=self.pool.in_use, free=self.pool.free_count,
                         by_owner={str(k): v
                                   for k, v in self.pool.owners().items()}),
            "table": dict(self.table.stats, resident=self.table.resident,
                          pinned=self.table.pinned,
                          by_namespace=self.table.resident_by_ns()),
            "engines": {name: eng.stats()
                        for name, eng in self.engines.items()},
        }
