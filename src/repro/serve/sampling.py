"""Deterministic per-request stochastic sampling for the serving engine.

Greedy decode made every determinism invariant in the stack free:
replaying an argmax chain from the prompt reproduces it bit-for-bit.
Sampling breaks that unless the PRNG state is part of the replayable
state — so this module treats the sampler exactly the way the engine
treats the KV cache:

* :class:`SamplingParams` rides on the :class:`~repro.serve.engine.
  Request` (temperature / top-k / top-p / seed) and is journaled per
  admission in the :class:`~repro.runtime.ft.SlotRecord`, so a replayed
  admission re-seeds the exact chain the original run used.
* The per-lane PRNG key lives in the engine's **device state** next to
  the cache (a ``(n_lanes, 2)`` raw ``uint32`` array, donated through the
  jitted step like the KV pool), and advances **on-device** each step —
  the sampled token replaces the on-device argmax as the async-dispatch
  feedback path, so the one-step-ahead pipeline survives sampling.
* The advance is **gated by the engine's emit mask**: a lane's key splits
  only on steps that emit a token (decode steps, and the prefill launch
  that consumes the last prompt token). The chain position therefore
  equals the number of tokens produced — invariant to chunking, prefix
  adoption, dedup stalls, mid-flight re-matches, backend choice, and
  async dispatch, which is what makes preempt/replay (where the replayed
  run may find different pages resident and take a different number of
  prefill launches) bit-identical.

Greedy decode is the zero-temperature degenerate case: ``temperature ==
0`` returns the exact argmax (the pre-sampling engine behaviour), so a
mixed batch of greedy and sampled lanes shares one step function and the
greedy lanes' outputs are bit-identical to an engine with no sampling at
all.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GREEDY", "SamplingParams", "sample", "seed_key", "split_keys",
           "zero_keys"]

_MASKED = -jnp.inf


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls (hashable, journal-friendly).

    ``temperature == 0`` is exact greedy decode — ``top_k``/``top_p``/
    ``seed`` are then inert. ``top_k == 0`` disables the top-k filter;
    ``top_p == 1.0`` disables the nucleus filter; both filters compose
    (top-k first, then top-p over the renormalised survivors). ``seed``
    names the request's private PRNG chain: equal seeds + equal logits ⇒
    equal tokens, on any backend, replayed any number of times.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError("temperature cannot be negative")
        if self.top_k < 0:
            raise ValueError("top_k cannot be negative")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if self.seed < 0:
            raise ValueError("seed cannot be negative")

    @property
    def greedy(self) -> bool:
        """True when this is the zero-temperature (argmax) degenerate."""
        return self.temperature == 0.0

    def astuple(self) -> tuple:
        """The journal form: ``(temperature, top_k, top_p, seed)``."""
        return (float(self.temperature), int(self.top_k),
                float(self.top_p), int(self.seed))


GREEDY = SamplingParams()


def seed_key(seed: int) -> np.ndarray:
    """Host-side raw threefry key for ``seed`` — the same ``(2,)`` uint32
    layout ``jax.random.PRNGKey`` produces, computed without a device op
    so admission stays a host-only event."""
    return np.array([(seed >> 32) & 0xFFFFFFFF, seed & 0xFFFFFFFF],
                    np.uint32)


def zero_keys(n_lanes: int):
    """Initial per-lane key state: every lane at ``seed_key(0)`` (lanes
    are re-seeded at admission; idle lanes never consume their key)."""
    return jnp.zeros((n_lanes, 2), jnp.uint32)


def split_keys(keys):
    """Split a ``(B, 2)`` raw key batch into ``(carry, use)`` halves.

    Row convention (shared by every step function so lane and paged
    backends walk bit-identical chains): ``split(key)[0]`` is the key
    carried to the next emitting step, ``split(key)[1]`` is consumed by
    this step's :func:`sample`.
    """
    ks = jax.vmap(lambda k: jax.random.split(k))(keys)
    return ks[:, 0], ks[:, 1]


def sample(logits, key, temperature, top_k, top_p):
    """Sample one token id from one ``(vocab,)`` logits vector.

    Temperature-scaled categorical sampling with optional top-k and
    nucleus (top-p) filtering; ``temperature == 0`` short-circuits to the
    exact argmax (bitwise the engine's pre-sampling greedy path). The
    nucleus keeps the smallest probability-sorted set whose *exclusive*
    cumulative mass is below ``top_p`` — the top token always survives,
    so the distribution is never empty. All arguments may be traced
    scalars, so one compiled step serves every lane's parameters.
    """
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    scaled = (logits / jnp.maximum(temperature, 1e-6)).astype(jnp.float32)
    # top-k: logits below the kth-largest are masked (k == 0 keeps all)
    desc = jnp.sort(scaled)[::-1]
    kth = jnp.where(top_k > 0, desc[jnp.maximum(top_k - 1, 0)], _MASKED)
    scaled = jnp.where(scaled < kth, _MASKED, scaled)
    # top-p over the survivors: keep the smallest prefix of the
    # probability-sorted distribution with exclusive cumsum < top_p
    probs = jax.nn.softmax(scaled)
    ps = jnp.sort(probs)[::-1]
    exclusive = jnp.cumsum(ps) - ps
    pmin = jnp.min(jnp.where(exclusive < top_p, ps, jnp.inf))
    scaled = jnp.where((top_p < 1.0) & (probs < pmin), _MASKED, scaled)
    sampled = jax.random.categorical(key, scaled).astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)
