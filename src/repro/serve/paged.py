"""Global KV page pool + jitted paged decode steps for the serving engine.

This is the device side of the paged backend: one pool of fixed-size KV
pages shared by every decode slot (and by the prefix page table), reached
per step through per-slot *block tables*. It replaces the PR 2 layout of
one full-length cache lane per slot — prefix adoption becomes block-table
pointing (no copy-on-write lane materialisation), publishing a page is a
host-side refcount bump (no device gather), and eviction returns page ids
to a free list instead of resetting whole lanes.

Since PR 4 the pool is a *cluster-ownable* resource: the allocator (free
list, refcounts, per-tenant accounting) is model-agnostic and hands out
**globally valid page ids** from one id space, while the device storage
lives in per-cache-signature *arenas* created lazily by :meth:`PagePool.
arena`. Engines serving the same model family/shape share one arena (so a
page id published by one engine is directly readable by another — the
basis of cross-engine prefix sharing), and engines of different shapes
share only the id space and budget — the serving analogue of X-HEEP's
heterogeneous compute units arbitrating one memory pool.

Invariants:

* **Pool refcounts never go negative.** Every page id handed out by
  :meth:`PagePool.alloc` / pinned by :meth:`PagePool.retain` is released
  exactly once; over-release raises (the ``Platform.bank_release``
  discipline, applied to pages).
* **A referenced page is never recycled.** A page returns to the free list
  only when its last holder (slot block table or page-table residency)
  releases it.
* **The null page is write-never and release-never.** Row ``null`` pads
  unused block-table entries; attention masks every position at or beyond
  a slot's length, so its contents are unobservable — and the allocator
  refuses to ``retain``/``release`` it (it is not a real page).
* **One id space, many arenas.** ``alloc`` draws from a single free list
  regardless of which arena the page's bytes will land in, so the pool is
  one shared budget; per-tenant ``in_use_by`` accounting lets a scheduler
  arbitrate it.

The jitted step functions take *device feedback*: a decoding lane's input
token can come straight from the previous step's on-device next token —
sampled per the lane's :class:`~repro.serve.sampling.SamplingParams`,
exact argmax at zero temperature — via ``feedback``/``prev``, so the host
never has to block on a transfer before dispatching the next step: the
data path of the engine's async double-buffered dispatch survives
stochastic sampling because the per-lane PRNG keys advance on-device in
the same launch (see :mod:`repro.serve.sampling`).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import compat
from repro.models import registry
from repro.models.config import ModelConfig
from repro.serve.sampling import sample, split_keys
from repro.sharding import rules as R

__all__ = ["PagePool", "PoolArena", "pool_signature", "paged_step_fn",
           "paged_chunk_fn", "place_params", "mesh_tp"]

# jitted paged kernels shared across engine instances (jax then caches
# compilations per pool/table shape)
_PAGED_FNS: dict = {}

# host params tree -> per-mesh placed copy (weights load once; every
# engine on the same mesh shares the placed tree). Keyed by object id —
# the cluster already enforces same-namespace params identity by id.
_PLACED_PARAMS: dict = {}


def mesh_tp(mesh: Mesh, tp_axis: str = "model") -> int:
    """Size of the tensor-parallel axis of ``mesh`` (loud on a bad axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if tp_axis not in sizes:
        raise ValueError(f"mesh {mesh.axis_names} has no axis {tp_axis!r}")
    return sizes[tp_axis]


def place_params(cfg: ModelConfig, params, mesh: Mesh,
                 tp_axis: str = "model"):
    """Shard a host params tree onto ``mesh`` for the TP paged decode.

    wq/wk/wv land head-sharded over ``tp_axis``; everything else (embed,
    norms, MLP, wo, head) is replicated — the layout
    :func:`repro.sharding.rules.serve_param_specs` derives from the
    registry's logical axes. Placement is cached per (params, mesh, axis):
    replicas sharing one checkpoint share one device copy.
    """
    key = (id(params), mesh, tp_axis)
    if key not in _PLACED_PARAMS:
        R.validate_serve_tp(cfg, mesh_tp(mesh, tp_axis))
        specs = R.serve_param_specs(cfg, tp_axis)
        _PLACED_PARAMS[key] = jax.tree.map(
            lambda p, s: jax.device_put(p, NamedSharding(mesh, s)),
            params, specs)
    return _PLACED_PARAMS[key]


def pool_signature(cfg: ModelConfig) -> tuple:
    """Cache-shape signature of a config: configs with equal signatures can
    share one device arena (their KV pages are layout-compatible)."""
    return (cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim)


@dataclasses.dataclass
class PoolArena:
    """Device storage for one cache signature: a (k, v) pair shaped
    ``(L, n_pages + 1, page_size, Kh, Dh)`` — the extra row is the null
    page. Engines mutate ``k``/``v`` in place per step (donated buffers);
    same-signature engines share one arena, so page contents written by one
    engine are readable by every other through the shared id space."""

    k: Any
    v: Any


class PagePool:
    """Fixed-size KV page pool: free list, per-page refcounts, per-tenant
    accounting, and lazily created per-signature device arenas.

    Host state is the allocator: ``alloc()`` hands out a globally valid
    page id with one reference; ``retain``/``release`` follow the
    shared-bank discipline. Device state is reached via :meth:`arena` —
    one (k, v) arena per distinct cache signature, created on first use,
    all sharing the one id space (ids are valid rows in every arena).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError("pool needs at least one page of one token")
        self.page_size = page_size
        self.n_pages = n_pages
        self.null = n_pages                    # sentinel row, never written
        self._arenas: dict[tuple, PoolArena] = {}
        self._refs = np.zeros((n_pages,), np.int32)
        self._free = list(range(n_pages - 1, -1, -1))   # pop() -> 0, 1, 2, ...
        self._owner: dict[int, str | None] = {}
        self._by_owner: dict[str | None, int] = {}
        self.stats = {"allocated": 0, "freed": 0, "high_water": 0}
        # fault-injection hook (chaos harness): called with the owner tag
        # at the top of every alloc, before the free list is touched — it
        # may raise (repro.serve.chaos.AllocFault) to model a transient
        # allocation failure. None = off.
        self.fault_hook = None

    def arena(self, cfg: ModelConfig, mesh: Mesh | None = None,
              tp_axis: str = "model") -> PoolArena:
        """Device arena for ``cfg``'s cache signature (created on first
        use). Same-signature configs on the same mesh get the *same*
        arena object.

        With ``mesh`` the arena's KV-head axis is sharded over
        ``tp_axis`` (:func:`repro.sharding.rules.serve_pool_spec`): each
        device holds ``Kh/tp`` heads of every page — the arena is
        *split*, not duplicated, so ``tp`` devices cost the same KV bytes
        as one. Page ids (and the host allocator) are mesh-invariant;
        arenas on different meshes are distinct device storage keyed
        ``(signature, mesh)``, because a page's bytes physically live
        only on the mesh slice that wrote them — replicas on disjoint
        slices therefore never share an arena (see
        :meth:`ServeCluster.add_replica_group`).
        """
        sig = (pool_signature(cfg), mesh, tp_axis if mesh is not None
               else None)
        if sig not in self._arenas:
            k, v = registry.paged_pool_init(cfg, self.n_pages + 1,
                                            self.page_size)
            if mesh is not None:
                R.validate_serve_tp(cfg, mesh_tp(mesh, tp_axis))
                sharding = NamedSharding(mesh, R.serve_pool_spec(tp_axis))
                k = jax.device_put(k, sharding)
                v = jax.device_put(v, sharding)
            self._arenas[sig] = PoolArena(k, v)
        return self._arenas[sig]

    def bytes_by_device(self) -> dict[str, int]:
        """Real KV bytes resident per device, summed over every arena's
        addressable shards — the number that shows a TP arena is split
        (Kh/tp heads per device) rather than duplicated. Complements
        :attr:`device_pages`, which counts logical pages per arena."""
        out: dict[str, int] = {}
        for arena in self._arenas.values():
            for arr in (arena.k, arena.v):
                for shard in arr.addressable_shards:
                    dev = str(shard.device)
                    out[dev] = out.get(dev, 0) + shard.data.nbytes
        return out

    def _check(self, idx: int) -> None:
        if not 0 <= idx < self.n_pages:
            raise ValueError(
                f"page id {idx} out of range (the null sentinel "
                f"{self.null} is not a refcounted page)")

    def alloc(self, owner: str | None = None) -> int:
        """Take a free page (one reference held by the caller). ``owner``
        tags the page for per-tenant accounting until it is recycled.
        With a ``fault_hook`` installed (chaos harness) the hook runs
        first and may raise — the pool is untouched in that case, so the
        caller's allocation loop is safely retryable."""
        if self.fault_hook is not None:
            self.fault_hook(owner)
        if not self._free:
            raise RuntimeError(
                f"page pool exhausted ({self.n_pages} pages, all referenced)")
        idx = self._free.pop()
        self._refs[idx] = 1
        self._owner[idx] = owner
        self._by_owner[owner] = self._by_owner.get(owner, 0) + 1
        self.stats["allocated"] += 1
        self.stats["high_water"] = max(self.stats["high_water"], self.in_use)
        return idx

    def retain(self, idx: int) -> None:
        """Add a reference to a live page (block-table pin, residency, or a
        cross-tenant adoption of a sibling engine's page)."""
        self._check(idx)
        if self._refs[idx] <= 0:
            raise ValueError(f"page {idx} retained while free")
        self._refs[idx] += 1

    def release(self, idx: int) -> None:
        """Drop one reference; the last release recycles the page."""
        self._check(idx)
        if self._refs[idx] <= 0:
            raise ValueError(f"page {idx} released more than retained")
        self._refs[idx] -= 1
        if self._refs[idx] == 0:
            self._free.append(idx)
            owner = self._owner.pop(idx, None)
            self._by_owner[owner] = self._by_owner.get(owner, 1) - 1
            if not self._by_owner[owner]:
                del self._by_owner[owner]
            self.stats["freed"] += 1

    @property
    def in_use(self) -> int:
        """Pages currently referenced (allocated and not yet recycled)."""
        return self.n_pages - len(self._free)

    @property
    def free_count(self) -> int:
        """Pages available for allocation."""
        return len(self._free)

    @property
    def device_pages(self) -> int:
        """Device pages actually materialised: every arena carries the full
        id space (plus the null row), so this is arenas × (n_pages + 1) —
        the number to quote when sizing real KV memory, as opposed to the
        shared *id-space* size ``n_pages``."""
        return len(self._arenas) * (self.n_pages + 1)

    def in_use_by(self, owner: str | None) -> int:
        """Live pages carrying ``owner``'s tag. This is **alloc-origin**
        accounting: a page stays charged to the tenant that allocated it
        until its final release recycles it, even while other tenants hold
        adopted references — use it to see who *fills* the pool, and
        :meth:`PageTable.resident_by_ns` to see who *keeps* residency (the
        cluster's fair reclaim arbitrates on the latter)."""
        return self._by_owner.get(owner, 0)

    def owners(self) -> dict[str | None, int]:
        """Tenant tag -> live page count (alloc-origin, see
        :meth:`in_use_by`), for stats and debugging."""
        return dict(self._by_owner)

    def refcounts(self) -> dict[int, int]:
        """Live page id -> reference count (for tests and debugging)."""
        return {i: int(r) for i, r in enumerate(self._refs) if r > 0}


def _decode_call(cfg: ModelConfig, window: int | None,
                 mesh: Mesh | None, tp_axis: str):
    """The decode body shared by the step fns: a direct
    ``registry.decode_step_paged`` on one device, or the same step under
    ``shard_map`` on a mesh — params and pool arrive as per-device head
    slices (in_specs derived from the registry's logical axes), block
    tables / lengths / tokens ride replicated, and the one collective is
    the head all-gather inside the transformer (``tp_axis``). Outputs:
    logits replicated (every device computes the identical post-gather
    tail), pools sharded as they came in.
    """
    if mesh is None:
        def call(params, pool_k, pool_v, tables, lengths, tok, mask):
            return registry.decode_step_paged(
                params, cfg, pool_k, pool_v, tables, lengths, tok,
                append_mask=mask, window=window)
        return call

    pool_spec = R.serve_pool_spec(tp_axis)
    param_specs = R.serve_param_specs(cfg, tp_axis)
    rep = PartitionSpec()

    def local(params, pool_k, pool_v, tables, lengths, tok, mask):
        return registry.decode_step_paged(
            params, cfg, pool_k, pool_v, tables, lengths, tok,
            append_mask=mask, window=window, tp_axis=tp_axis)

    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(param_specs, pool_spec, pool_spec, rep, rep, rep, rep),
        out_specs=(rep, pool_spec, pool_spec),
        check_vma=False)


def paged_step_fn(cfg: ModelConfig, window: int | None = None,
                  mesh: Mesh | None = None, tp_axis: str = "model"):
    """Jitted single-token paged decode over every lane.

    Signature: ``(params, pool_k, pool_v, tables, lengths, toks, feedback,
    prev, mask, emit, keys, temp, top_k, top_p) -> (next_tokens, pool_k',
    pool_v', keys')`` where ``toks`` (B,) are host-chosen tokens,
    ``feedback`` (B,) selects the previous step's on-device next token
    ``prev`` instead (async double-buffering), and ``mask`` (B,) gates the
    KV append (False = idle/stalled lane riding the batch). ``keys`` is the
    per-lane raw PRNG key state; each lane's next token is drawn by
    :func:`~repro.serve.sampling.sample` under its (``temp``, ``top_k``,
    ``top_p``) parameters — exact argmax at zero temperature — and its key
    splits only where ``emit`` (B,) is set, so the sampling chain position
    always equals the lane's produced-token count (replay determinism).
    ``window`` (sliding-window configs) switches the block tables to ring
    semantics — pass the engine's *clamped* window (``min(cfg.sliding_
    window, device cache length)``) so the decode stays bit-identical to
    the lane ring cache. Pools and keys are donated.

    ``mesh`` switches the decode to tensor parallelism over ``tp_axis``
    (:func:`_decode_call`): the same jitted step, with the transformer
    body under ``shard_map`` on head-sliced params and pool. Sampling
    runs outside the sharded region on the replicated logits, so the TP
    step's tokens are bit-identical to the single-device step's.
    """
    key = ("step", cfg, window, mesh, tp_axis if mesh is not None else None)
    if key not in _PAGED_FNS:
        decode = _decode_call(cfg, window, mesh, tp_axis)

        def step(params, pool_k, pool_v, tables, lengths, toks, feedback,
                 prev, mask, emit, keys, temp, top_k, top_p):
            tok = jnp.where(feedback, prev, toks)
            logits, pool_k, pool_v = decode(
                params, pool_k, pool_v, tables, lengths, tok, mask)
            carry, use = split_keys(keys)
            nxt = jax.vmap(sample)(logits, use, temp, top_k, top_p)
            keys = jnp.where(emit[:, None], carry, keys)
            return nxt, pool_k, pool_v, keys

        _PAGED_FNS[key] = jax.jit(step, donate_argnums=(1, 2, 10))
    return _PAGED_FNS[key]


def paged_chunk_fn(cfg: ModelConfig, chunk: int, window: int | None = None,
                   mesh: Mesh | None = None, tp_axis: str = "model"):
    """Jitted chunked step: up to ``chunk`` tokens per lane in one launch.

    Scans the single-token paged step; iterations past a lane's ``count``
    are masked appends (the pool is untouched bitwise, so a decode lane
    with ``count == 1`` sees exactly one append). The returned token is
    sampled (exact argmax at zero temperature) after each lane's last fed
    token. The per-lane key splits **once per launch** regardless of
    ``count`` — every scan iteration draws with the same per-launch
    subkey and only the last fed iteration's token is kept, so a chunked
    prefill's first generated token is bit-identical to the unchunked
    path's — and the split is kept only where ``emit`` is set (lanes
    whose prefill completes this launch, and decode lanes). ``window``
    and ``mesh``/``tp_axis`` as in :func:`paged_step_fn` (the sharded
    decode runs per scan iteration; the scan carry is the sharded pool).
    """
    key = ("chunk", cfg, chunk, window, mesh,
           tp_axis if mesh is not None else None)
    if key not in _PAGED_FNS:
        decode = _decode_call(cfg, window, mesh, tp_axis)

        def step(params, pool_k, pool_v, tables, lengths, toks, counts,
                 feedback, prev, emit, keys, temp, top_k, top_p):
            carry_keys, use = split_keys(keys)

            def body(carry, xs):
                pool_k, pool_v = carry
                j, tok_j = xs
                tok = jnp.where((j == 0) & feedback, prev, tok_j)
                logits, pool_k, pool_v = decode(
                    params, pool_k, pool_v, tables, lengths + j, tok,
                    j < counts)
                return ((pool_k, pool_v),
                        jax.vmap(sample)(logits, use, temp, top_k, top_p))

            (pool_k, pool_v), outs = lax.scan(
                body, (pool_k, pool_v),
                (jnp.arange(chunk, dtype=jnp.int32), toks.T))
            last = jnp.take_along_axis(
                outs.T, jnp.maximum(counts - 1, 0)[:, None], 1)[:, 0]
            keys = jnp.where(emit[:, None], carry_keys, keys)
            return last, pool_k, pool_v, keys

        _PAGED_FNS[key] = jax.jit(step, donate_argnums=(1, 2, 10))
    return _PAGED_FNS[key]
