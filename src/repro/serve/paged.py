"""Global KV page pool + jitted paged decode steps for the serving engine.

This is the device side of the paged backend: one pool of fixed-size KV
pages shared by every decode slot (and by the prefix page table), reached
per step through per-slot *block tables*. It replaces the PR 2 layout of
one full-length cache lane per slot — prefix adoption becomes block-table
pointing (no copy-on-write lane materialisation), publishing a page is a
host-side refcount bump (no device gather), and eviction returns page ids
to a free list instead of resetting whole lanes.

Invariants:

* **Pool refcounts never go negative.** Every page id handed out by
  :meth:`PagePool.alloc` / pinned by :meth:`PagePool.retain` is released
  exactly once; over-release raises (the ``Platform.bank_release``
  discipline, applied to pages).
* **A referenced page is never recycled.** A page returns to the free list
  only when its last holder (slot block table or page-table residency)
  releases it.
* **The null page is write-never.** Row ``null`` pads unused block-table
  entries; attention masks every position at or beyond a slot's length, so
  its contents are unobservable — appends target it only via the
  out-of-bounds drop trick for masked lanes, which writes nothing.

The jitted step functions take *device feedback*: a decoding lane's input
token can come straight from the previous step's on-device argmax
(``feedback``/``prev``), so the host never has to block on a transfer
before dispatching the next step — the data path of the engine's async
double-buffered dispatch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import registry
from repro.models.config import ModelConfig

__all__ = ["PagePool", "paged_step_fn", "paged_chunk_fn"]

# jitted paged kernels shared across engine instances (jax then caches
# compilations per pool/table shape)
_PAGED_FNS: dict = {}


class PagePool:
    """Fixed-size KV page pool with a free list and per-page refcounts.

    Device state is a (k, v) pair shaped ``(L, n_pages + 1, page_size, Kh,
    Dh)`` — the extra row is the null page (see module docstring). Host
    state is the allocator: ``alloc()`` hands out a page id with one
    reference; ``retain``/``release`` follow the shared-bank discipline.
    """

    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError("pool needs at least one page of one token")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        self.null = n_pages                    # sentinel row, never written
        self.k, self.v = registry.paged_pool_init(cfg, n_pages + 1, page_size)
        self._refs = np.zeros((n_pages,), np.int32)
        self._free = list(range(n_pages - 1, -1, -1))   # pop() -> 0, 1, 2, ...
        self.stats = {"allocated": 0, "freed": 0, "high_water": 0}

    def alloc(self) -> int:
        """Take a free page (one reference held by the caller)."""
        if not self._free:
            raise RuntimeError(
                f"page pool exhausted ({self.n_pages} pages, all referenced)")
        idx = self._free.pop()
        self._refs[idx] = 1
        self.stats["allocated"] += 1
        self.stats["high_water"] = max(self.stats["high_water"], self.in_use)
        return idx

    def retain(self, idx: int) -> None:
        """Add a reference to a live page (block-table pin, residency, ...)."""
        if self._refs[idx] <= 0:
            raise ValueError(f"page {idx} retained while free")
        self._refs[idx] += 1

    def release(self, idx: int) -> None:
        """Drop one reference; the last release recycles the page."""
        if self._refs[idx] <= 0:
            raise ValueError(f"page {idx} released more than retained")
        self._refs[idx] -= 1
        if self._refs[idx] == 0:
            self._free.append(idx)
            self.stats["freed"] += 1

    @property
    def in_use(self) -> int:
        """Pages currently referenced (allocated and not yet recycled)."""
        return self.n_pages - len(self._free)

    @property
    def free_count(self) -> int:
        """Pages available for allocation."""
        return len(self._free)

    def refcounts(self) -> dict[int, int]:
        """Live page id -> reference count (for tests and debugging)."""
        return {i: int(r) for i, r in enumerate(self._refs) if r > 0}


def paged_step_fn(cfg: ModelConfig):
    """Jitted single-token paged decode over every lane.

    Signature: ``(params, pool_k, pool_v, tables, lengths, toks, feedback,
    prev, mask) -> (next_tokens, pool_k', pool_v')`` where ``toks`` (B,) are
    host-chosen tokens, ``feedback`` (B,) selects the previous step's
    on-device argmax ``prev`` instead (async double-buffering), and ``mask``
    (B,) gates the KV append (False = idle/stalled lane riding the batch).
    Pools are donated.
    """
    key = ("step", cfg)
    if key not in _PAGED_FNS:
        def step(params, pool_k, pool_v, tables, lengths, toks, feedback,
                 prev, mask):
            tok = jnp.where(feedback, prev, toks)
            logits, pool_k, pool_v = registry.decode_step_paged(
                params, cfg, pool_k, pool_v, tables, lengths, tok,
                append_mask=mask)
            return (jnp.argmax(logits, -1).astype(jnp.int32), pool_k, pool_v)

        _PAGED_FNS[key] = jax.jit(step, donate_argnums=(1, 2))
    return _PAGED_FNS[key]


def paged_chunk_fn(cfg: ModelConfig, chunk: int):
    """Jitted chunked step: up to ``chunk`` tokens per lane in one launch.

    Scans the single-token paged step; iterations past a lane's ``count``
    are masked appends (the pool is untouched bitwise, so a decode lane
    with ``count == 1`` sees exactly one append). The returned token is the
    argmax after each lane's last fed token. Pools are donated.
    """
    key = ("chunk", cfg, chunk)
    if key not in _PAGED_FNS:
        def step(params, pool_k, pool_v, tables, lengths, toks, counts,
                 feedback, prev):
            def body(carry, xs):
                pool_k, pool_v = carry
                j, tok_j = xs
                tok = jnp.where((j == 0) & feedback, prev, tok_j)
                logits, pool_k, pool_v = registry.decode_step_paged(
                    params, cfg, pool_k, pool_v, tables, lengths + j, tok,
                    append_mask=j < counts)
                return ((pool_k, pool_v),
                        jnp.argmax(logits, -1).astype(jnp.int32))

            (pool_k, pool_v), outs = lax.scan(
                body, (pool_k, pool_v),
                (jnp.arange(chunk, dtype=jnp.int32), toks.T))
            last = jnp.take_along_axis(
                outs.T, jnp.maximum(counts - 1, 0)[:, None], 1)[:, 0]
            return last, pool_k, pool_v

        _PAGED_FNS[key] = jax.jit(step, donate_argnums=(1, 2))
    return _PAGED_FNS[key]
