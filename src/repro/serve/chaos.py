"""Deterministic fault injection for the serving stack.

This is the serving rendition of FEMU-style pre-silicon fault emulation:
X-HEEP's platform story is that compute domains can be power-gated away
and the host keeps running — here an engine, a device step, or a pool
allocation can fail mid-flight and the cluster must keep serving
bit-identical outputs. The :class:`FaultPlan` injects faults at defined
points through hooks threaded into the engine
(:mod:`repro.serve.engine`), the page pool (:mod:`repro.serve.paged`),
the prefix table (:mod:`repro.serve.pages`), and the cluster
(:mod:`repro.serve.cluster`):

* **Device-step failure** (``step_fail``) — the batched launch raises
  :class:`DeviceStepFault` before any device state is touched. All host
  bookkeeping that launch would have driven happens *after* the launch
  returns, and page allocation is idempotent-resumable, so the cluster
  retries the step after a bounded backoff.
* **Corrupted token** (``token_corrupt``) — the host-transferred next
  token is bit-flipped before retire (the on-device value is
  untouched, modelling a transfer-level upset). The engine's vocab
  range check refuses to journal it; the slot is quarantined and the
  request replays from the journal, whose ``record_token`` cross-check
  verifies the replayed prefix token-for-token.
* **NaN logits** (``nan_logits``) — the sampled token degenerates to
  ``-1`` (an argmax over all-NaN logits); detected and recovered
  exactly like a corrupted token.
* **Allocation failure** (``alloc_fail``) — :meth:`~repro.serve.paged.
  PagePool.alloc` raises :class:`AllocFault`; transient, retried with
  backoff like a step failure.
* **Engine crash** (``engine_crash``) — the engine loses *all*
  host-side slot state. The cluster sweeps the dead tenant's shared
  references, then rebuilds the engine from
  :meth:`~repro.runtime.ft.ClusterJournal.incomplete` — every in-flight
  request is re-admitted and replayed (re-adopting shared prefix pages
  where still resident).
* **Bank power-fault** (``bank_fault``) — one memory bank of the
  engine's platform faults: every slot on that bank is preempted and
  requeued (its pre-fault tokens are valid journal state), and a
  ``chaos.bank_fault`` interrupt fires on the platform's XAIF fabric.
* **Prefix-match drop** (``prefix_drop``) — a page-table ``acquire``
  spuriously misses, forcing a cold prefill. Sharing is an optimisation
  only, so this degrades throughput without touching any token.

Determinism contract (the invariant the chaos bench and tests assert):
the plan draws from per-``(kind, scope)`` streams seeded as
``random.Random(f"{seed}-{kind}-{scope}")`` — the string-keyed idiom of
:mod:`repro.serve.loadgen` — at decision points that are themselves
deterministic, so two same-seed chaos runs inject the identical fault
schedule and produce bit-identical outputs; and under *any* schedule,
every completed request's tokens equal the fault-free run's, with no
request lost or double-completed.
"""

from __future__ import annotations

import dataclasses
import random

__all__ = ["AllocFault", "DeviceStepFault", "FaultSpec", "FaultPlan"]


class DeviceStepFault(RuntimeError):
    """A batched device launch failed (transient; the step is retryable)."""


class AllocFault(RuntimeError):
    """A page-pool allocation failed (transient; the step is retryable)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-point fault probabilities (all default 0 = that fault off).

    ``step_fail``/``alloc_fail`` draw once per device launch / pool
    allocation; ``token_corrupt``/``nan_logits`` once per retired token;
    ``engine_crash``/``bank_fault`` once per cluster step per engine;
    ``prefix_drop`` once per page-table acquire.
    """

    step_fail: float = 0.0
    token_corrupt: float = 0.0
    nan_logits: float = 0.0
    alloc_fail: float = 0.0
    engine_crash: float = 0.0
    bank_fault: float = 0.0
    prefix_drop: float = 0.0

    def __post_init__(self):
        for f in dataclasses.fields(self):
            p = getattr(self, f.name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{f.name} must be a probability, got {p}")


# a corrupted token has bit 30 forced on: far above any model's vocab, so
# the engine's range check catches every injected flip (the analogue of an
# ECC/range trap on the device->host transfer)
_FLIP_BIT = 1 << 30


class FaultPlan:
    """Seeded, string-keyed fault schedule over the serving stack.

    One plan is shared by a cluster and all its engines; each injection
    point draws from its own ``(kind, scope)`` RNG stream (scope = engine
    name, pool owner, or namespace), so adding an engine or reordering
    hook calls in one scope never perturbs another scope's schedule.
    ``budget`` optionally caps injections per kind (``{"engine_crash":
    2}``) — a draw past its budget always passes, which bounds recovery
    work in smoke tests. ``counts`` tallies every injected fault by kind.
    """

    def __init__(self, seed: int, spec: FaultSpec,
                 budget: dict[str, int] | None = None):
        self.seed = int(seed)
        self.spec = spec
        self.budget = dict(budget) if budget else {}
        self.counts: dict[str, int] = {
            f.name: 0 for f in dataclasses.fields(FaultSpec)}
        self._rngs: dict[tuple[str, str], random.Random] = {}

    def _draw(self, kind: str, scope: str) -> bool:
        p = getattr(self.spec, kind)
        if p <= 0.0:
            return False
        key = (kind, scope)
        rng = self._rngs.get(key)
        if rng is None:
            rng = self._rngs[key] = random.Random(
                f"{self.seed}-{kind}-{scope}")
        hit = rng.random() < p
        if not hit:
            return False
        cap = self.budget.get(kind)
        if cap is not None and self.counts[kind] >= cap:
            return False
        self.counts[kind] += 1
        return True

    # -- engine-level points -------------------------------------------------

    def launch(self, engine: str) -> None:
        """Device-launch injection point: raises :class:`DeviceStepFault`
        on a ``step_fail`` draw. Called at the very top of the engine's
        launch, before any device buffer is donated, so a faulted step
        leaves device and host state untouched and fully retryable."""
        if self._draw("step_fail", engine):
            raise DeviceStepFault(f"injected device-step fault on {engine}")

    def deliver_token(self, engine: str, token: int) -> int:
        """Token-transfer injection point: returns ``token`` possibly
        corrupted — bit-flipped out of vocab range (``token_corrupt``) or
        degenerated to ``-1`` (``nan_logits``, an argmax over all-NaN
        logits). The engine's range check quarantines either one."""
        if self._draw("token_corrupt", engine):
            return int(token) | _FLIP_BIT
        if self._draw("nan_logits", engine):
            return -1
        return int(token)

    # -- pool / table points -------------------------------------------------

    def alloc(self, owner: str | None) -> None:
        """Pool-allocation injection point (wired as
        :attr:`~repro.serve.paged.PagePool.fault_hook`): raises
        :class:`AllocFault` on an ``alloc_fail`` draw, before the free
        list is touched."""
        if self._draw("alloc_fail", owner or ""):
            raise AllocFault(
                f"injected page-allocation fault (owner {owner!r})")

    def drop_prefix(self, ns: str) -> bool:
        """Prefix-acquire injection point (wired as
        :attr:`~repro.serve.pages.PageTable.fault_hook`): True =
        suppress this acquire's match, forcing a cold prefill."""
        return self._draw("prefix_drop", ns)

    # -- cluster-level points ------------------------------------------------

    def crash(self, engine: str) -> bool:
        """Per-cluster-step crash draw for ``engine``: True = the engine
        loses all host-side slot state this round (the cluster sweeps and
        rebuilds it from the journal)."""
        return self._draw("engine_crash", engine)

    def bank(self, engine: str) -> bool:
        """Per-cluster-step bank power-fault draw for ``engine``: True =
        one of its occupied banks faults (every slot on it is preempted
        and requeued)."""
        return self._draw("bank_fault", engine)
