"""Latency metrics and SLO accounting for open-loop serving simulations.

The open-loop harness (:mod:`repro.serve.loadgen` driving
:class:`repro.serve.sim.ClusterSimulator`) measures what a serving system
actually promises its users: not raw throughput, but *latency under load*
and *goodput* — tokens delivered inside each request's service-level
objective. This module is the measurement layer:

* :class:`SLO` — per-request latency targets (attach one to
  ``Request.slo``; the cluster scheduler and this module both read it).
* :func:`percentile` — exact nearest-rank percentiles (no interpolation:
  the reported p99 is a latency some real request actually experienced).
* :func:`request_ttft` / :func:`request_tpot` / :func:`met_slo` — pure
  per-request derivations from the engine's timestamps
  (``arrival_time`` → ``first_token_time`` → ``finish_time``).
* :class:`ServeMetrics` — an accumulator over finished requests that
  reports p50/p99 TTFT, p50/p99 per-token latency, SLO attainment, and
  goodput.

Definitions (simulated-clock units throughout):

* **TTFT** (time to first token): ``first_token_time - arrival_time``.
  Measured from *arrival*, not admission — queueing delay under overload
  is the user-visible part.
* **TPOT** (time per output token): ``(finish_time - first_token_time) /
  (n_tokens - 1)`` — the mean inter-token latency after the first token
  (``0.0`` for single-token outputs).
* **SLO attainment**: fraction of finished requests *carrying an SLO*
  that met every target they set (``1.0`` when no request carries one).
* **Goodput**: tokens from SLO-meeting finished requests per unit of
  simulated time (a request without an SLO always counts as good).
  Rejected and shed requests deliver zero tokens, so overload shows up
  as a goodput gap even before latency percentiles are read.

Everything here is pure host arithmetic on journaled timestamps — same
seed, same trace, same metrics, bit-identical.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

__all__ = ["SLO", "ServeMetrics", "met_slo", "percentile", "request_tpot",
           "request_ttft"]


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency targets in simulated-clock units.

    ``ttft`` caps the time from arrival to the first generated token;
    ``tpot`` caps the mean per-output-token latency after the first
    token. ``None`` means "don't care" for that component; a request with
    neither set is unconstrained (always counted as meeting its SLO).
    """

    ttft: float | None = None
    tpot: float | None = None

    def __post_init__(self):
        for name, v in (("ttft", self.ttft), ("tpot", self.tpot)):
            if v is not None and v <= 0:
                raise ValueError(f"SLO {name} must be positive, got {v}")

    def deadline(self, arrival_time: float, max_new_tokens: int) -> float:
        """Latest finish time at which the request can still meet every
        target it set: ``arrival + ttft + tpot * (max_new_tokens - 1)``
        (unset components contribute nothing; ``inf`` when neither is
        set). The cluster's preemption policy compares the clock against
        this to spot requests that are already doomed."""
        if self.ttft is None and self.tpot is None:
            return math.inf
        t = arrival_time
        if self.ttft is not None:
            t += self.ttft
        if self.tpot is not None:
            t += self.tpot * max(0, max_new_tokens - 1)
        return t


def percentile(values: Sequence[float], q: float) -> float:
    """Exact nearest-rank percentile: the smallest element such that at
    least ``q`` percent of the data is ≤ it. No interpolation — the
    returned p99 is a latency some request actually experienced. Raises
    on an empty sequence (an empty p99 is a harness bug, not a 0.0)."""
    if not values:
        raise ValueError("percentile of an empty sequence")
    if not 0 < q <= 100:
        raise ValueError(f"q must be in (0, 100], got {q}")
    xs = sorted(values)
    k = max(0, math.ceil(q / 100 * len(xs)) - 1)
    return float(xs[k])


def request_ttft(request) -> float:
    """Time to first token of a finished request (arrival → first
    generated token). Raises if the engine's timestamps are missing —
    an unfinished request has no TTFT to report."""
    if request.arrival_time is None or request.first_token_time is None:
        raise ValueError(f"request {request.id!r} has no TTFT timestamps")
    return request.first_token_time - request.arrival_time


def request_tpot(request) -> float:
    """Mean per-output-token latency after the first token of a finished
    request (``0.0`` for single-token outputs)."""
    n = len(request.tokens)
    if n <= 1:
        return 0.0
    if request.finish_time is None or request.first_token_time is None:
        raise ValueError(f"request {request.id!r} has no TPOT timestamps")
    return (request.finish_time - request.first_token_time) / (n - 1)


def met_slo(request) -> bool:
    """True when a finished request met every target of its ``slo``
    (requests without an SLO trivially meet it)."""
    slo = getattr(request, "slo", None)
    if slo is None:
        return True
    if slo.ttft is not None and request_ttft(request) > slo.ttft:
        return False
    if slo.tpot is not None and request_tpot(request) > slo.tpot:
        return False
    return True


class ServeMetrics:
    """Accumulate per-request latency observations into one report.

    Feed every finished request through :meth:`observe` (or a batch via
    :meth:`observe_all`), then read :meth:`summary`. The accumulator keeps
    the full TTFT/TPOT samples so the percentiles are exact, and the
    per-request derivations live in the module-level functions — the
    collector adds no statistics of its own.
    """

    def __init__(self):
        self.ttfts: list[float] = []
        self.tpots: list[float] = []
        self.energies: list[float] = []       # per-request attributed µJ
        self.good_tokens = 0
        self.total_tokens = 0
        self.slo_met = 0
        self.slo_total = 0

    def observe(self, request) -> None:
        """Record one finished request (its ``arrival_time`` /
        ``first_token_time`` / ``finish_time`` stamps must be set by the
        engine). A metered engine's ``energy_uj`` attribution is picked
        up automatically; unmetered runs contribute zeros and the energy
        summary fields stay absent."""
        self.ttfts.append(request_ttft(request))
        self.tpots.append(request_tpot(request))
        self.energies.append(float(getattr(request, "energy_uj", 0.0)))
        n = len(request.tokens)
        self.total_tokens += n
        ok = met_slo(request)
        if getattr(request, "slo", None) is not None:
            self.slo_total += 1
            self.slo_met += int(ok)
        if ok:
            self.good_tokens += n

    def observe_all(self, requests: Iterable) -> None:
        """Record a batch of finished requests."""
        for req in requests:
            self.observe(req)

    @property
    def count(self) -> int:
        """Finished requests observed so far."""
        return len(self.ttfts)

    def attainment(self) -> float:
        """Fraction of SLO-carrying finished requests that met their SLO
        (``1.0`` when none carried one)."""
        return self.slo_met / self.slo_total if self.slo_total else 1.0

    def summary(self, elapsed: float | None = None) -> dict:
        """One flat dict of the headline numbers: exact p50/p99 (and
        mean) TTFT, p50/p99 per-token latency, SLO attainment, and
        good/total token counts. Pass the run's simulated ``elapsed`` to
        additionally get ``goodput``/``throughput`` rates. Metered runs
        (any nonzero ``Request.energy_uj``) add per-request energy
        percentiles plus ``uj_per_token`` / ``tokens_per_joule`` —
        the serving rendition of the paper's Fig. 6 energy framing."""
        out = {
            "completed": self.count,
            "slo_requests": self.slo_total,
            "slo_attainment": self.attainment(),
            "good_tokens": self.good_tokens,
            "total_tokens": self.total_tokens,
        }
        if self.ttfts:
            out.update(
                ttft_p50=percentile(self.ttfts, 50),
                ttft_p99=percentile(self.ttfts, 99),
                ttft_mean=sum(self.ttfts) / len(self.ttfts),
                tpot_p50=percentile(self.tpots, 50),
                tpot_p99=percentile(self.tpots, 99),
            )
        total_uj = sum(self.energies)
        if total_uj > 0:
            out.update(
                energy_uj_p50=percentile(self.energies, 50),
                energy_uj_p99=percentile(self.energies, 99),
                energy_uj_total=total_uj,
            )
            if self.total_tokens:
                out["uj_per_token"] = total_uj / self.total_tokens
                out["tokens_per_joule"] = self.total_tokens / (total_uj * 1e-6)
        if elapsed:
            out["goodput"] = self.good_tokens / elapsed
            out["throughput"] = self.total_tokens / elapsed
        return out
