"""Deterministic simulation of the serving engine under scripted traffic.

No wall clock anywhere: a :class:`FakeClock` provides time, arrivals come
from a scripted :class:`Trace`, and every engine step is charged a fixed
cost — ``step_time`` of device compute plus ``dispatch_time`` of host
scheduling. A synchronous engine pays the two serially; an engine running
async double-buffered dispatch overlaps them, modelled with an explicit
device-busy-until pipeline (see :class:`Simulator`). This makes
throughput, latency, and fairness assertions exactly reproducible — the
serving analogue of the repo's step-indexed data pipeline — including the
measured win from host/device overlap.

The same harness drives two admission policies:

* ``sequential=False`` — continuous batching (the engine's native mode).
* ``sequential=True``  — one-request-at-a-time serving: the next request is
  only handed to the engine when it is completely idle. This is the
  baseline the paper's interrupt-driven overlap is measured against.

Trace generators: :func:`staggered_trace` (arrivals ``gap`` apart),
:func:`burst_trace` (everything at once), and
:func:`shared_prefix_requests` (a multi-tenant workload where every
request's prompt starts with the same prefix — the page-table reuse
workload; with prefix sharing enabled only the first request prefills the
shared pages). For a multi-model cluster, tag each arrival with its
target engine (:func:`tag_engine`) and drive the merged trace through
:class:`ClusterSimulator` — several engines, one fake clock, one report.

Traces may also be *lazy*: both simulators accept any iterator of
:class:`Arrival` (e.g. :func:`repro.serve.loadgen.open_loop_trace`) and
pull from it one arrival at a time, so a 10⁵–10⁶-request open-loop trace
never materialises in memory. Lazy traces must already be time-ordered
(generators own their ordering); materialised sequences are stable-sorted
by the simulator as before.

Invariants the harness preserves: no wall clock or randomness anywhere, so
every report is exactly reproducible; same-time arrivals are delivered in
trace order (FIFO admission is observable end-to-end); and a reused engine
reports per-run deltas, never cumulative lifetime counters.
"""

from __future__ import annotations

import collections.abc
import dataclasses
from typing import Iterable, Sequence

from repro.serve.engine import ContinuousBatchingEngine, Request


class FakeClock:
    """Deterministic simulated time source (compatible with FTController)."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        """Move time forward by ``dt`` (negative ``dt`` raises)."""
        if dt < 0:
            raise ValueError("time cannot run backwards")
        self.t += dt

    def advance_to(self, t: float) -> None:
        """Jump forward to ``t`` (never backwards)."""
        self.t = max(self.t, float(t))


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scripted arrival; ``engine`` routes it on a cluster trace
    (single-engine simulations leave it ``None``)."""

    time: float
    request: Request
    engine: str | None = None


def staggered_trace(requests: Sequence[Request], start: float = 0.0,
                    gap: float = 1.0) -> list[Arrival]:
    """Arrivals spaced ``gap`` apart — the canonical overlap workload."""
    return [Arrival(start + i * gap, r) for i, r in enumerate(requests)]


def burst_trace(requests: Sequence[Request], at: float = 0.0) -> list[Arrival]:
    """Everything at once — the saturation workload."""
    return [Arrival(at, r) for r in requests]


def tag_engine(trace: Sequence[Arrival], engine: str) -> list[Arrival]:
    """Route every arrival of ``trace`` to cluster engine ``engine``.
    Merge tagged traces (list concatenation) before handing them to
    :class:`ClusterSimulator`; delivery is stable-sorted by time, so
    same-time arrivals keep their merged order."""
    return [Arrival(a.time, a.request, engine) for a in trace]


def shared_prefix_requests(n: int, *, prefix_len: int = 64,
                           tail_len: int = 4, new_tokens: int = 8,
                           prefix: Sequence[int] | None = None,
                           id_prefix: str = "shared") -> list[Request]:
    """``n`` requests whose prompts share one ``prefix_len``-token prefix.

    The shared-prefix serving workload (a common system prompt, a shared
    document, a RAG template): tails are distinct per request, so outputs
    diverge after the prefix. Deterministic — same arguments, same
    requests. Pass an explicit ``prefix`` to pin the shared tokens.
    """
    if prefix is None:
        prefix = [(13 * j) % 241 + 1 for j in range(prefix_len)]
    prefix = [int(t) for t in prefix]
    return [
        Request(id=f"{id_prefix}{i}",
                prompt=prefix + [(17 * i + 5 * j) % 239 + 1
                                 for j in range(tail_len)],
                max_new_tokens=new_tokens)
        for i in range(n)
    ]


class _TraceFeed:
    """Uniform, lazily-consumed view over an arrival trace.

    A materialised sequence is validated up front and stable-sorted by
    time (same-time arrivals keep trace order — the FIFO contract). Any
    other iterable is consumed one arrival at a time — an open-loop
    generator of 10⁶ arrivals costs O(1) memory — and must already be
    time-ordered: the feed enforces nondecreasing times and validates
    each arrival as it surfaces, so a bad engine tag raises a clear
    ``ValueError`` naming the arrival instead of a bare ``KeyError``
    deep inside the cluster.
    """

    def __init__(self, trace: Iterable[Arrival], *,
                 engines: collections.abc.Set | None = None):
        self._engines = engines
        self._lazy = not isinstance(trace, collections.abc.Sequence)
        if self._lazy:
            self._it = iter(trace)
        else:
            arrivals = list(trace)
            for arr in arrivals:
                self._validate(arr)
            arrivals.sort(key=lambda a: a.time)      # stable: ties keep order
            self._it = iter(arrivals)
        self._last = float("-inf")
        self.head: Arrival | None = None
        self._advance()

    def _validate(self, arr: Arrival) -> None:
        if self._engines is None or arr.engine in self._engines:
            return
        if arr.engine is None:
            raise ValueError(
                f"untagged arrival {arr.request.id!r}: cluster traces "
                "route by engine name (see tag_engine)")
        raise ValueError(
            f"arrival {arr.request.id!r} targets unknown engine "
            f"{arr.engine!r} (cluster engines: {sorted(self._engines)}; "
            "see tag_engine)")

    def _advance(self) -> None:
        arr = next(self._it, None)
        if arr is not None and self._lazy:
            if arr.time < self._last:
                raise ValueError(
                    f"lazy trace ran backwards: arrival "
                    f"{arr.request.id!r} at t={arr.time} after t="
                    f"{self._last} (generator traces must be "
                    "nondecreasing; materialise a list to let the "
                    "simulator sort)")
            self._validate(arr)
        if arr is not None:
            self._last = arr.time
        self.head = arr

    def pop(self) -> Arrival:
        """Return the current head and pull the next arrival forward."""
        arr = self.head
        self._advance()
        return arr

    def __bool__(self) -> bool:
        return self.head is not None

    def __getitem__(self, i: int) -> Arrival:
        # head-only indexing keeps the `sim.pending[0].time` drive-by-hand
        # idiom working on lazy feeds (only the head is materialised)
        if i != 0 or self.head is None:
            raise IndexError("trace feed exposes only its head arrival")
        return self.head


@dataclasses.dataclass
class SimReport:
    elapsed: float                    # fake-clock span of the run
    steps: int
    tokens_generated: int
    completed: list                   # requests, completion order
    rejected: int
    energy_uj: float = 0.0            # metered platform energy, this run

    @property
    def throughput(self) -> float:
        """Generated tokens per unit of fake time."""
        return self.tokens_generated / self.elapsed if self.elapsed else 0.0

    @property
    def tokens_per_joule(self) -> float:
        """Generated tokens per joule of metered platform energy
        (``0.0`` for unmetered runs)."""
        if self.energy_uj <= 0:
            return 0.0
        return self.tokens_generated / (self.energy_uj * 1e-6)


class Simulator:
    """Drive an engine step-by-step from a scripted arrival trace.

    The cost model has two components per engine step: ``step_time`` (the
    device computing one batched launch) and ``dispatch_time`` (the host
    building the batch, journaling, scheduling — everything in
    ``engine.step()`` outside the device). A synchronous engine pays them
    serially: ``dispatch_time + step_time`` per step. An engine with
    ``async_dispatch=True`` overlaps them — the host dispatches step N+1
    while the device chews on step N — so the steady-state cost is
    ``max(dispatch_time, step_time)`` per step, modelled with an explicit
    device-busy-until timestamp (depth-1 double buffering: the host blocks
    on step N-1's completion only after dispatching step N). The default
    ``dispatch_time=0.0`` reproduces the PR 1/PR 2 accounting exactly.
    """

    def __init__(self, engine: ContinuousBatchingEngine,
                 trace: Iterable[Arrival], clock: FakeClock, *,
                 step_time: float = 1.0, dispatch_time: float = 0.0,
                 sequential: bool = False):
        if engine.clock is not clock:
            raise ValueError("engine must share the simulator's clock")
        if step_time < 0 or dispatch_time < 0:
            raise ValueError("step/dispatch times cannot be negative")
        self.engine = engine
        self.clock = clock
        self.step_time = step_time
        self.dispatch_time = dispatch_time
        self.sequential = sequential
        self._device_free = clock.t          # device pipeline: busy-until
        self.pending = _TraceFeed(trace)

    def _deliver_due(self) -> None:
        eng = self.engine
        while self.pending and self.pending[0].time <= self.clock.t:
            if self.sequential and eng.busy:
                break                    # hold traffic until the engine drains
            arr = self.pending.pop()
            arr.request.arrival_time = arr.time
            eng.submit(arr.request)
            if self.sequential:
                break                    # at most one request in flight

    def _timed_step(self) -> None:
        """Advance the engine one step and charge the cost model."""
        eng = self.engine
        steps_before = eng.steps
        eng.step()
        launched = eng.steps > steps_before
        if not getattr(eng, "async_dispatch", False):
            if launched:
                self.clock.advance(self.dispatch_time + self.step_time)
            return
        if not launched:
            # flush-only step (retiring the in-flight launch at drain time)
            self.clock.advance_to(self._device_free)
            return
        dispatched = self.clock.t + self.dispatch_time
        prev_free = self._device_free
        # device starts when both the dispatch and its previous step are done
        self._device_free = max(dispatched, prev_free) + self.step_time
        # depth-1 double buffer: after dispatching step N the host retires
        # step N-1, blocking until the device finished it
        self.clock.advance_to(max(dispatched, prev_free))

    def run(self, max_steps: int = 1_000_000) -> SimReport:
        """Deliver arrivals and step the engine until the trace drains;
        returns this run's deltas (a reused engine never double-counts)."""
        eng = self.engine
        # snapshot the engine's lifetime counters: a reused engine must
        # report this run's deltas, not cumulative totals over stale time
        t0 = self.clock.t
        steps0, tokens0 = eng.steps, eng.tokens_generated
        done0, rejected0 = len(eng.completed), eng.rejected
        energy0 = eng._meter.total_uj if eng._meter is not None else 0.0
        for _ in range(max_steps):
            self._deliver_due()
            if eng.busy:
                self._timed_step()
            elif self.pending:
                # idle: jump to the next arrival instead of spinning
                self.clock.advance_to(self.pending[0].time)
            else:
                break
        else:
            raise RuntimeError(f"simulation did not drain in {max_steps} steps")
        if getattr(eng, "async_dispatch", False):
            self.clock.advance_to(self._device_free)   # drain the pipeline
        energy = (eng._meter.total_uj - energy0
                  if eng._meter is not None else 0.0)
        return SimReport(elapsed=self.clock.t - t0, steps=eng.steps - steps0,
                         tokens_generated=eng.tokens_generated - tokens0,
                         completed=list(eng.completed[done0:]),
                         rejected=eng.rejected - rejected0,
                         energy_uj=energy)


@dataclasses.dataclass
class ClusterSimReport:
    """One cluster run: aggregate counters plus per-engine completions."""

    elapsed: float                    # fake-clock span of the run
    steps: int                        # cluster scheduling rounds
    tokens_generated: int             # summed over every engine
    completed: dict                   # engine name -> requests, finish order
    rejected: int                     # summed engine backpressure rejections
    shed: int = 0                     # summed SLO-busted heads dropped
    energy_uj: float = 0.0            # summed metered energy, this run

    @property
    def throughput(self) -> float:
        """Aggregate generated tokens per unit of fake time."""
        return self.tokens_generated / self.elapsed if self.elapsed else 0.0

    @property
    def tokens_per_joule(self) -> float:
        """Aggregate generated tokens per joule of metered energy
        (``0.0`` for unmetered runs)."""
        if self.energy_uj <= 0:
            return 0.0
        return self.tokens_generated / (self.energy_uj * 1e-6)


class ClusterSimulator:
    """Drive a :class:`~repro.serve.cluster.ServeCluster` from one merged,
    engine-tagged arrival trace (list or lazy generator) on one fake clock.

    Cost model: the cluster's engines are modelled as concurrently running
    accelerator tiles on one platform (the X-HEEP picture), so one cluster
    step — every busy engine advancing one batched launch — charges
    ``dispatch_time`` once, plus device time per the engine's own dispatch
    mode. A synchronous engine holds the round open for its full
    ``step_time``; an ``async_dispatch`` engine carries its own
    device-busy-until pipeline (exactly the :class:`Simulator` depth-1
    double-buffer model, one pipeline per engine), so the round only waits
    for its *previous* launch and its device time overlaps the next
    round's host work. Cross-engine prefix reuse therefore shows up as
    *fewer cluster steps* to drain the same trace, and async tenants are
    charged their overlapped cost, not the sync one. With only sync
    engines this reproduces the original ``dispatch_time + step_time``
    per-round accounting bit-for-bit.
    """

    def __init__(self, cluster, trace: Iterable[Arrival], clock: FakeClock,
                 *, step_time: float = 1.0, dispatch_time: float = 0.0):
        if cluster.clock is not clock:
            raise ValueError("cluster must share the simulator's clock")
        if step_time < 0 or dispatch_time < 0:
            raise ValueError("step/dispatch times cannot be negative")
        # engine tags are validated against the cluster's tenant set — a
        # sequence trace entirely at construction, a lazy one per arrival
        self.cluster = cluster
        self.clock = clock
        self.step_time = step_time
        self.dispatch_time = dispatch_time
        # replica groups are valid targets too (cluster.submit routes them)
        self.pending = _TraceFeed(
            trace, engines=getattr(cluster, "targets", None)
            or set(cluster.engines))

    def _deliver_due(self) -> None:
        while self.pending and self.pending[0].time <= self.clock.t:
            arr = self.pending.pop()
            arr.request.arrival_time = arr.time
            self.cluster.submit(arr.engine, arr.request)

    def run(self, max_steps: int = 1_000_000) -> ClusterSimReport:
        """Deliver arrivals and step the cluster until the trace drains;
        returns this run's deltas (a reused cluster never double-counts)."""
        cl = self.cluster
        t0 = self.clock.t
        steps0 = cl.steps
        tokens0 = {n: e.tokens_generated for n, e in cl.engines.items()}
        done0 = {n: len(e.completed) for n, e in cl.engines.items()}
        rejected0 = {n: e.rejected for n, e in cl.engines.items()}
        shed0 = {n: e.shed for n, e in cl.engines.items()}
        # meters survive crash rebuilds (the cluster carries them over),
        # so per-name snapshots stay valid across mid-run engine swaps
        energy0 = {n: e._meter.total_uj for n, e in cl.engines.items()
                   if e._meter is not None}
        # per-engine device pipelines (device-busy-until timestamps)
        dev_free = {n: self.clock.t for n in cl.engines}
        steps_prev = {n: e.steps for n, e in cl.engines.items()}
        for _ in range(max_steps):
            self._deliver_due()
            if cl.busy:
                pend_prev = {n: e._pending is not None
                             for n, e in cl.engines.items()}
                if cl.step():
                    dispatched = self.clock.t + self.dispatch_time
                    round_end = dispatched
                    for n, e in cl.engines.items():
                        launched = e.steps > steps_prev[n]
                        steps_prev[n] = e.steps
                        if not getattr(e, "async_dispatch", False):
                            if launched:       # sync: round holds for device
                                dev_free[n] = dispatched + self.step_time
                                round_end = max(round_end, dev_free[n])
                        elif launched:
                            # async: device starts once the dispatch and the
                            # engine's previous step are both done; the host
                            # only blocks on the *previous* step (depth-1)
                            prev = dev_free[n]
                            dev_free[n] = (max(dispatched, prev)
                                           + self.step_time)
                            round_end = max(round_end, prev)
                        elif pend_prev[n] and e._pending is None:
                            # flush-only: host blocked until the in-flight
                            # launch finished on the device
                            round_end = max(round_end, dev_free[n])
                    self.clock.advance_to(round_end)
            elif self.pending:
                # idle: jump to the next arrival instead of spinning
                self.clock.advance_to(self.pending[0].time)
            else:
                break
        else:
            raise RuntimeError(f"simulation did not drain in {max_steps} steps")
        if dev_free:
            self.clock.advance_to(max(dev_free.values()))  # drain pipelines
        return ClusterSimReport(
            elapsed=self.clock.t - t0, steps=cl.steps - steps0,
            tokens_generated=sum(e.tokens_generated - tokens0[n]
                                 for n, e in cl.engines.items()),
            completed={n: list(e.completed[done0[n]:])
                       for n, e in cl.engines.items()},
            rejected=sum(e.rejected - rejected0[n]
                         for n, e in cl.engines.items()),
            shed=sum(e.shed - shed0[n] for n, e in cl.engines.items()),
            energy_uj=sum(e._meter.total_uj - energy0.get(n, 0.0)
                          for n, e in cl.engines.items()
                          if e._meter is not None))
