"""Step-level energy meter for the serving engine (ROADMAP item 5).

Integrates the calibrated HEEPocrates domain model (:mod:`repro.core.energy`
/ :mod:`repro.core.power`) over the engine loop and attributes joules to
individual requests. The meter is purely observational — it never touches
launches, tokens, PRNG state, or admission order, so a metered engine's
completed tokens are bit-identical to an unmetered run of the same trace.

Accounting model
----------------

Work is charged in **cycles**, converted to energy at the meter's current
DVFS :class:`~repro.core.energy.OperatingPoint`:

* a decode token costs ``CYCLES_PER_DECODE_TOKEN``, a prefill token
  ``CYCLES_PER_PREFILL_TOKEN``;
* bank dynamic energy is ``active_dyn × dyn_scale(V) × cycles`` — CV²·cycles,
  so frequency cancels and only voltage matters;
* bank leakage accrues over the *time* those cycles take
  (``cycles / freq``), so a lower-frequency point pays more leakage per
  token — together these land the two calibrated points on the paper's
  §IV-D ~2.1× DVFS energy ratio;
* KV pages held by a slot leak at a retention-class per-page power for the
  step's duration, with shared prefix pages split ``1/refcount`` across
  their local holders;
* per-step CPU work and the engine's *idle* banks go to unattributed
  overhead buckets (``host`` / ``idle``): the CPU burns
  :data:`HOST_DISPATCH_CYCLES` of active dispatch per step, then waits out
  the device. With clock gating on (the default) the waiting CPU and the
  idle banks fall to leakage; with ``gate_idle_banks=False`` both burn
  full ON duty-0 power — the host-only baseline of the tokens/joule
  benchmark, mirroring the paper's Fig. 6 clock-gated vs active split.

Conservation holds by construction and is property-tested
(``tests/test_energy_serve.py``)::

    total_uj == attributed_uj + overhead_uj
    attributed_uj == Σ Request.energy_uj  (over every metered request)

All accumulators are monotone non-decreasing; every charge is ≥ 0. Each
engine meters its own bank/page view, so cluster totals are sums of
per-engine meters (a shared pool page held by two engines is split only
among the holders each meter can see).
"""

from __future__ import annotations

from repro.core import energy
from repro.core.power import RETENTION_LEAK_FACTOR

# A bank holds this many KV pages in the retention-cost model: one bank's
# retention-class leakage is split evenly over its pages, giving the
# per-page holding power below. Purely an accounting granularity — the
# pool's real page count is whatever the engine configured.
PAGES_PER_BANK = 8

# Per-page holding power (µW at 0.8 V): a held KV page keeps 1/8th of a
# bank in retention — 5.0 µW leak × 0.575 retention factor / 8 pages.
PAGE_RETENTION_UW = 5.0 * RETENTION_LEAK_FACTOR / PAGES_PER_BANK

# CPU cycles of active host work per engine step (batch building,
# journaling, scheduling); the rest of the step the CPU waits on the
# device — at gated leakage or, ungated, at ON duty-0 power.
HOST_DISPATCH_CYCLES = 1e5


class EnergyMeter:
    """Per-engine joule accounting over the calibrated domain model.

    The engine calls :meth:`tick` once per step (wall/sim-clock retention)
    and :meth:`charge_step` after each device launch (cycle-derived work);
    policies read :meth:`projected_uj_per_token` and flip the DVFS point
    with :meth:`set_point`. Everything else is read-only reporting.
    """

    def __init__(self, *, point: str = "max",
                 gate_idle_banks: bool = True) -> None:
        pm = energy.build_heepocrates_pm()
        self._cpu = pm.domains["cpu"]
        self._bank = pm.domains["bank0"]
        self._point = energy.operating_point(point)
        self.gate_idle_banks = gate_idle_banks
        # attributed buckets (mirrored into Request.energy_uj)
        self.prefill_uj = 0.0
        self.decode_uj = 0.0
        self.pages_uj = 0.0
        self.retention_uj = 0.0
        # unattributed overhead buckets
        self.host_uj = 0.0
        self.idle_uj = 0.0
        self.dvfs_switches = 0
        self._last_tick: float | None = None

    # -- DVFS ---------------------------------------------------------------

    @property
    def point(self) -> energy.OperatingPoint:
        """The meter's current DVFS operating point."""
        return self._point

    def set_point(self, name: str) -> None:
        """Switch the DVFS point (accounting only — outputs never change)."""
        pt = energy.operating_point(name)
        if pt is not self._point:
            self._point = pt
            self.dvfs_switches += 1

    # -- totals -------------------------------------------------------------

    @property
    def attributed_uj(self) -> float:
        """Energy charged to specific requests (Σ ``Request.energy_uj``)."""
        return (self.prefill_uj + self.decode_uj + self.pages_uj
                + self.retention_uj)

    @property
    def overhead_uj(self) -> float:
        """Energy no single request owns: CPU dispatch + idle banks."""
        return self.host_uj + self.idle_uj

    @property
    def total_uj(self) -> float:
        """Total platform energy integral — conservation's left-hand side."""
        return self.attributed_uj + self.overhead_uj

    def projected_uj_per_token(self) -> float:
        """Marginal decode-token energy at the current point.

        The energy-aware admission policy compares this against a tenant's
        ``energy_cap_uj_per_token``: ~4.4 µJ at ``max``, ~2.1 µJ at
        ``nominal`` (the calibrated §IV-D tradeoff).
        """
        pt = self._point
        cycles = energy.CYCLES_PER_DECODE_TOKEN
        dyn = self._bank.active_dyn_uw_mhz * pt.dyn_scale * cycles * 1e-6
        leak = (self._bank.leak_uw * pt.leak_scale
                * cycles / (pt.freq_mhz * 1e6))
        return dyn + leak

    # -- charging -----------------------------------------------------------

    def charge_step(self, slot_charges, idle_banks: int) -> None:
        """Charge one device step.

        ``slot_charges`` is ``[(request, kind, tokens, page_share)]`` for
        every slot the launch fed: ``kind`` is ``"prefill"`` or ``"decode"``,
        ``tokens`` the count consumed/produced this step, ``page_share`` the
        slot's refcount-weighted KV page holding. ``idle_banks`` is how many
        of the engine's banks hosted no occupied slot during the step.
        """
        pt = self._point
        ds, ls = pt.dyn_scale, pt.leak_scale
        hz = pt.freq_mhz * 1e6
        tau_step = 0.0
        for request, kind, tokens, page_share in slot_charges:
            per_tok = (energy.CYCLES_PER_PREFILL_TOKEN if kind == "prefill"
                       else energy.CYCLES_PER_DECODE_TOKEN)
            cycles = tokens * per_tok
            tau = cycles / hz
            tau_step = max(tau_step, tau)
            dyn = self._bank.active_dyn_uw_mhz * ds * cycles * 1e-6
            leak = self._bank.leak_uw * ls * tau
            hold = PAGE_RETENTION_UW * ls * page_share * tau
            if kind == "prefill":
                self.prefill_uj += dyn + leak
            else:
                self.decode_uj += dyn + leak
            self.pages_uj += hold
            if request is not None:
                request.energy_uj += dyn + leak + hold
        if not slot_charges:
            return
        # host CPU: a fixed slice of active dispatch work, then waiting on
        # the device — gated to leakage, or full ON duty-0 power when
        # clock gating is off
        self.host_uj += (self._cpu.active_dyn_uw_mhz * ds
                         * HOST_DISPATCH_CYCLES * 1e-6
                         + self._cpu.leak_uw * ls * HOST_DISPATCH_CYCLES / hz)
        if self.gate_idle_banks:
            cpu_wait_uw = self._cpu.leak_uw * ls
        else:
            cpu_wait_uw = (self._cpu.leak_uw * ls
                           + self._cpu.idle_dyn_uw_mhz * pt.freq_mhz * ds)
        self.host_uj += cpu_wait_uw * tau_step
        # banks with no occupied slot: same gating split
        if idle_banks > 0:
            if self.gate_idle_banks:
                per_bank = self._bank.leak_uw * ls * tau_step
            else:
                per_bank = (self._bank.leak_uw * ls
                            + self._bank.idle_dyn_uw_mhz * pt.freq_mhz
                            * ds) * tau_step
            self.idle_uj += idle_banks * per_bank
        return

    def tick(self, now: float, residents, idle_banks: int = 0) -> None:
        """Accrue clock-time retention since the last tick.

        ``residents`` is ``[(request, bank_weight, page_share)]`` for every
        occupied slot: banks in retention leak at ``RETENTION_LEAK_FACTOR``
        split by ``bank_weight`` across the slots sharing the bank, and held
        pages leak at :data:`PAGE_RETENTION_UW`. Idle banks accrue to the
        overhead bucket. Under the engine's default frozen clock ``dt`` is
        zero and this is a no-op; fake-clock simulations make it count.
        """
        if self._last_tick is None:
            self._last_tick = now
            return
        dt = now - self._last_tick
        self._last_tick = now
        if dt <= 0.0:
            return
        ls = self._point.leak_scale
        bank_ret = self._bank.leak_uw * RETENTION_LEAK_FACTOR * ls
        for request, bank_weight, page_share in residents:
            e = (bank_ret * bank_weight
                 + PAGE_RETENTION_UW * ls * page_share) * dt
            self.retention_uj += e
            if request is not None:
                request.energy_uj += e
        if idle_banks > 0:
            self.idle_uj += idle_banks * bank_ret * dt

    # -- reporting ----------------------------------------------------------

    def stats(self) -> dict:
        """Snapshot for ``engine.stats()['energy']`` — all µJ, all monotone."""
        return {
            "point": self._point.name,
            "total_uj": self.total_uj,
            "attributed_uj": self.attributed_uj,
            "overhead_uj": self.overhead_uj,
            "prefill_uj": self.prefill_uj,
            "decode_uj": self.decode_uj,
            "pages_uj": self.pages_uj,
            "retention_uj": self.retention_uj,
            "host_uj": self.host_uj,
            "idle_uj": self.idle_uj,
            "dvfs_switches": self.dvfs_switches,
            "projected_uj_per_token": self.projected_uj_per_token(),
        }
