"""Deterministic simulation harness for the serving-engine tests.

Thin test-facing layer over :mod:`repro.serve.sim`: everything here is
driven by a :class:`FakeClock` and scripted arrival traces, so every
assertion in ``test_engine.py`` is exactly reproducible — no wall clock,
no threads, no randomness outside fixed seeds.
"""

from __future__ import annotations

import jax

from repro import configs
from repro.core.platform import Platform, XHeepConfig
from repro.models import registry
from repro.serve.cluster import PowerBudget, ServeCluster
from repro.serve.engine import ContinuousBatchingEngine, Request
from repro.serve.sim import (Arrival, ClusterSimulator, FakeClock, SimReport,
                             Simulator, burst_trace, shared_prefix_requests,
                             staggered_trace, tag_engine)
from repro.sharding import params as P

__all__ = [
    "Arrival", "ClusterSimulator", "FakeClock", "PowerBudget", "ServeCluster",
    "SimReport", "Simulator", "add_smoke_engine", "burst_trace",
    "make_cluster", "shared_prefix_requests", "staggered_trace", "tag_engine",
    "Request", "make_engine", "make_requests", "run_trace", "smoke_params",
    "shared_prefix_reqs", "standalone_tokens", "tokens_of",
]

_PARAM_CACHE: dict[str, tuple] = {}

# Canonical device shapes for every test engine (set by the session-scoped
# ``shared_jit_cache`` fixture in conftest.py): padding lanes / cache
# positions up to one shared shape lets every engine test reuse a single
# compiled step function. ``None`` = no padding (engine uses its own shape).
CANONICAL: dict = {"lane_batch": None, "device_len": None}


def smoke_params(arch: str = "granite_3_2b", seed: int = 0):
    """(cfg, params) for a tiny CPU model; cached per arch across tests."""
    key = f"{arch}:{seed}"
    if key not in _PARAM_CACHE:
        cfg = configs.smoke(arch)
        params = P.init_tree(registry.decls(cfg), jax.random.key(seed))
        _PARAM_CACHE[key] = (cfg, params)
    return _PARAM_CACHE[key]


def make_engine(arch: str = "granite_3_2b", *, slots: int = 3,
                max_len: int = 32, clock: FakeClock | None = None,
                platform: Platform | None = None, n_banks: int | None = None,
                queue_capacity: int | None = None, **engine_kwargs):
    """A tiny engine on a fake clock. Returns (engine, clock).

    Extra keyword arguments (``prefill_chunk``, ``page_size``, ...) pass
    through to :class:`ContinuousBatchingEngine`.
    """
    cfg, params = smoke_params(arch)
    clock = clock or FakeClock()
    if platform is None and n_banks is not None:
        platform = Platform(XHeepConfig(n_banks=n_banks))
        for i in range(n_banks):        # the platform owner gates idle banks
            platform.power.clock_gate(f"bank{i}")
    engine_kwargs.setdefault("lane_batch", CANONICAL["lane_batch"])
    engine_kwargs.setdefault("device_len", CANONICAL["device_len"])
    eng = ContinuousBatchingEngine(cfg, params, slots=slots, max_len=max_len,
                                   clock=clock, platform=platform,
                                   queue_capacity=queue_capacity,
                                   **engine_kwargs)
    return eng, clock


def make_cluster(*, pool_pages: int = 48, page_size: int = 8,
                 clock: FakeClock | None = None, **cluster_kwargs):
    """A tiny multi-model cluster on a fake clock. Returns (cluster, clock).

    One canonical pool shape (48 pages of 8 tokens) across the cluster
    tests keeps every test on the same compiled paged step.
    """
    clock = clock or FakeClock()
    cluster = ServeCluster(pool_pages=pool_pages, page_size=page_size,
                           clock=clock, **cluster_kwargs)
    return cluster, clock


def add_smoke_engine(cluster: ServeCluster, arch: str = "granite_3_2b", *,
                     name: str, namespace: str | None = None, slots: int = 2,
                     max_len: int = 40, seed: int = 0, **engine_kwargs):
    """Add a smoke-model tenant with the canonical padded device shapes."""
    cfg, params = smoke_params(arch, seed)
    engine_kwargs.setdefault("lane_batch", CANONICAL["lane_batch"])
    engine_kwargs.setdefault("device_len", CANONICAL["device_len"])
    return cluster.add_engine(cfg, params, name=name, namespace=namespace,
                              slots=slots, max_len=max_len, **engine_kwargs)


def make_requests(n: int, *, prompt_len: int = 3, new_tokens: int = 4,
                  prefix: str = "r") -> list[Request]:
    """n deterministic requests with distinct prompts."""
    return [
        Request(id=f"{prefix}{i}",
                prompt=[(7 * i + j) % 251 + 1 for j in range(prompt_len)],
                max_new_tokens=new_tokens)
        for i in range(n)
    ]


def tokens_of(eng_or_report) -> dict:
    """``{request_id: token tuple}`` over the ``completed`` list of an
    engine, a cluster engine, or a ``SimReport`` — the comparison every
    bit-identity assertion in the suite is written against."""
    return {r.id: tuple(r.tokens) for r in eng_or_report.completed}


def shared_prefix_reqs(prefix: str, n: int = 4, *, prefix_len: int = 16,
                       tail_len: int = 3, new_tokens: int = 4):
    """``n`` requests sharing one prompt prefix (the prefix-cache workload),
    with ids ``{prefix}0..``."""
    return shared_prefix_requests(n, prefix_len=prefix_len, tail_len=tail_len,
                                  new_tokens=new_tokens, id_prefix=prefix)


def standalone_tokens(arch: str, reqs, *, seed: int = 0, trace=burst_trace,
                      slots: int = 2, max_len: int = 40, page_size: int = 8,
                      **engine_kwargs) -> dict:
    """Reference tokens: the same model serving the same trace alone, on
    its own private pool and table (the bit-identity baseline the cluster
    tests compare tenants against)."""
    cfg, params = smoke_params(arch, seed)
    clock = FakeClock()
    engine_kwargs.setdefault("lane_batch", CANONICAL["lane_batch"])
    engine_kwargs.setdefault("device_len", CANONICAL["device_len"])
    eng = ContinuousBatchingEngine(
        cfg, params, slots=slots, max_len=max_len, clock=clock,
        page_size=page_size, **engine_kwargs)
    Simulator(eng, trace(reqs), clock).run()
    return tokens_of(eng)


def run_trace(arch: str, trace, *, slots: int = 3, max_len: int = 32,
              sequential: bool = False, step_time: float = 1.0,
              queue_capacity: int | None = None, **engine_kwargs):
    """Build a fresh engine, run the trace to completion. (engine, report)."""
    eng, clock = make_engine(arch, slots=slots, max_len=max_len,
                             queue_capacity=queue_capacity, **engine_kwargs)
    sim = Simulator(eng, trace, clock, step_time=step_time,
                    sequential=sequential)
    return eng, sim.run()
