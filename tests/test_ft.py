"""Fault-tolerance controller: heartbeats, stragglers, rescale, backoff."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the in-repo seeded-random subset
    from repro.testing.hypo import given, settings, strategies as st

from repro.runtime.ft import FTConfig, FTController, WorkerState


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make(n=8, **kw):
    clock = FakeClock()
    ctl = FTController(n, FTConfig(**kw), clock=clock)
    return ctl, clock


def test_heartbeat_timeout_marks_dead():
    ctl, clock = make(4, heartbeat_timeout_s=10)
    clock.advance(5)
    for i in range(4):
        ctl.report_heartbeat(i)
    clock.advance(11)
    ctl.report_heartbeat(0)
    ctl.report_heartbeat(1)
    res = ctl.tick()
    assert sorted(res["dead"]) == [2, 3]
    assert ctl.healthy_workers() == [0, 1]


def test_dead_worker_can_rejoin():
    ctl, clock = make(2, heartbeat_timeout_s=1)
    clock.advance(2)
    ctl.tick()
    assert ctl.workers[0].state is WorkerState.DEAD
    ctl.report_heartbeat(0)
    assert ctl.workers[0].state is WorkerState.HEALTHY


def test_straggler_detection_needs_streak():
    ctl, clock = make(4, straggler_factor=1.5, straggler_streak=3)
    for step in range(4):
        for i in range(4):
            ctl.report_heartbeat(i)
            ctl.report_step_time(i, 1.0 if i else 2.5)  # worker 0 slow
        res = ctl.tick()
    assert 0 in res["stragglers"]
    assert ctl.workers[0].state is WorkerState.STRAGGLING
    # recovery clears the flag
    ctl.report_step_time(0, 1.0)
    for i in range(1, 4):
        ctl.report_step_time(i, 1.0)
    ctl.tick()
    assert ctl.workers[0].state is WorkerState.HEALTHY


def test_rescale_plan_shrinks_to_power_of_two():
    ctl, clock = make(512, heartbeat_timeout_s=1)
    # kill one pod's worth: 300 remain
    for i in range(300):
        ctl.report_heartbeat(i)
    clock.advance(2)
    for i in range(300):
        ctl.report_heartbeat(i)
    ctl.tick()
    plan = ctl.rescale_plan((2, 16, 16), axis=0)
    assert plan == (1, 16, 16)  # 256 <= 300 healthy


def test_rescale_none_when_full():
    ctl, _ = make(512)
    assert ctl.rescale_plan((2, 16, 16)) is None


@settings(max_examples=100, deadline=None)
@given(n_workers=st.integers(1, 32), n_mb=st.integers(1, 256),
       slow=st.lists(st.integers(0, 31), max_size=8))
def test_microbatch_shares_conserve_work(n_workers, n_mb, slow):
    ctl, _ = make(n_workers)
    for s in slow:
        if s < n_workers:
            ctl.workers[s].state = WorkerState.STRAGGLING
    shares = ctl.microbatch_shares(n_mb)
    assert sum(shares.values()) == n_mb          # nothing dropped
    if any(s < n_workers for s in slow) and n_workers > 1:
        healthy = [shares[i] for i, w in ctl.workers.items()
                   if w.state is WorkerState.HEALTHY]
        straggling = [shares[i] for i, w in ctl.workers.items()
                      if w.state is WorkerState.STRAGGLING]
        if healthy and straggling and n_mb >= n_workers * 2:
            assert max(straggling) <= max(healthy)  # stragglers never loaded more


def test_restart_backoff_doubles_then_exhausts():
    ctl, _ = make(1, max_restarts=3, backoff_base_s=2.0)
    assert ctl.restart_delay() == 2.0
    assert ctl.restart_delay() == 4.0
    assert ctl.restart_delay() == 8.0
    assert ctl.restart_delay() is None


def test_add_worker_and_report_failure():
    ctl, clock = make(0)
    a, b = ctl.add_worker(), ctl.add_worker()
    assert (a, b) == (0, 1)
    ctl.report_failure(a, reason="engine crash")
    assert ctl.workers[a].state is WorkerState.DEAD
    assert ctl.healthy_workers() == [b]
    assert any("engine crash" in msg for _, msg in ctl.events)
    # a second failure report is idempotent (one event, not two)
    ctl.report_failure(a, reason="engine crash")
    assert sum("declared dead" in m for _, m in ctl.events) == 1
    # heartbeat rejoins, exactly like a timeout death
    ctl.report_heartbeat(a)
    assert ctl.workers[a].state is WorkerState.HEALTHY
    assert sorted(ctl.healthy_workers()) == [a, b]


def test_add_worker_ids_continue_after_static_init():
    ctl, _ = make(3)
    assert ctl.add_worker() == 3
    assert ctl.add_worker() == 4


# ---------------------------------------------------------------------------
# RequestJournal retention horizon
# ---------------------------------------------------------------------------


def _filled_journal(n_done=5, n_inflight=2, horizon=None):
    from repro.runtime.ft import RequestJournal

    j = RequestJournal(horizon=horizon)
    for i in range(n_done):
        j.open(f"d{i}", [1, 2, 3], 4)
        j.record_token(f"d{i}", 10 + i)
        j.complete(f"d{i}")
    for i in range(n_inflight):
        j.open(f"f{i}", [1, 2, 3], 4)
    return j


def test_journal_horizon_evicts_oldest_completed_only():
    j = _filled_journal(n_done=5, n_inflight=2, horizon=2)
    s = j.size()
    # only the 2 newest completed records survive; in-flight all survive
    assert s["records"] == 2 + 2 and s["in_flight"] == 2
    assert s["auto_evicted"] == 3 and s["horizon"] == 2
    assert not j.has("d0") and not j.has("d1") and not j.has("d2")
    assert j.has("d3") and j.has("d4")
    assert [r.request_id for r in j.incomplete()] == ["f0", "f1"]


def test_journal_unbounded_without_horizon():
    j = _filled_journal(n_done=5, n_inflight=2, horizon=None)
    s = j.size()
    assert s["records"] == 7 and s["auto_evicted"] == 0
    assert s["tokens"] == 7 * 3 + 5        # prompts + one token per done
    assert s["approx_bytes"] == 400 * 7 + 28 * s["tokens"]


def test_journal_evict_forgiving_after_horizon():
    j = _filled_journal(n_done=3, n_inflight=1, horizon=1)
    j.evict("d0")                          # horizon got there first: no-op
    j.evict("d2")                          # still retained: explicit drop
    assert not j.has("d2")
    with pytest.raises(ValueError, match="in flight"):
        j.evict("f0")                      # never evict replay state
    assert j.has("f0")


def test_journal_horizon_validation():
    from repro.runtime.ft import RequestJournal

    with pytest.raises(ValueError, match="horizon"):
        RequestJournal(horizon=-1)


def test_cluster_journal_propagates_horizon():
    from repro.runtime.ft import ClusterJournal

    cj = ClusterJournal(horizon=1)
    for eng in ("a", "b"):
        j = cj.journal(eng)
        assert j.horizon == 1
        for i in range(3):
            j.open(f"{eng}{i}", [1], 1)
            j.complete(f"{eng}{i}")
    assert cj.journal("a").size()["records"] == 1
    assert cj.journal("b").size()["auto_evicted"] == 2
