"""Optimizers: convergence, state structure, sharding-axes derivation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sharding import axes as lx
from repro.sharding.params import Axes
from repro.train import optim as optim_lib


def quadratic_problem(seed=0):
    k = jax.random.key(seed)
    target = jax.random.normal(k, (16, 8))
    params = {"w": jnp.zeros((16, 8), jnp.bfloat16)}

    def grad_fn(p):
        return {"w": (p["w"].astype(jnp.float32) - target).astype(jnp.bfloat16)}

    def loss(p):
        return float(jnp.mean((p["w"].astype(jnp.float32) - target) ** 2))

    return params, grad_fn, loss


@pytest.mark.parametrize("name,lr,steps", [("adamw", 0.05, 300),
                                           ("adafactor", 0.1, 600),
                                           ("lion", 0.02, 300)])
def test_optimizer_converges_on_quadratic(name, lr, steps):
    opt = optim_lib.get(name, weight_decay=0.0)
    params, grad_fn, loss = quadratic_problem()
    state = opt.init(params)
    l0 = loss(params)
    for _ in range(steps):
        params, state, _ = opt.update(grad_fn(params), state, params,
                                      jnp.asarray(lr))
    assert loss(params) < 0.05 * l0


@pytest.mark.parametrize("name", ["adamw", "adafactor", "lion"])
def test_state_structure_stable_across_updates(name):
    opt = optim_lib.get(name)
    params, grad_fn, _ = quadratic_problem()
    state = opt.init(params)
    td0 = jax.tree.structure(state)
    _, state2, _ = opt.update(grad_fn(params), state, params, jnp.asarray(1e-3))
    assert jax.tree.structure(state2) == td0  # donation-safe


def test_adamw_matches_reference_math():
    # single scalar, closed-form first step
    opt = optim_lib.get("adamw", b1=0.9, b2=0.99, eps=0.0,
                        weight_decay=0.0, clip=0.0)
    p = {"w": jnp.asarray([2.0], jnp.float32)}
    g = {"w": jnp.asarray([0.5], jnp.float32)}
    st = opt.init(p)
    p2, st2, _ = opt.update(g, st, p, jnp.asarray(0.1))
    # first step: m/ (1-b1) = g; sqrt(v/(1-b2)) = |g| -> update = sign(g)*lr
    np.testing.assert_allclose(np.asarray(p2["w"]), [2.0 - 0.1], atol=1e-6)


def test_adafactor_is_factored():
    opt = optim_lib.get("adafactor")
    params = {"w": jnp.zeros((32, 64)), "b": jnp.zeros((7,))}
    st = opt.init(params)
    assert st["vr"]["w"].shape == (32,)
    assert st["vc"]["w"].shape == (64,)
    assert st["vr"]["b"].shape == (7,)   # vectors not factored


@pytest.mark.parametrize("name", ["adamw", "adafactor", "lion"])
def test_axes_tree_matches_state_structure(name):
    opt = optim_lib.get(name)
    params = {"w": jnp.zeros((32, 64)), "b": jnp.zeros((7,))}
    p_axes = {"w": Axes(lx.EMBED, lx.MLP), "b": Axes(lx.EMBED)}
    st = opt.init(params)
    ax = opt.axes(p_axes)
    assert jax.tree.structure(st, is_leaf=lambda x: isinstance(x, Axes)).num_leaves \
        == jax.tree.structure(ax, is_leaf=lambda x: isinstance(x, Axes)).num_leaves
    if name == "adafactor":
        assert tuple(ax["vr"]["w"]) == (lx.EMBED,)
        assert tuple(ax["vc"]["w"]) == (lx.MLP,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = optim_lib.clip_by_global_norm(g, 1.0)
    total = float(optim_lib.global_norm(clipped))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(norm), np.sqrt(90 + 160), rtol=1e-5)


def test_gradient_compression_error_feedback():
    """Quantization error must not accumulate: with error feedback the mean
    of compressed updates converges to the true gradient."""
    from repro.train.compress import dequantize, quantize

    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    steps = 200
    for _ in range(steps):
        corrected = g_true + err
        q, s = quantize(corrected)
        sent = dequantize(q, s)
        err = corrected - sent
        acc = acc + sent
    mean_sent = acc / steps
    np.testing.assert_allclose(np.asarray(mean_sent), np.asarray(g_true),
                               atol=5e-6)
