"""Per-architecture smoke tests + serving consistency (decode == forward)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.sharding import params as P


def _init(cfg, seed=0):
    return P.init_tree(registry.decls(cfg), jax.random.key(seed))


def _inputs(cfg, b, s, seed=1):
    key = jax.random.key(seed)
    if cfg.embed_inputs:
        return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    return {"embeds": jax.random.normal(key, (b, s, cfg.d_model), jnp.bfloat16)}


# fast tier keeps one arch per family (+MoE); the long tail of exotic
# configs runs in the full tier
_FAST_FORWARD = {"granite_3_2b", "mamba2_370m", "recurrentgemma_2b",
                 "grok_1_314b"}


@pytest.mark.parametrize(
    "arch", [a if a in _FAST_FORWARD else pytest.param(a, marks=pytest.mark.slow)
             for a in configs.names()])
def test_smoke_forward_shapes_finite(arch):
    cfg = configs.smoke(arch)
    params = _init(cfg)
    b, s = 2, 32
    logits, aux = registry.forward(params, cfg, **_inputs(cfg, b, s))
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.slow   # builds a sharded train step per architecture
@pytest.mark.parametrize("arch", configs.names())
def test_smoke_train_step_no_nans(arch):
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import rules as R
    from repro.train.trainer import TrainConfig, build_sharded_train

    cfg = configs.smoke(arch)
    mesh = make_host_mesh()
    rules = R.fully_connected(mesh)
    tc = TrainConfig(optimizer="adamw", accum=2, lr=1e-3)
    st = build_sharded_train(cfg, tc, mesh, rules, global_batch=4, seq=32)
    params = P.cast_tree(_init(cfg), jnp.bfloat16)
    from repro.train import optim as optim_lib

    opt = optim_lib.get("adamw").init(params)
    key = jax.random.key(3)
    batch = {"labels": jax.random.randint(key, (2, 2, 32), 0, cfg.vocab)}
    if cfg.embed_inputs:
        batch["tokens"] = jax.random.randint(key, (2, 2, 32), 0, cfg.vocab)
    else:
        batch["embeds"] = jax.random.normal(key, (2, 2, 32, cfg.d_model),
                                            jnp.bfloat16)
    before = jax.tree.map(lambda x: np.asarray(x, np.float32), params)
    with mesh:
        params2, opt2, metrics = st.step_fn(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed (params itself was donated)
    after = jax.tree.map(lambda x: np.asarray(x, np.float32), params2)
    delta = sum(float(np.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(after), jax.tree.leaves(before)))
    assert delta > 0.0


# decode-vs-forward consistency: greedy decode logits must match the
# training forward at the same positions (teacher forcing).
@pytest.mark.slow   # token-by-token decode sweep across five architectures
@pytest.mark.parametrize("arch", ["granite_3_2b", "mamba2_370m",
                                  "recurrentgemma_2b", "h2o_danube3_4b",
                                  "grok_1_314b"])
def test_decode_matches_forward(arch):
    import dataclasses

    cfg = configs.smoke(arch)
    if cfg.moe_experts:
        # GShard capacity drops differ between batched forward and one-token
        # decode; use a no-drop capacity so the equality is exact semantics.
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = _init(cfg)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.key(5), (b, s), 0, cfg.vocab)
    full_logits, _ = registry.forward(params, cfg, tokens=toks)

    cache = registry.cache_init(cfg, b, max_len=s)
    errs = []
    for i in range(s):
        logits, cache = registry.decode_step(params, cfg, cache, toks[:, i:i + 1])
        errs.append(float(jnp.abs(
            logits.astype(jnp.float32)
            - full_logits[:, i].astype(jnp.float32)).max()))
    assert max(errs) < 0.15, errs  # bf16 accumulation tolerance


@pytest.mark.slow   # prefill + decode consistency sweep
@pytest.mark.parametrize("arch", ["granite_3_2b", "mamba2_370m",
                                  "recurrentgemma_2b"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = configs.smoke(arch)
    params = _init(cfg)
    b, s, extra = 2, 16, 4
    toks = jax.random.randint(jax.random.key(6), (b, s + extra), 0, cfg.vocab)
    full_logits, _ = registry.forward(params, cfg, tokens=toks)

    logits, cache = registry.prefill(params, cfg, tokens=toks[:, :s],
                                     max_len=s + extra)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full_logits[:, s - 1], np.float32),
                               atol=0.15)
    for i in range(extra):
        logits, cache = registry.decode_step(params, cfg, cache,
                                             toks[:, s + i:s + i + 1])
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, s + i], np.float32), atol=0.15)


def test_sliding_window_cache_is_bounded():
    cfg = configs.smoke("h2o_danube3_4b")  # window 16
    from repro.models.transformer import KVCache

    cache = KVCache.abstract(cfg, batch=2, max_len=500_000)
    assert cache.k.shape[2] == cfg.sliding_window  # ring buffer, not 500k


def test_long_context_eligibility_flags():
    assert configs.get("mamba2_370m").is_subquadratic
    assert configs.get("recurrentgemma_2b").is_subquadratic
    assert configs.get("h2o_danube3_4b").is_subquadratic
    assert not configs.get("stablelm_3b").is_subquadratic
    assert not configs.get("grok_1_314b").is_subquadratic


def test_param_counts_match_published():
    expect = {
        "h2o_danube3_4b": (3.96e9, 0.08),
        "stablelm_3b": (2.8e9, 0.15),
        "granite_3_2b": (2.5e9, 0.10),
        "nemotron_4_15b": (15.6e9, 0.08),
        "musicgen_large": (2.4e9, 0.20),
        "internvl2_76b": (70.5e9, 0.10),
        "grok_1_314b": (314e9, 0.05),
        "llama4_maverick_400b": (400e9, 0.05),
        "mamba2_370m": (0.37e9, 0.10),
        "recurrentgemma_2b": (2.7e9, 0.10),
    }
    for arch, (want, tol) in expect.items():
        got = configs.get(arch).param_count()
        assert abs(got - want) / want < tol, (arch, got, want)


def test_moe_capacity_dispatch_matches_dense_ref():
    from repro.models import layers as L
    from repro.sharding.params import init_tree

    d, f, e, k = 32, 64, 4, 2
    decls = L.moe_decls(d, f, e)
    p = init_tree(decls, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, d))
    # capacity factor 4.0 => nothing dropped => must equal the dense oracle
    out, aux = L.moe(x, p, n_exp=e, top_k=k, capacity_factor=4.0)
    want = L.moe_dense_ref(x, p, n_exp=e, top_k=k)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=1e-4)
    assert 0.5 < float(aux) < 4.0  # load-balance loss near E*(1/E)*1 = 1
