"""End-to-end system behaviour: training convergence, restart reproducibility,
serving engine, data pipelines, healthcare apps on the platform."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.biosignal import (HEARTBEAT_ECG, SEIZURE_EEG, AcquisitionSim,
                                  ecg_window, eeg_window)
from repro.data.lm import LMDataConfig, LMPipeline


@pytest.mark.slow   # 300 optimizer steps
def test_training_loss_decreases():
    from repro.launch import train as train_mod

    loss = train_mod.main([
        "--arch", "granite-3-2b", "--smoke", "--steps", "300",
        "--global-batch", "8", "--seq", "64", "--accum", "2",
        "--lr", "1e-2",
    ])
    # ln(256)=5.55 unigram floor; the stream's bigram structure is learnable
    assert loss < 5.35, loss


@pytest.mark.slow   # two full training runs + checkpoint restore
def test_restart_is_bit_identical(tmp_path):
    from repro.launch import train as train_mod

    ck = str(tmp_path / "ck")
    # run A: 8 steps, checkpoint at step 5 only (the end is NOT checkpointed)
    l1 = train_mod.main([
        "--arch", "stablelm-3b", "--smoke", "--steps", "8",
        "--global-batch", "4", "--seq", "32", "--accum", "2",
        "--ckpt", ck, "--ckpt-every", "5",
    ])
    # run B: restore at 5, recompute steps 5..7 -> must land on the same loss
    l2 = train_mod.main([
        "--arch", "stablelm-3b", "--smoke", "--steps", "8",
        "--global-batch", "4", "--seq", "32", "--accum", "2",
        "--ckpt", ck, "--resume",
    ])
    assert l1 == l2  # exact: step-indexed data + deterministic compute


def test_serve_driver_reports_throughput():
    from repro.launch import serve as serve_mod

    tps = serve_mod.main(["--arch", "mamba2-370m", "--smoke", "--batch", "2",
                          "--prompt-len", "8", "--steps", "4"])
    assert tps > 0


def test_lm_pipeline_deterministic_and_step_indexed():
    cfg = LMDataConfig(vocab=256, seq=16, global_batch=4, accum=2)
    p = LMPipeline(cfg)
    b1, b2 = p.batch_at(3), p.batch_at(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p.batch_at(4)
    assert np.abs(np.asarray(b3["tokens"]) - np.asarray(b1["tokens"])).sum() > 0
    assert b1["tokens"].shape == (2, 2, 16)
    assert int(b1["tokens"].max()) < 256


def test_lm_pipeline_modality_stub_embeds():
    cfg = LMDataConfig(vocab=2048, seq=8, global_batch=2, embed_dim=32)
    b = LMPipeline(cfg).batch_at(0)
    assert "embeds" in b and b["embeds"].shape == (1, 2, 8, 32)
    assert b["embeds"].dtype == jnp.bfloat16


# -- healthcare pipeline (the paper's application domain) ---------------------

def test_acquisition_specs_match_paper_table2():
    assert HEARTBEAT_ECG.leads == 3
    assert HEARTBEAT_ECG.samples_per_window == 15 * 256
    assert abs(HEARTBEAT_ECG.window_bytes - 22.5 * 1024) < 1
    assert SEIZURE_EEG.leads == 23
    assert abs(SEIZURE_EEG.window_bytes - 46 * 1024) < 1024


def test_bank_gating_from_acquisition():
    sim = AcquisitionSim(HEARTBEAT_ECG, n_banks=8)
    states = sim.bank_states()
    assert sum(states) == HEARTBEAT_ECG.banks_needed == 1
    sim2 = AcquisitionSim(SEIZURE_EEG, n_banks=8)
    assert sum(sim2.bank_states()) == 2


def test_signal_generators_shapes_and_range():
    e = ecg_window(HEARTBEAT_ECG, seed=1)
    assert e.shape == (3, 3840) and e.dtype == np.int16
    g = eeg_window(SEIZURE_EEG, seed=1, seizure=True)
    assert g.shape == (23, 1024)
    # seizure windows have higher amplitude (spike-wave discharge)
    g0 = eeg_window(SEIZURE_EEG, seed=1, seizure=False)
    assert np.abs(g.astype(np.float32)).mean() > np.abs(g0.astype(np.float32)).mean()


def test_healthcare_cnn_on_cgra_plugin():
    """The paper's seizure CNN conv layers, dispatched through XAIF to the
    conv1d 'CGRA' kernel, must match the host (ref) path."""
    import repro.kernels  # noqa: F401
    from repro.core.xaif import REGISTRY
    from repro.kernels.conv1d import ref as conv_ref

    x = jnp.asarray(eeg_window(SEIZURE_EEG, seed=0), jnp.float32).T[None] / 32768
    x = x[:, :1024, :16]  # (1, S, 16 channels)
    w = jax.random.normal(jax.random.key(0), (4, 16)) * 0.2
    host = conv_ref.conv1d(x, w)
    cgra = REGISTRY.dispatch("conv1d", "pallas", x, w)
    np.testing.assert_allclose(np.asarray(cgra), np.asarray(host), atol=1e-5)
