"""Open-loop traffic, SLO-aware scheduling, and the simulator fixes.

The load-generator/metrics layer is pure Python and tested exactly; the
cluster-level tests drive the tiny smoke models through the deterministic
sim harness. The invariants:

* **Trace determinism** — every arrival process and the full
  ``open_loop_trace`` stream are bit-identical for a fixed seed, lazily
  generated, and time-ordered.
* **Run determinism** — two fresh same-seed open-loop runs under the full
  SLO-aware policy (DRR + shed + preempt) produce identical reports and
  identical per-request tokens.
* **Open-loop overload** — offered load beyond capacity builds queues and
  rejections but the run still drains; tail TTFT reflects the backlog.
* **SLO preempt-and-requeue is bit-identical** — a deadline-busted slot is
  demoted, journaled, replayed, and finishes with exactly the tokens an
  undisturbed run produces.
* **Carried simulator fixes** — cross-engine cold-prefill dedup (the
  table-level claim registry), eager window recycling (no dead ring pages
  held between steps), per-engine async pipelines in the cluster cost
  model, and construction-time trace validation.
"""

import dataclasses
import itertools

import pytest

from engine_sim import (CANONICAL, Arrival, ClusterSimulator, FakeClock,
                        Request, Simulator, add_smoke_engine, burst_trace,
                        make_cluster, make_engine, make_requests,
                        smoke_params, staggered_trace, tag_engine)
from repro.serve.cluster import SchedPolicy
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.loadgen import (TenantSpec, bursty_times, diurnal_times,
                                 open_loop_trace, poisson_times)
from repro.serve.metrics import SLO, ServeMetrics, met_slo, percentile


from engine_sim import tokens_of as _tokens  # shared across the suites


# ---------------------------------------------------------------------------
# load generator


@pytest.mark.parametrize("make", [
    lambda s: poisson_times(5.0, seed=s),
    lambda s: bursty_times(5.0, seed=s, burst=4),
    lambda s: diurnal_times(5.0, seed=s, period=50.0, amplitude=0.5),
], ids=["poisson", "bursty", "diurnal"])
def test_arrival_processes_deterministic_and_ordered(make):
    """Same seed ⇒ bit-identical times; different seed ⇒ different times;
    the stream is nondecreasing and lazily infinite."""
    a = list(itertools.islice(make(7), 400))
    b = list(itertools.islice(make(7), 400))
    c = list(itertools.islice(make(8), 400))
    assert a == b
    assert a != c
    assert all(t1 <= t2 for t1, t2 in zip(a, a[1:]))
    assert a[0] >= 0.0


def test_bursty_times_spike_but_keep_the_mean_rate():
    """Bursts place many arrivals at the same instant, while the long-run
    mean rate stays near the requested aggregate rate."""
    ts = list(itertools.islice(bursty_times(10.0, seed=3, burst=6), 3000))
    biggest_tie = max(len(list(g)) for _, g in itertools.groupby(ts))
    assert biggest_tie > 1                      # same-instant releases
    mean_rate = len(ts) / (ts[-1] - ts[0])
    assert 7.0 < mean_rate < 13.0               # ~10/s, huge-sample-loose


def test_open_loop_trace_deterministic_lazy_and_mixed():
    tenants = [
        TenantSpec(engine="a", share=3.0, prompt_len=(6, 12),
                   prefix_len=4, prefix_seed=5, slo=SLO(ttft=10.0)),
        TenantSpec(engine="b", share=1.0, prompt_len=(4, 8)),
    ]

    def digest(n):
        return [(a.time, a.engine, a.request.id, tuple(a.request.prompt),
                 a.request.max_new_tokens, a.request.slo)
                for a in open_loop_trace(tenants, n_requests=n, rate=20.0,
                                         seed=11)]

    full = digest(500)
    assert full == digest(500)                  # same seed ⇒ bit-identical
    # lazy: the head of a 10^6-request trace is cheap, and prefix-stable
    head = list(itertools.islice(
        open_loop_trace(tenants, n_requests=10**6, rate=20.0, seed=11), 5))
    assert [(a.time, a.request.id) for a in head] == \
        [(t, rid) for t, _, rid, *_ in full[:5]]
    assert all(t1 <= t2 for (t1, *_), (t2, *_) in zip(full, full[1:]))
    engines = [e for _, e, *_ in full]
    assert set(engines) == {"a", "b"}
    assert engines.count("a") > engines.count("b")      # ~3:1 share
    # tenant a's requests carry its SLO and its shared prefix
    pfx = tenants[0].prefix_tokens()
    for _, eng, _, prompt, _, slo in full:
        if eng == "a":
            assert slo == SLO(ttft=10.0)
            assert prompt[:4] == pfx
            assert len(prompt) >= 5              # final token always fresh
        else:
            assert slo is None


def test_open_loop_trace_validates_inputs():
    good = [TenantSpec(engine="a")]
    with pytest.raises(ValueError, match="at least one TenantSpec"):
        next(open_loop_trace([], n_requests=1, rate=1.0))
    with pytest.raises(ValueError, match="arrival process"):
        next(open_loop_trace(good, n_requests=1, rate=1.0, process="uniform"))
    with pytest.raises(ValueError, match="share"):
        TenantSpec(engine="a", share=0.0)
    with pytest.raises(ValueError, match="prompt_len"):
        TenantSpec(engine="a", prompt_len=(0, 4))
    with pytest.raises(ValueError, match="rate"):
        next(poisson_times(0.0, seed=1))


# ---------------------------------------------------------------------------
# metrics


def test_percentile_is_exact_nearest_rank():
    xs = [4.0, 1.0, 3.0, 2.0]
    assert percentile(xs, 50) == 2.0
    assert percentile(xs, 75) == 3.0
    assert percentile(xs, 99) == 4.0            # an actually-observed value
    assert percentile([5.0], 50) == 5.0
    with pytest.raises(ValueError):
        percentile([], 99)
    with pytest.raises(ValueError):
        percentile(xs, 0)


def test_slo_deadline():
    assert SLO(ttft=4.0, tpot=2.0).deadline(10.0, 5) == 10.0 + 4.0 + 2.0 * 4
    assert SLO(ttft=4.0).deadline(10.0, 5) == 14.0
    assert SLO(tpot=2.0).deadline(10.0, 1) == 10.0
    assert SLO().deadline(10.0, 5) == float("inf")
    with pytest.raises(ValueError):
        SLO(ttft=0.0)


def _stamped(rid, *, arrival, first, finish, n_tokens, slo=None):
    req = Request(id=rid, prompt=[1, 2], max_new_tokens=n_tokens, slo=slo)
    req.tokens = list(range(1, n_tokens + 1))
    req.arrival_time = arrival
    req.first_token_time = first
    req.finish_time = finish
    return req


def test_metrics_summary_over_hand_stamped_requests():
    slo = SLO(ttft=4.0, tpot=2.0)
    hit = _stamped("hit", arrival=0.0, first=3.0, finish=7.0, n_tokens=3,
                   slo=slo)                      # ttft 3 ≤ 4, tpot 2 ≤ 2
    miss = _stamped("miss", arrival=0.0, first=9.0, finish=11.0, n_tokens=2,
                    slo=slo)                     # ttft 9 > 4
    free = _stamped("free", arrival=0.0, first=50.0, finish=51.0, n_tokens=2)
    assert met_slo(hit) and not met_slo(miss) and met_slo(free)

    m = ServeMetrics()
    m.observe_all([hit, miss, free])
    s = m.summary(elapsed=10.0)
    assert s["completed"] == 3
    assert s["slo_requests"] == 2               # `free` carries no SLO
    assert s["slo_attainment"] == 0.5
    assert s["good_tokens"] == 3 + 2            # hit + no-SLO free
    assert s["total_tokens"] == 7
    assert s["ttft_p50"] == 9.0 and s["ttft_p99"] == 50.0
    assert s["goodput"] == 0.5 and s["throughput"] == 0.7
    single = ServeMetrics()
    single.observe(_stamped("one", arrival=0.0, first=1.0, finish=1.0,
                            n_tokens=1))
    assert single.summary()["tpot_p50"] == 0.0  # single-token output


# ---------------------------------------------------------------------------
# open-loop cluster runs

SLO_POLICY = SchedPolicy(scheduler="drr", shed_busted=True,
                         preempt_busted=True)


def _open_loop_run(policy, *, n=160, rate=40.0, seed=5):
    """One fresh overloaded 2-replica cluster driven by a seeded bursty
    open-loop trace. Returns (report, cluster, tokens-by-request-id)."""
    cluster, clock = make_cluster(pool_pages=64, page_size=8, policy=policy)
    for name in ("rep-a", "rep-b"):
        add_smoke_engine(cluster, name=name, namespace="granite", slots=2,
                         max_len=40, queue_capacity=6, prefill_chunk=4)
    tenants = [
        TenantSpec(engine=name, prompt_len=(4, 10), new_tokens=(3, 6),
                   prefix_len=4, prefix_seed=2, slo=SLO(ttft=12.0, tpot=4.0))
        for name in ("rep-a", "rep-b")
    ]
    trace = open_loop_trace(tenants, n_requests=n, rate=rate, seed=seed,
                            process="bursty", burst=4)
    rep = ClusterSimulator(cluster, trace, clock).run()
    toks = {}
    for eng in cluster.engines.values():
        toks.update(_tokens(eng))
    return rep, cluster, toks


def _digest(rep, cluster, toks):
    return (rep.elapsed, rep.steps, rep.tokens_generated, rep.rejected,
            rep.shed, cluster.sheds, cluster.slo_preempts,
            sorted(toks), sorted(toks.items()))


def test_open_loop_same_seed_runs_are_bit_identical():
    """Two fresh same-seed runs under the full SLO-aware policy: identical
    report, identical shed/preempt counters, identical tokens."""
    first = _open_loop_run(SLO_POLICY)
    second = _open_loop_run(SLO_POLICY)
    assert _digest(*first) == _digest(*second)
    rep, cluster, toks = first
    assert toks                                  # something actually served
    assert rep.rejected > 0                      # offered load > capacity


def test_open_loop_overload_builds_queues_then_drains():
    """Flat WRR under the same overload: no shedding, heavy backpressure,
    a fully drained cluster at the end, and tail TTFT that reflects the
    backlog (the queue-growth symptom open-loop traffic exposes)."""
    rep, cluster, toks = _open_loop_run(SchedPolicy())
    assert rep.shed == 0 and cluster.sheds == 0
    assert rep.rejected > len(toks)              # most arrivals bounced
    for eng in cluster.engines.values():
        assert not eng.busy                      # drained, not deadlocked
    m = ServeMetrics()
    for eng in cluster.engines.values():
        m.observe_all(eng.completed)
    s = m.summary(elapsed=rep.elapsed)
    # served + queued-then-served requests: the p99 waiter sat behind a
    # full queue, far beyond any single request's own service time
    assert s["ttft_p99"] > 3 * s["ttft_p50"] or s["ttft_p99"] > 12.0
    assert 0.0 <= s["slo_attainment"] <= 1.0


def test_slo_policy_sheds_and_beats_flat_wrr_on_goodput():
    """The headline comparison at test scale: under identical offered
    load the SLO-aware policy sheds doomed work and converts a larger
    share of its tokens into SLO-met (good) tokens."""

    def goodput(policy):
        rep, cluster, _ = _open_loop_run(policy, n=240, rate=60.0)
        m = ServeMetrics()
        for eng in cluster.engines.values():
            m.observe_all(eng.completed)
        return rep, cluster, m.summary(elapsed=rep.elapsed)

    slo_rep, slo_cluster, slo_sum = goodput(SLO_POLICY)
    flat_rep, _, flat_sum = goodput(SchedPolicy())
    assert slo_rep.shed > 0 and slo_cluster.sheds == slo_rep.shed
    assert slo_sum["slo_attainment"] > flat_sum["slo_attainment"]
    assert slo_sum["goodput"] > flat_sum["goodput"]


def test_slo_preempt_and_requeue_is_bit_identical():
    """A deadline-busted decode is demoted to the back of the queue,
    journaled, replayed after the followers, and still produces exactly
    the tokens an undisturbed solo run produces."""
    cluster, clock = make_cluster(
        pool_pages=48, page_size=8,
        policy=SchedPolicy(preempt_busted=True))
    eng = add_smoke_engine(cluster, name="g", namespace="granite", slots=1,
                           max_len=40)
    doomed = Request(id="long", prompt=[3, 4, 5], max_new_tokens=16,
                     slo=SLO(ttft=4.0, tpot=0.5))   # deadline = 11.5
    followers = make_requests(2, prompt_len=3, new_tokens=4, prefix="f")
    trace = tag_engine(burst_trace([doomed] + followers), "g")
    ClusterSimulator(cluster, trace, clock).run()

    assert cluster.slo_preempts == 1
    assert doomed.slo_preempts == 1
    assert cluster.journal.journal("g").get("long").slo_preempts == 1
    # followers finished before the demoted request was replayed
    order = [r.id for r in eng.completed]
    assert order.index("long") > order.index("f0")

    iso, iclock = make_engine(slots=1, max_len=40)
    Simulator(iso, burst_trace(
        [Request(id="long", prompt=[3, 4, 5], max_new_tokens=16)]
        + make_requests(2, prompt_len=3, new_tokens=4, prefix="f")),
        iclock).run()
    assert _tokens(eng) == _tokens(iso)


# ---------------------------------------------------------------------------
# carried simulator fixes


def test_cold_prefill_dedup_across_engines():
    """Two same-namespace replicas fed the same cold prompt in one burst:
    the table-level claim registry makes the second replica *stall* on the
    first one's in-flight pages instead of recomputing them, then adopt
    them — the whole point of claims spanning engines."""
    cluster, clock = make_cluster(pool_pages=48, page_size=8)
    ea = add_smoke_engine(cluster, name="x", namespace="granite", slots=1,
                          max_len=40, prefill_chunk=4)
    eb = add_smoke_engine(cluster, name="y", namespace="granite", slots=1,
                          max_len=40, prefill_chunk=4)
    prompt = [(13 * j) % 241 + 1 for j in range(17)]     # 2 pages + tail
    trace = (tag_engine(burst_trace(
        [Request(id="xa", prompt=prompt, max_new_tokens=4)]), "x")
        + tag_engine(burst_trace(
            [Request(id="yb", prompt=prompt, max_new_tokens=4)]), "y"))
    ClusterSimulator(cluster, trace, clock).run()

    assert ea.stalls + eb.stalls > 0             # waited, didn't recompute
    total = ea.prompt_tokens_processed + eb.prompt_tokens_processed
    assert total < 2 * len(prompt)               # shared pages filled once
    assert ea.prompt_tokens_reused + eb.prompt_tokens_reused >= 8

    iso, iclock = make_engine(slots=1, max_len=40, prefill_chunk=4)
    Simulator(iso, burst_trace(
        [Request(id="xa", prompt=list(prompt), max_new_tokens=4)]),
        iclock).run()
    ref = _tokens(iso)["xa"]
    assert _tokens(ea)["xa"] == ref and _tokens(eb)["yb"] == ref


def test_eager_window_recycling_holds_no_dead_pages():
    """After *every* step, no slot of a windowed engine holds a ring page
    wholly below its window (the lazy scheme held them until the ring
    wrapped); the dead page is back in the pool at the boundary crossing."""
    window = 8
    cfg0, params = smoke_params("granite_3_2b")
    cfg = dataclasses.replace(cfg0, name=f"{cfg0.name}-swa{window}-eager",
                              sliding_window=window)
    eng = ContinuousBatchingEngine(
        cfg, params, slots=1, max_len=36, clock=FakeClock(), page_size=8,
        lane_batch=CANONICAL["lane_batch"], device_len=CANONICAL["device_len"])
    eng.submit(Request(id="w0", prompt=[(11 * j) % 239 + 1 for j in range(10)],
                       max_new_tokens=20))        # 30 positions, 4 blocks
    ps = eng._ps
    while eng.busy:
        eng.step()
        for slot in eng.slots:
            if slot is None or not slot.pages_by_block:
                continue
            first_needed = max(0, slot.fed + 1 - window) // ps
            dead = [b for b in slot.pages_by_block if b < first_needed]
            assert not dead, (f"dead ring blocks {dead} held at "
                              f"fed={slot.fed} (window {window})")
    assert eng.pages_recycled >= 2
    assert len(_tokens(eng)["w0"]) == 20


def test_cluster_trace_validation():
    """Engine tags are validated at construction for sequence traces, at
    delivery for lazy ones; lazy traces must be time-ordered."""
    cluster, clock = make_cluster()
    add_smoke_engine(cluster, name="g", namespace="granite")

    def arr(rid, t=0.0, engine="g"):
        return Arrival(t, Request(id=rid, prompt=[1, 2], max_new_tokens=1),
                       engine)

    with pytest.raises(ValueError, match="unknown engine"):
        ClusterSimulator(cluster, [arr("z0", engine="nope")], clock)
    with pytest.raises(ValueError, match="untagged arrival"):
        ClusterSimulator(cluster, [arr("z1", engine=None)], clock)

    def bad_tag():
        yield arr("ok0")
        yield arr("z2", t=1.0, engine="nope")

    with pytest.raises(ValueError, match="unknown engine"):
        ClusterSimulator(cluster, bad_tag(), clock).run()

    def backwards():
        yield arr("ok1", t=5.0)
        yield arr("ok2", t=1.0)

    with pytest.raises(ValueError, match="backwards"):
        ClusterSimulator(cluster, backwards(), clock).run()

    # sequence traces may arrive unsorted: delivery stable-sorts by time
    sim = ClusterSimulator(
        cluster, [arr("s1", t=2.0), arr("s0", t=0.0)], clock)
    assert sim.pending[0].time == 0.0
    rep = sim.run()
    assert len(rep.completed["g"]) == 2


def test_cluster_charges_async_engines_their_overlapped_cost():
    """An ``async_dispatch`` tenant pays the depth-1 pipeline cost inside
    the cluster simulator — matching the single-engine :class:`Simulator`
    on the same trace exactly — instead of being billed the sync
    ``dispatch + step`` serial cost (the pre-fix accounting). All-sync
    clusters reproduce the old accounting bit-for-bit."""

    def cluster_run(async_dispatch):
        cluster, clock = make_cluster()
        eng = add_smoke_engine(cluster, name="g", namespace="granite",
                               slots=2, max_len=40,
                               async_dispatch=async_dispatch)
        trace = tag_engine(staggered_trace(
            make_requests(6, prompt_len=3, new_tokens=6), gap=1.0), "g")
        rep = ClusterSimulator(cluster, trace, clock, step_time=1.0,
                               dispatch_time=1.0).run()
        return rep, _tokens(eng)

    def solo_run(async_dispatch):
        eng, clock = make_engine(slots=2, max_len=40,
                                 async_dispatch=async_dispatch)
        rep = Simulator(eng, staggered_trace(
            make_requests(6, prompt_len=3, new_tokens=6), gap=1.0), clock,
            step_time=1.0, dispatch_time=1.0).run()
        return rep, _tokens(eng)

    sync_rep, sync_toks = cluster_run(False)
    async_rep, async_toks = cluster_run(True)
    assert sync_toks == async_toks               # same results...
    assert async_rep.elapsed < sync_rep.elapsed  # ...cheaper sim clock
    for async_dispatch, rep in ((False, sync_rep), (True, async_rep)):
        solo_rep, solo_toks = solo_run(async_dispatch)
        assert rep.elapsed == solo_rep.elapsed   # same cost model as solo
        assert rep.tokens_generated == solo_rep.tokens_generated
        assert solo_toks == sync_toks


# ---------------------------------------------------------------------------
# determinism regression gate


def test_sim_smoke_determinism_gate():
    """The 1k-request sim-smoke trace, in-process: ``serve_bench``'s
    open-loop mode drives the seeded bursty trace through two
    independently constructed clusters and raises inside ``run_open_loop``
    if any report field, metric summary, or token stream differs — this
    test is the fast-tier regression gate for that bit-reproducibility
    claim (``make sim-smoke`` runs the same configuration as a build
    step)."""
    import pathlib
    import sys

    bench_dir = str(pathlib.Path(__file__).resolve().parents[1]
                    / "benchmarks")
    if bench_dir not in sys.path:
        sys.path.insert(0, bench_dir)
    import serve_bench

    gain = serve_bench.main(["--slots", "4", "--prefill-chunk", "4",
                             "--open-loop", "1000", "--open-loop-skip-flat"])
    assert gain == 1.0                # skip-flat: determinism pair only
