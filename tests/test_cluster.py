"""Multi-model serving cluster: shared pool/table, budget, replay, fairness.

Everything runs under the deterministic harness (fake clock, scripted
traces, tiny smoke models). The invariants held here are the cluster
analogue of the engine suite's:

* **Per-engine bit-identity** — a request's tokens are the same whether
  its engine serves alone (private pool/table) or as a cluster tenant
  (shared pool/table, cross-engine prefix aliasing, admission stalls).
* **The power budget is never exceeded** — admissions stall instead, and
  preempt/replay under a budget stays bit-identical per engine.
* **Pool invariants survive multi-tenancy** — the property test drives
  random interleaved acquire/release/adopt across two tenants and checks
  the free list and refcounts never leak or go negative.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from repro.testing.hypo import given, settings, strategies as st

from engine_sim import (ClusterSimulator, FakeClock, PowerBudget, Request,
                        Simulator, add_smoke_engine, burst_trace,
                        make_cluster, shared_prefix_requests, smoke_params,
                        staggered_trace, tag_engine)
from repro.serve.paged import PagePool


# shared helpers live in engine_sim (PR 7 hygiene: one definition for the
# five suites that compare completed-token maps)
from engine_sim import shared_prefix_reqs as _reqs
from engine_sim import standalone_tokens as _standalone
from engine_sim import tokens_of as _tokens


# -- the tentpole: two models, one pool, one table -----------------------------


def test_two_models_one_pool_bit_identical_and_paged():
    """Two different model configs on one cluster share a single
    PagePool/PageTable, both stay on the paged backend (the old
    shared-table lane fallback is gone), both reuse prefix pages, and
    outputs are bit-identical to each engine serving alone."""
    want_g = _standalone("granite_3_2b", _reqs("g"))
    want_s = _standalone("stablelm_3b", _reqs("s"))
    cluster, clock = make_cluster()
    eg = add_smoke_engine(cluster, "granite_3_2b", name="granite")
    es = add_smoke_engine(cluster, "stablelm_3b", name="stablelm")
    assert eg._pool is cluster.pool and es._pool is cluster.pool
    assert eg.pages is cluster.table and es.pages is cluster.table
    trace = (tag_engine(burst_trace(_reqs("g")), "granite")
             + tag_engine(burst_trace(_reqs("s")), "stablelm"))
    rep = ClusterSimulator(cluster, trace, clock).run()
    assert rep.tokens_generated == 2 * 4 * 4
    assert eg.stats()["backend"] == "paged"      # shared table, still paged
    assert es.stats()["backend"] == "paged"
    assert _tokens(eg) == want_g and _tokens(es) == want_s
    assert eg.prompt_tokens_reused > 0 and es.prompt_tokens_reused > 0
    by_ns = cluster.table.resident_by_ns()
    assert set(by_ns) == {"granite-smoke", "stablelm-smoke"}


def test_cross_engine_replica_prefix_reuse():
    """Two engines serving the same model under one namespace: the second
    replica's cold requests adopt pages the first replica published —
    prefix sharing across engines, bit-identical outputs."""
    want_a = _standalone("granite_3_2b", _reqs("a"))
    want_b = _standalone("granite_3_2b", _reqs("b"))
    cluster, clock = make_cluster()
    ea = add_smoke_engine(cluster, name="rep-a", namespace="granite")
    eb = add_smoke_engine(cluster, name="rep-b", namespace="granite")
    for r in _reqs("a"):
        cluster.submit("rep-a", r)
    cluster.run_until_idle()
    published = cluster.table.stats["published"]
    assert published > 0
    for r in _reqs("b"):
        cluster.submit("rep-b", r)
    cluster.run_until_idle()
    # replica b found every shared page resident: nothing new published,
    # and even its first request was admitted with the prefix pre-consumed
    assert cluster.table.stats["published"] == published
    assert cluster.journal.journal("rep-b").get("b0").prefix_reused == 16
    assert eb.prompt_tokens_reused >= 4 * 16
    assert _tokens(ea) == want_a and _tokens(eb) == want_b


def test_namespaces_isolate_different_weights():
    """Same config, different weights, different namespaces: identical
    token prefixes must NOT alias across the namespace boundary (the same
    tokens under different weights are different states)."""
    cluster, _ = make_cluster()
    add_smoke_engine(cluster, name="m0", namespace="m0", seed=0)
    eb = add_smoke_engine(cluster, name="m1", namespace="m1", seed=1)
    for r in _reqs("a"):
        cluster.submit("m0", r)
    cluster.run_until_idle()
    # m0's prefix pages are resident under ns "m0"; m1 sees a cold table
    for r in _reqs("b"):
        cluster.submit("m1", r)
    cluster.run_until_idle()
    assert cluster.journal.journal("m1").get("b0").prefix_reused == 0
    by_ns = cluster.table.resident_by_ns()
    assert by_ns["m0"] > 0 and by_ns["m1"] > 0
    assert _tokens(eb) == _standalone("granite_3_2b", _reqs("b"), seed=1)


def test_same_namespace_different_model_rejected():
    """Namespace peers alias pages bitwise, so a namespace may only ever
    serve one (config, weights) identity."""
    cluster, _ = make_cluster()
    add_smoke_engine(cluster, name="a", namespace="shared", seed=0)
    with pytest.raises(ValueError, match="different model"):
        add_smoke_engine(cluster, name="b", namespace="shared", seed=1)
    with pytest.raises(ValueError, match="different model"):
        add_smoke_engine(cluster, "stablelm_3b", name="c", namespace="shared")
    # distinct namespace with the distinct model is fine
    add_smoke_engine(cluster, "stablelm_3b", name="d")
    # and duplicate target names (engine or replica group) are not
    with pytest.raises(ValueError, match="duplicate target name"):
        add_smoke_engine(cluster, name="a", namespace="granite")


def test_lane_only_family_cannot_join_cluster():
    """The shared pool holds transformer KV pages; an SSM config has no
    paged decode and must be rejected loudly."""
    cluster, _ = make_cluster()
    with pytest.raises(ValueError, match="paged"):
        add_smoke_engine(cluster, "mamba2_370m", name="ssm")


# -- power-budget backpressure -------------------------------------------------


def test_power_budget_stalls_admissions_never_exceeds():
    """With a 1-bank budget the cluster keeps at most one bank awake at
    every instant, stalls admissions (observably) instead of exceeding it,
    and still drains the trace bit-identically."""
    want_a = _standalone("granite_3_2b", _reqs("a"))
    want_b = _standalone("granite_3_2b", _reqs("b"))
    cluster, clock = make_cluster(
        power_budget=PowerBudget(max_awake_banks=1))
    ea = add_smoke_engine(cluster, name="x", namespace="granite")
    eb = add_smoke_engine(cluster, name="y", namespace="granite")
    sim = ClusterSimulator(
        cluster,
        tag_engine(burst_trace(_reqs("a")), "x")
        + tag_engine(burst_trace(_reqs("b")), "y"),
        clock)
    max_awake = 0
    while cluster.busy or sim.pending:
        sim._deliver_due()
        if cluster.busy:
            cluster.step()
            clock.advance(1.0)
        max_awake = max(max_awake, cluster.awake_banks())
    assert max_awake == 1
    assert cluster.power_stalls > 0
    assert ea.admission_stalls + eb.admission_stalls >= cluster.power_stalls
    assert _tokens(ea) == want_a and _tokens(eb) == want_b


def test_power_budget_preempt_replay_bit_identical():
    """preempt() + replay under a constrained budget reproduces every
    engine's tokens bit-for-bit (per-engine journals cross-check)."""
    want_a = _standalone("granite_3_2b", _reqs("a"))
    want_b = _standalone("granite_3_2b", _reqs("b"))
    cluster, _ = make_cluster(power_budget=PowerBudget(max_awake_banks=1))
    ea = add_smoke_engine(cluster, name="x", namespace="granite")
    eb = add_smoke_engine(cluster, name="y", namespace="granite")
    for r in _reqs("a"):
        cluster.submit("x", r)
    for r in _reqs("b"):
        cluster.submit("y", r)
    for _ in range(5):
        cluster.step()                        # mid-flight on both tenants
    requeued = cluster.preempt()
    assert any(requeued.values())
    assert all(e.active == 0 for e in cluster.engines.values())
    assert cluster.table.pinned == 0
    cluster.run_until_idle()
    assert _tokens(ea) == want_a and _tokens(eb) == want_b


def test_power_veto_skips_to_slot_on_awake_bank():
    """A per-slot power veto must not end the round: a later free slot
    whose bank is already awake admits the same head request at zero
    budget cost (slots 0 and 2 share bank0 here; slots 1 and 3 would wake
    bank1 and stay vetoed)."""
    from repro.core.platform import Platform, XHeepConfig

    platform = Platform(XHeepConfig(n_banks=2))
    for i in range(2):
        platform.power.clock_gate(f"bank{i}")
    cluster, _ = make_cluster(platform=platform,
                              power_budget=PowerBudget(max_awake_banks=1))
    eng = add_smoke_engine(cluster, name="x", slots=4)
    for r in _reqs("p", 3):
        cluster.submit("x", r)
    cluster.step()
    occupied = [i for i, s in enumerate(eng.slots) if s is not None]
    assert occupied == [0, 2]                  # both bank0, one wake total
    assert cluster.awake_banks() == 1
    assert cluster.power_stalls > 0            # slots 1/3 were vetoed
    cluster.run_until_idle()
    assert len(eng.completed) == 3


def test_impossible_budget_raises_instead_of_spinning():
    """A budget no admission can ever satisfy must fail loudly (budget
    deadlock), not stall the cluster forever."""
    cluster, _ = make_cluster(
        power_budget=PowerBudget(budget_uw=-1.0))   # nothing fits
    add_smoke_engine(cluster, name="x")
    cluster.submit("x", Request(id="r", prompt=[1, 2], max_new_tokens=1))
    with pytest.raises(RuntimeError, match="budget deadlock"):
        cluster.run_until_idle()


def test_power_budget_validation():
    with pytest.raises(ValueError,
                       match="max_awake_banks, budget_uw, or max_uj"):
        PowerBudget()
    with pytest.raises(ValueError, match=">= 1"):
        PowerBudget(max_awake_banks=0)
    with pytest.raises(ValueError, match="max_uj_per_token"):
        PowerBudget(max_uj_per_token=0.0)
    with pytest.raises(ValueError, match="unknown operating point"):
        PowerBudget(max_awake_banks=1, throttle_point="turbo")


def test_wrr_weight_paces_admissions_per_round():
    """weight=1 on a 4-slot engine admits at most one request per
    scheduling round (the stall is observable and FIFO-preserving);
    the default weight (= slots) fills every free slot at once."""
    cluster, _ = make_cluster()
    paced = add_smoke_engine(cluster, name="paced", slots=4, weight=1)
    for r in _reqs("p"):
        cluster.submit("paced", r)
    cluster.step()
    assert paced.active == 1 and paced.admission_stalls > 0
    cluster.step()
    assert paced.active == 2
    assert cluster.wrr_stalls > 0
    cluster.run_until_idle()
    assert len(paced.completed) == 4
    # admissions were spread over rounds in FIFO order
    seqs = [cluster.journal.journal("paced").get(f"p{i}").arrival_seq
            for i in range(4)]
    assert seqs == sorted(seqs)

    cluster2, _ = make_cluster()
    eager = add_smoke_engine(cluster2, name="eager", slots=4)   # weight=slots
    for r in _reqs("e"):
        cluster2.submit("eager", r)
    cluster2.step()
    assert eager.active == 4 and eager.admission_stalls == 0


# -- shared-pool pressure ------------------------------------------------------


def test_pool_pressure_reclaims_fairly_and_serves_correctly():
    """A pool too small to hold every tenant's residency reclaims idle
    pages (heaviest namespace first) instead of failing or wiping every
    tenant, and outputs stay bit-identical."""
    from engine_sim import make_requests

    reqs_a = lambda: make_requests(6, prompt_len=25, prefix="a")
    reqs_b = lambda: make_requests(6, prompt_len=25, prefix="b")
    want_a = _standalone("granite_3_2b", reqs_a())
    want_b = _standalone("granite_3_2b", reqs_b(), seed=1)
    # distinct 25-token prompts publish 3 resident pages each; worst-case
    # concurrent block-table demand is 16 (4 slots x 4 pages), so a
    # 17-page pool forces reclaim of idle residency as waves turn over
    cluster, clock = make_cluster(pool_pages=17)
    ea = add_smoke_engine(cluster, name="x", namespace="granite")
    eb = add_smoke_engine(cluster, name="y", namespace="other", seed=1)
    trace = (tag_engine(burst_trace(reqs_a()), "x")
             + tag_engine(burst_trace(reqs_b()), "y"))
    ClusterSimulator(cluster, trace, clock).run()
    assert sum(cluster.reclaims.values()) > 0
    assert cluster.pool.in_use <= cluster.pool.n_pages
    assert _tokens(ea) == want_a and _tokens(eb) == want_b


# -- cluster sim mechanics -----------------------------------------------------


def test_cluster_sim_one_clock_per_engine_reports():
    """One fake clock drives every tenant; the report splits completions
    per engine and sums tokens; untagged arrivals are rejected."""
    cluster, clock = make_cluster()
    add_smoke_engine(cluster, name="granite")
    add_smoke_engine(cluster, "stablelm_3b", name="stablelm")
    trace = (tag_engine(staggered_trace(_reqs("g", 3), gap=2.0), "granite")
             + tag_engine(staggered_trace(_reqs("s", 3), gap=3.0),
                          "stablelm"))
    rep = ClusterSimulator(cluster, trace, clock).run()
    assert set(rep.completed) == {"granite", "stablelm"}
    assert [r.id for r in rep.completed["granite"]] == ["g0", "g1", "g2"]
    assert [r.id for r in rep.completed["stablelm"]] == ["s0", "s1", "s2"]
    assert rep.tokens_generated == 6 * 4
    assert rep.elapsed > 0 and rep.throughput > 0
    finish = [r.finish_time for r in rep.completed["granite"]]
    assert finish == sorted(finish)
    with pytest.raises(ValueError, match="untagged arrival"):
        ClusterSimulator(cluster,
                         staggered_trace(_reqs("u", 1)), clock)


def test_cluster_journal_keeps_engines_separate():
    cluster, clock = make_cluster()
    add_smoke_engine(cluster, name="a", namespace="granite")
    add_smoke_engine(cluster, name="b", namespace="granite")
    trace = (tag_engine(burst_trace(_reqs("a", 2)), "a")
             + tag_engine(burst_trace(_reqs("b", 2)), "b"))
    ClusterSimulator(cluster, trace, clock).run()
    done = cluster.journal.completed()
    assert set(done) == {"a", "b"}
    assert [r.request_id for r in done["a"]] == ["a0", "a1"]
    assert [r.request_id for r in done["b"]] == ["b0", "b1"]
    assert not cluster.journal.incomplete()


# -- PagePool invariants under multi-tenant interleaving (property test) -------


@settings(max_examples=25, deadline=None)
@given(codes=st.lists(st.integers(min_value=0, max_value=10**6),
                      min_size=1, max_size=120),
       n_pages=st.integers(min_value=2, max_value=9))
def test_pool_invariants_random_two_tenant_interleaving(codes, n_pages):
    """Random interleaved alloc/adopt/release across two tenants: the free
    list and refcounts never leak or go negative, per-tenant accounting
    sums to the pool's occupancy, and the null sentinel is never a real
    page. (Runs via hypothesis when installed, repro.testing.hypo
    otherwise.)"""
    pool = PagePool(n_pages, 4)
    refs: dict[int, int] = {}
    held = {"a": [], "b": []}
    for code in codes:
        tenant = "a" if (code // 7) % 2 == 0 else "b"
        op = code % 3
        if op == 0:                                  # alloc
            if pool.free_count:
                idx = pool.alloc(tenant)
                assert idx != pool.null
                assert refs.get(idx, 0) == 0
                refs[idx] = 1
                held[tenant].append(idx)
            else:
                with pytest.raises(RuntimeError, match="exhausted"):
                    pool.alloc(tenant)
        elif op == 1:                                # adopt (cross-tenant pin)
            live = sorted(i for i, c in refs.items() if c > 0)
            if live:
                idx = live[code % len(live)]
                pool.retain(idx)
                refs[idx] += 1
                held[tenant].append(idx)
        else:                                        # release one we hold
            if held[tenant]:
                idx = held[tenant].pop(code % len(held[tenant]))
                pool.release(idx)
                refs[idx] -= 1
        # invariants after every operation
        assert pool.in_use + pool.free_count == pool.n_pages
        assert pool.refcounts() == {i: c for i, c in refs.items() if c > 0}
        assert sum(pool.owners().values()) == pool.in_use
    # the null sentinel is not a refcounted page
    with pytest.raises(ValueError):
        pool.retain(pool.null)
    with pytest.raises(ValueError):
        pool.release(pool.null)
    # drain everything: the pool must return to fully free, nothing leaked
    for tenant in held:
        for idx in held[tenant]:
            pool.release(idx)
    assert pool.in_use == 0 and pool.free_count == pool.n_pages
    assert pool.stats["allocated"] == pool.stats["freed"]
    assert not pool.owners()
    if n_pages:                                      # over-release raises
        with pytest.raises(ValueError, match="released more"):
            pool.release(0)
