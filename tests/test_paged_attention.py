"""Paged decode attention: Pallas kernel vs ref vs flash/naive attention.

Parity sweeps across page sizes, ragged per-slot valid lengths, GQA/MQA
head layouts, and window masks (interpret=True on CPU), plus the fused
append semantics (tail-page scatter, masked-lane drop) and the contiguous-
equivalence property: gathering a slot's pages reproduces exactly what
causal attention over the contiguous KV prefix computes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import ops, ref
from repro.models.layers import attention_ref

RNG = np.random.default_rng(11)

# jit the op entry points once per (shape, impl, window) — eager pallas_call
# re-traces every invocation, which would dominate the test wall clock
import functools


@functools.partial(jax.jit, static_argnames=("impl", "window"))
def _paged(q, kp, vp, tables, lengths, *, impl, window=None):
    return ops.paged_attention(q, kp, vp, tables, lengths, window=window,
                               impl=impl)


@functools.partial(jax.jit, static_argnames=("impl", "window"))
def _append(q, k_new, v_new, kp, vp, tables, lengths, mask, *, impl,
            window=None):
    return ops.paged_decode_append(q, k_new, v_new, kp, vp, tables, lengths,
                                   append_mask=mask, impl=impl, window=window)


def t(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


def make_pool(b, max_len, kh, d, ps, dtype=jnp.float32):
    """Disjoint per-slot page chains over a shuffled pool + ragged lengths."""
    np_slot = -(-max_len // ps)
    pool_pages = b * np_slot + 1            # spare page stays unreferenced
    perm = RNG.permutation(pool_pages)
    tables = jnp.asarray(perm[:b * np_slot].reshape(b, np_slot), jnp.int32)
    kp = t(pool_pages, ps, kh, d, dtype=dtype)
    vp = t(pool_pages, ps, kh, d, dtype=dtype)
    lengths = jnp.asarray(RNG.integers(1, max_len + 1, size=(b,)), jnp.int32)
    return kp, vp, tables, lengths


PAGED_CASES = [
    # B, H, K, D, max_len, ps, window
    (3, 4, 2, 16, 32, 8, None),
    (2, 4, 4, 48, 24, 4, None),      # MHA, unaligned D, tiny pages
    (1, 8, 1, 64, 64, 16, None),     # MQA
    (4, 4, 2, 16, 40, 8, 12),        # sliding window
    (2, 6, 3, 32, 33, 16, None),     # max_len not a page multiple
]


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("b,h,k,d,max_len,ps,win", PAGED_CASES)
def test_paged_kernel_vs_ref(b, h, k, d, max_len, ps, win, dtype):
    q = t(b, h, d, dtype=dtype)
    kp, vp, tables, lengths = make_pool(b, max_len, k, d, ps, dtype=dtype)
    want = _paged(q, kp, vp, tables, lengths, impl="ref", window=win)
    got = _paged(q, kp, vp, tables, lengths, impl="pallas", window=win)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_paged_kernel_vs_ref_bf16():
    """One bf16 sweep — the pool dtype the serving engine actually uses."""
    test_paged_kernel_vs_ref(*PAGED_CASES[0], jnp.bfloat16)


def test_paged_matches_causal_attention_over_contiguous_kv():
    """Scattering contiguous KV into pages and attending through the block
    table reproduces causal attention at the last position — the property
    the engine's paged decode rests on."""
    b, s, h, kh, d, ps = 2, 24, 4, 2, 16, 8
    q = t(b, 1, h, d)
    kc, vc = t(b, s, kh, d), t(b, s, kh, d)
    np_slot = s // ps
    pool_pages = b * np_slot + 1
    kp = jnp.zeros((pool_pages, ps, kh, d))
    vp = jnp.zeros((pool_pages, ps, kh, d))
    tables = np.zeros((b, np_slot), np.int32)
    page = 0
    for bi in range(b):
        for j in range(np_slot):
            kp = kp.at[page].set(kc[bi, j * ps:(j + 1) * ps])
            vp = vp.at[page].set(vc[bi, j * ps:(j + 1) * ps])
            tables[bi, j] = page
            page += 1
    lengths = jnp.asarray([s, s - 5], jnp.int32)
    want = jax.vmap(
        lambda qb, kb, vb, lb: attention_ref(
            qb[None], kb[None], vb[None], causal=True, q_offset=lb - 1,
            kv_len=lb)[0])(q, kc, vc, lengths)[:, 0]
    for impl in ("ref", "pallas"):
        got = _paged(q[:, 0], kp, vp, jnp.asarray(tables), lengths,
                     impl=impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_fused_append_writes_tail_page_and_masks_idle_lanes(impl):
    b, h, kh, d, ps, max_len = 3, 4, 2, 16, 8, 32
    q = t(b, h, d)
    kp, vp, tables, _ = make_pool(b, max_len, kh, d, ps)
    lengths = jnp.asarray([0, 9, 31], jnp.int32)     # page starts/middles/ends
    k_new, v_new = t(b, kh, d), t(b, kh, d)
    mask = jnp.asarray([True, False, True])
    o, kp2, vp2 = _append(q, k_new, v_new, kp, vp, tables, lengths, mask,
                          impl=impl)
    for bi, (ln, m) in enumerate(zip([0, 9, 31], [True, False, True])):
        page, off = int(tables[bi, ln // ps]), ln % ps
        if m:
            np.testing.assert_array_equal(np.asarray(kp2[page, off]),
                                          np.asarray(k_new[bi]))
            np.testing.assert_array_equal(np.asarray(vp2[page, off]),
                                          np.asarray(v_new[bi]))
        else:
            # masked lane: the pool is untouched bitwise
            np.testing.assert_array_equal(np.asarray(kp2[page, off]),
                                          np.asarray(kp[page, off]))
    # active lanes attend over the appended entry: lengths+1 with new pool
    want = _paged(q, kp2, vp2, tables, lengths + 1, impl="ref")
    live = np.asarray([0, 2])
    np.testing.assert_allclose(np.asarray(o)[live], np.asarray(want)[live],
                               atol=2e-5, rtol=2e-5)


def test_append_positions_compose_into_a_decode_chain():
    """Sequentially appending tokens through the fused op reproduces
    attention over the full contiguous history at every step."""
    h, kh, d, ps, steps = 4, 2, 8, 4, 10
    np_slot = -(-steps // ps)
    kp = jnp.zeros((np_slot + 1, ps, kh, d))
    vp = jnp.zeros((np_slot + 1, ps, kh, d))
    tables = jnp.asarray([[0, 1, 2][:np_slot]], jnp.int32)
    # fixed-shape contiguous mirror of the appended history (one compile)
    kc = jnp.zeros((1, steps, kh, d))
    vc = jnp.zeros((1, steps, kh, d))
    oracle = jax.jit(lambda q, kc, vc, kv_len, off: attention_ref(
        q[:, None], kc, vc, causal=False, q_offset=off, kv_len=kv_len)[0, 0])
    for step in range(steps):
        q = t(1, h, d)
        kn, vn = t(1, kh, d), t(1, kh, d)
        kc = kc.at[0, step].set(kn[0])
        vc = vc.at[0, step].set(vn[0])
        lengths = jnp.asarray([step], jnp.int32)
        o, kp, vp = _append(q, kn, vn, kp, vp, tables, lengths, None,
                            impl="pallas")
        want = oracle(q, kc, vc, step + 1, step)
        np.testing.assert_allclose(np.asarray(o[0]), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [3, 4, 6, 9])
def test_ring_block_table_decode_matches_windowed_oracle(window):
    """Decoding through a *ring* block table — ceil(window/ps)+1 entries,
    the tail entry wrapping and old pages recycled — reproduces windowed
    attention over the full contiguous history at every step, for windows
    smaller than (3), equal to (4), and not multiples of (6, 9) the page
    size. This is the layout the serving engine keeps for sliding-window
    configs; page ids are deliberately reused so recycled pages carry
    stale positions the mask must hide."""
    h, kh, d, ps, steps = 4, 2, 8, 4, 14
    r = -(-window // ps) + 1
    ids = r + 2                       # rotating live ids; row `ids` = null
    pools = {impl: (jnp.zeros((ids + 1, ps, kh, d)),
                    jnp.zeros((ids + 1, ps, kh, d)))
             for impl in ("ref", "pallas")}
    tables = np.full((1, r), ids, np.int32)
    kc = jnp.zeros((1, steps, kh, d))     # contiguous mirror of the history
    vc = jnp.zeros((1, steps, kh, d))
    oracle = jax.jit(lambda q, kc, vc, n, off: attention_ref(
        q[:, None], kc, vc, causal=False, window=window, q_offset=off,
        kv_len=n)[0, 0])
    for n in range(steps):
        blk = n // ps
        if n % ps == 0:
            # ring install: the entry's previous occupant (block blk - r)
            # is recycled; its page id returns to the rotation
            tables[0, blk % r] = blk % ids
        q = t(1, h, d)
        kn, vn = t(1, kh, d), t(1, kh, d)
        kc, vc = kc.at[0, n].set(kn[0]), vc.at[0, n].set(vn[0])
        want = oracle(q, kc, vc, n + 1, n)
        lengths = jnp.asarray([n], jnp.int32)
        for impl in ("ref", "pallas"):
            kp, vp = pools[impl]
            o, kp, vp = _append(q, kn, vn, kp, vp, jnp.asarray(tables),
                                lengths, None, impl=impl, window=window)
            pools[impl] = (kp, vp)
            np.testing.assert_allclose(np.asarray(o[0]), np.asarray(want),
                                       atol=2e-5, rtol=2e-5,
                                       err_msg=f"{impl} step {n}")


def test_ring_append_wraps_into_the_reused_entry():
    """Past the ring, the fused append lands in the page the wrapped table
    entry points at — offset ``lengths % ps`` of page ``tables[(lengths //
    ps) % R]`` — for both impls."""
    h, kh, d, ps = 4, 2, 8, 4
    window, r = 4, 2
    kp, vp = t(5, ps, kh, d), t(5, ps, kh, d)
    tables = jnp.asarray([[3, 1]], jnp.int32)   # entry 0 now holds block 2
    lengths = jnp.asarray([9], jnp.int32)       # block 2, offset 1 -> entry 0
    q, kn, vn = t(1, h, d), t(1, kh, d), t(1, kh, d)
    for impl in ("ref", "pallas"):
        _, kp2, vp2 = _append(q, kn, vn, kp, vp, tables, lengths, None,
                              impl=impl, window=window)
        np.testing.assert_array_equal(np.asarray(kp2[3, 1]),
                                      np.asarray(kn[0]))
        np.testing.assert_array_equal(np.asarray(vp2[3, 1]),
                                      np.asarray(vn[0]))


def test_xaif_registers_paged_attention():
    from repro.core.xaif import REGISTRY

    assert "pallas" in REGISTRY.impls("paged_attention")
    spec = REGISTRY.get("paged_attention", "pallas")
    assert any(p.name == "block_table" for p in spec.master_ports)
    assert spec.power_domain is not None
