import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_subprocess(code: str, devices: int = 1, timeout: int = 420):
    """Run python code in a fresh process with N host devices (for
    multi-device tests — the main test process keeps 1 device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC)
    if devices > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:\n{out.stdout}\n"
                             f"STDERR:\n{out.stderr}")
    return out.stdout


@pytest.fixture
def subproc():
    return run_subprocess


@pytest.fixture(scope="session", autouse=True)
def shared_jit_cache():
    """One jit/compile cache shared by every engine test in the session.

    The engine-test harness (``engine_sim.CANONICAL``) pads every engine to
    one canonical device shape (4 lanes, 48 cache positions), so the dozens
    of engines built across ``test_engine.py`` / ``test_pages.py`` /
    ``test_ft.py`` with different ``slots``/``max_len`` all hit the *same*
    jitted-and-compiled step function (the module-level caches in
    ``serve/engine.py`` / ``serve/paged.py``) instead of compiling one XLA
    program per shape. Extra lanes ride the batch idle and extra cache
    positions are masked; outputs are unchanged — the bit-identity tests
    hold the padded engines to that.

    jax's on-disk persistent compilation cache is opt-in only
    (``REPRO_JAX_CACHE_DIR=<dir>``): on this jax/jaxlib CPU build,
    deserialized executables for the donated training step produce NaNs and
    then segfault (reproduced via ``launch/train.py --resume``), so it must
    never be on by default for a repo whose headline claim is bit-identical
    determinism.
    """
    cache_dir = os.environ.get("REPRO_JAX_CACHE_DIR")
    if cache_dir:
        import jax

        pathlib.Path(cache_dir).mkdir(parents=True, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.3)

    sys.path.insert(0, str(REPO / "tests"))
    import engine_sim

    engine_sim.CANONICAL.update(lane_batch=4, device_len=48)
    yield
