import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_subprocess(code: str, devices: int = 1, timeout: int = 420):
    """Run python code in a fresh process with N host devices (for
    multi-device tests — the main test process keeps 1 device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC)
    if devices > 1:
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:\n{out.stdout}\n"
                             f"STDERR:\n{out.stderr}")
    return out.stdout


@pytest.fixture
def subproc():
    return run_subprocess
