"""Multi-device subprocess tests: sharded lowering, compressed pod psum,
pipeline parallelism, production-mesh smoke (tiny arch on 512 devices)."""

import pytest

pytestmark = pytest.mark.slow   # every test here forks a multi-device process


def test_compressed_pod_psum_close_to_exact(subproc):
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.compat import shard_map
from repro.launch.mesh import make_mesh
from repro.train.compress import compressed_psum_tree, init_error_state

mesh = make_mesh((2, 2), ("pod", "data"))
g = {"w": jax.random.normal(jax.random.key(0), (2, 64)) * 1e-2}
err = init_error_state(g, jnp.float32)

def inner(g, e):
    return compressed_psum_tree(g, e, "pod")

out, new_err = shard_map(inner, mesh=mesh,
                         in_specs=(P("pod"), P("pod")),
                         out_specs=(P("pod"), P("pod")),
                         check_vma=False)(g, err)
exact = jnp.mean(g["w"], axis=0, keepdims=True).repeat(2, 0)
rel = float(jnp.abs(out["w"] - exact).max() / jnp.abs(exact).max())
assert rel < 0.02, rel          # int8: ~1% worst-case per-tensor error
assert float(jnp.abs(new_err["w"]).max()) > 0  # error feedback captured
print("COMPRESS_OK", rel)
"""
    assert "COMPRESS_OK" in subproc(code, devices=4)


def test_pipeline_forward_matches_sequential(subproc):
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.train.pipeline import pipeline_forward

S, M, D = 4, 6, 8
mesh = make_mesh((S,), ("stage",))
key = jax.random.key(0)
ws = jax.random.normal(key, (S, D, D)) * 0.3

def stage_fn(w, x):
    return jnp.tanh(x @ w)

xs = jax.random.normal(jax.random.key(1), (M, 2, D))
run = pipeline_forward(stage_fn, mesh, "stage")
got = run(ws, xs)

want = xs
for i in range(S):
    want = jnp.tanh(want @ ws[i])
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
print("PIPELINE_OK")
"""
    assert "PIPELINE_OK" in subproc(code, devices=4)


def test_tiny_arch_runs_on_production_mesh(subproc):
    """Numerically run (not just compile) a smoke arch on the 16x16 mesh."""
    code = """
import jax, jax.numpy as jnp
from repro import configs
from repro.core.platform import Platform, XHeepConfig
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.sharding import params as P
from repro.train.trainer import TrainConfig, build_sharded_train
from repro.train import optim as optim_lib

cfg = configs.smoke("granite_3_2b")
mesh = make_production_mesh()            # 16 x 16 = 256 host devices
platform = Platform(XHeepConfig())
rules = platform.rules(mesh)
tc = TrainConfig(optimizer="adamw", accum=2)
st = build_sharded_train(cfg, tc, mesh, rules, global_batch=32, seq=32)
params = P.cast_tree(P.init_tree(registry.decls(cfg), jax.random.key(0)), jnp.bfloat16)
opt = optim_lib.get("adamw").init(params)
key = jax.random.key(1)
batch = {"tokens": jax.random.randint(key, (2, 16, 32), 0, cfg.vocab),
         "labels": jax.random.randint(key, (2, 16, 32), 0, cfg.vocab)}
batch = jax.tree.map(jax.device_put, batch, st.batch_shardings)
with mesh:
    params, opt, metrics = st.step_fn(params, opt, batch)
loss = float(metrics["loss"])
assert jnp.isfinite(loss), loss
print("PRODMESH_OK", loss)
"""
    assert "PRODMESH_OK" in subproc(code, devices=256, timeout=560)


def test_multipod_serve_lowering(subproc):
    code = """
import jax, jax.numpy as jnp
from repro import configs
from repro.core.platform import Platform, XHeepConfig
from repro.launch.mesh import make_production_mesh
from repro.serve.engine import build_sharded_serve

cfg = configs.get("recurrentgemma-2b")
mesh = make_production_mesh(multi_pod=True)   # (2,16,16) = 512
rules = Platform(XHeepConfig()).rules(mesh)
sv = build_sharded_serve(cfg, mesh, rules, batch=128, max_len=32768)
tok = jax.ShapeDtypeStruct((128, 1), jnp.int32)
with mesh:
    compiled = sv.decode_fn.lower(sv.params_abstract, sv.cache_abstract, tok).compile()
mem = compiled.memory_analysis()
assert mem.argument_size_in_bytes > 0
print("MULTIPOD_OK", mem.argument_size_in_bytes)
"""
    assert "MULTIPOD_OK" in subproc(code, devices=512, timeout=560)
