"""Rule-engine unit + property tests (hypothesis)."""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the in-repo seeded-random subset
    from repro.testing.hypo import given, settings, strategies as st
from jax.sharding import PartitionSpec

from repro.launch.mesh import make_host_mesh
from repro.sharding import axes as lx
from repro.sharding import rules as R
from repro.sharding.params import Axes, ParamDecl, axes_tree, init_tree, stack_tree


class FakeMesh:
    """Mesh stand-in with arbitrary axis sizes (no devices needed)."""

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)

        class _Dev:
            shape = tuple(sizes.values())

        self.devices = _Dev()


def fake_mesh(sizes):
    return FakeMesh(sizes)


PROD_MESH = fake_mesh({"data": 16, "model": 16})
POD_MESH = fake_mesh({"pod": 2, "data": 16, "model": 16})


def fc(mesh):
    return R.fully_connected(mesh)


def test_divisibility_fallback():
    rules = fc(PROD_MESH)
    # kv_heads=8 does not divide model=16 -> replicated; embed FSDPs on data
    spec = R.spec_for((2048, 8, 64), (lx.EMBED, lx.KV_HEADS, lx.HEAD_DIM),
                      rules, PROD_MESH)
    assert spec == PartitionSpec("data")
    # heads=32 divides -> sharded
    spec = R.spec_for((2048, 32, 64), (lx.EMBED, lx.HEADS, lx.HEAD_DIM),
                      rules, PROD_MESH)
    assert spec == PartitionSpec("data", "model")
    # odd dim (49155 vocab) -> replicated
    spec = R.spec_for((49155, 64), (lx.VOCAB, lx.HEAD_DIM), rules, PROD_MESH)
    assert spec == PartitionSpec()


def test_no_duplicate_mesh_axes():
    rules = fc(POD_MESH)
    # batch takes (pod, data); embed wants data -> must NOT reuse it
    spec = R.spec_for((256, 4096, 2048), (lx.BATCH, lx.SEQ, lx.EMBED),
                      rules, POD_MESH)
    flat = [a for e in spec for a in ((e,) if isinstance(e, str) else (e or ()))]
    assert len(flat) == len(set(flat))
    assert spec[0] == ("pod", "data")


def test_one_at_a_time_is_pure_dp():
    rules = R.one_at_a_time(PROD_MESH)
    spec = R.spec_for((1024, 1024), (lx.EMBED, lx.MLP), rules, PROD_MESH)
    assert spec == PartitionSpec()


@settings(max_examples=200, deadline=None)
@given(
    dims=st.lists(st.sampled_from(
        [lx.BATCH, lx.SEQ, lx.EMBED, lx.MLP, lx.HEADS, lx.KV_HEADS,
         lx.VOCAB, lx.EXPERT, lx.HEAD_DIM, None]), min_size=1, max_size=5),
    sizes=st.lists(st.integers(1, 4096), min_size=5, max_size=5),
    pod=st.booleans(),
)
def test_spec_property_valid_and_divisible(dims, sizes, pod):
    mesh = POD_MESH if pod else PROD_MESH
    rules = fc(mesh)
    shape = tuple(sizes[:len(dims)])
    spec = R.spec_for(shape, tuple(dims), rules, mesh)
    msizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = []
    for dim_size, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        names = (entry,) if isinstance(entry, str) else (entry or ())
        prod = 1
        for nm in names:
            used.append(nm)
            prod *= msizes[nm]
        # property 1: every sharded dim is exactly divisible
        assert dim_size % prod == 0
    # property 2: no mesh axis used twice
    assert len(used) == len(set(used))


@settings(max_examples=50, deadline=None)
@given(n_layers=st.integers(1, 8), d=st.integers(1, 64))
def test_stack_tree_prepends_layer_axis(n_layers, d):
    decl = ParamDecl((d, d * 2), Axes(lx.EMBED, lx.MLP), init="fan_in")
    stacked = stack_tree({"w": decl}, n_layers, lx.LAYERS)
    assert stacked["w"].shape == (n_layers, d, d * 2)
    assert tuple(stacked["w"].axes) == (lx.LAYERS, lx.EMBED, lx.MLP)


def test_init_tree_deterministic_and_independent():
    decls = {"a": ParamDecl((4, 8), Axes(None, None)),
             "b": ParamDecl((4, 8), Axes(None, None))}
    t1 = init_tree(decls, jax.random.key(0))
    t2 = init_tree(decls, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(t1["a"]), np.asarray(t2["a"]))
    assert np.abs(np.asarray(t1["a"]) - np.asarray(t1["b"])).max() > 1e-6


def test_shard_bytes():
    rules = fc(PROD_MESH)
    spec = R.spec_for((1024, 4096), (lx.EMBED, lx.MLP), rules, PROD_MESH)
    assert spec == PartitionSpec("data", "model")  # FSDP x TP
    b = R.shard_bytes((1024, 4096), spec, PROD_MESH, 2)
    assert b == 1024 * 4096 * 2 // (16 * 16)


def test_interleaved_addressing_adds_sequence_parallelism():
    from repro.core.platform import Platform, XHeepConfig

    mesh = make_host_mesh()
    p_cont = Platform(XHeepConfig(addressing="contiguous"))
    p_int = Platform(XHeepConfig(addressing="interleaved"))
    assert p_cont.rules(mesh).lookup(lx.SEQ) == ()
    assert p_int.rules(mesh).lookup(lx.SEQ) == ("data",)
