"""Sharding rules vs serving configs: every paged config resolves TP.

The serving tensor-parallel path (:func:`repro.sharding.rules.
validate_serve_tp` + ``serve_param_specs`` + ``serve_pool_spec``) must
agree with the registry about which configs it can shard and how:

* every paged-capable config resolves a valid head-axis sharding for any
  ``tp`` that divides its KV-head count — and the resolved spec tree
  shards exactly the into-head projections (Axes ending in HEAD_DIM),
  leaving the output projection replicated so the decode step's one
  collective stays the pre-``wo`` all-gather;
* indivisible head counts (GQA at too-large tp, MQA at any tp > 1) are
  rejected *loudly* with the cause in the message — the serving
  counterpart of ``spec_for``'s silent divisibility fallback, which would
  quietly replicate arenas the caller asked to split;
* MoE and the SSM/hybrid lane-fallback families are rejected at any tp
  (no paged KV, no head axis to shard), again naming the reason.
"""

import dataclasses

import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.models import registry
from repro.sharding import axes as lx
from repro.sharding import rules as R
from repro.sharding.params import Axes, axes_tree, is_axes, map_decls

PAGED = [a for a in configs.names()
         if registry.supports_paged(configs.smoke(a))]
UNPAGED = [a for a in configs.names()
           if not registry.supports_paged(configs.smoke(a))]


def _spec_leaves(cfg, tp_axis="model"):
    """(axes, spec) pairs over the config's parameter tree."""
    import jax

    axt = axes_tree(registry.decls(cfg))
    spt = R.serve_param_specs(cfg, tp_axis)
    axs = jax.tree.leaves(axt, is_leaf=is_axes)
    sps = jax.tree.leaves(spt, is_leaf=lambda x: isinstance(x, P))
    assert len(axs) == len(sps)
    return list(zip(axs, sps))


@pytest.mark.parametrize("arch", PAGED)
def test_paged_configs_resolve_head_sharding(arch):
    """Every paged-capable config validates at every tp dividing its KV
    heads, and its spec tree shards the head axis of exactly the
    into-head projections."""
    cfg = configs.smoke(arch)
    for tp in (1, 2, cfg.n_kv_heads):
        if cfg.n_kv_heads % tp == 0:
            R.validate_serve_tp(cfg, tp)    # must not raise
    # q heads are groups x kv heads, so kv divisibility implies q
    assert cfg.n_heads % cfg.n_kv_heads == 0
    sharded = 0
    for ax, spec in _spec_leaves(cfg):
        dims = tuple(ax)
        if "model" in spec:
            sharded += 1
            assert dims[-1] == lx.HEAD_DIM, (dims, spec)
            assert dims[spec.index("model")] in (lx.HEADS, lx.KV_HEADS)
            assert spec.count("model") == 1
        elif dims and dims[-1] == lx.HEAD_DIM:
            # an into-head projection left replicated would silently
            # duplicate attention compute across the mesh
            assert not ({lx.HEADS, lx.KV_HEADS} & set(dims)), (dims, spec)
    # wq + wk + wv (layer-stacked decls: one leaf each, LAYERS-leading)
    assert sharded >= 3


@pytest.mark.parametrize("arch", PAGED)
def test_output_projection_stays_replicated(arch):
    """wo consumes the all-gathered heads: its spec must be empty even
    though its axes mention HEADS — the HEAD_DIM-suffix rule, not a name
    denylist, is what distinguishes it."""
    cfg = configs.smoke(arch)
    specs = R.serve_param_specs(cfg)
    names = map_decls(lambda d: tuple(d.axes), registry.decls(cfg))
    seen_wo = False
    for ax, spec in _spec_leaves(cfg):
        dims = tuple(ax)
        if lx.HEADS in dims and dims[-1] != lx.HEAD_DIM:
            seen_wo = True
            assert spec == P(), (dims, spec)
    assert seen_wo, f"{arch}: no output projection found in {names}"
    del specs


@pytest.mark.parametrize("arch", PAGED)
def test_gqa_indivisible_tp_rejected(arch):
    """tp beyond the KV-head count (or not dividing it) fails loudly,
    naming the head count — never the silent-replication fallback."""
    cfg = configs.smoke(arch)
    bad = cfg.n_kv_heads * 2 - 1 if cfg.n_kv_heads > 1 else 2
    assert cfg.n_kv_heads % bad
    with pytest.raises(ValueError, match=r"n_kv_heads \d+ % tp"):
        R.validate_serve_tp(cfg, bad)


@pytest.mark.parametrize("arch", UNPAGED)
def test_lane_fallback_families_rejected(arch):
    """MoE / SSM / hybrid have no paged KV to shard — rejected at any tp
    with the family named, including tp=1 (the caller asked for the
    sharded path, not for a silent downgrade to lanes)."""
    cfg = configs.smoke(arch)
    for tp in (1, 2):
        with pytest.raises(ValueError, match="cannot serve tensor-parallel"):
            R.validate_serve_tp(cfg, tp)


def test_mqa_cannot_shard_beyond_one():
    """A single shared KV head cannot split: the error says MQA, not just
    a bare modulus, so the operator knows it is architectural."""
    cfg = dataclasses.replace(configs.smoke("granite_3_2b"),
                              n_kv_heads=1, n_heads=4)
    R.validate_serve_tp(cfg, 1)             # fine on one device
    with pytest.raises(ValueError, match="MQA has a single shared KV head"):
        R.validate_serve_tp(cfg, 2)


def test_tp_below_one_rejected():
    cfg = configs.smoke("granite_3_2b")
    with pytest.raises(ValueError, match="tp must be >= 1"):
        R.validate_serve_tp(cfg, 0)


def test_serve_param_spec_head_dim_suffix_rule():
    """Unit coverage of the rule itself: only HEAD_DIM-suffixed axes with
    a head dim shard, the first head axis takes the mesh axis, trailing
    Nones are trimmed, and the tp axis name is a parameter."""
    assert R.serve_param_spec(Axes(lx.EMBED, lx.HEADS, lx.HEAD_DIM)) == \
        P(None, "model")
    assert R.serve_param_spec(Axes(lx.KV_HEADS, lx.HEAD_DIM)) == P("model")
    # wo: head axes but EMBED-suffixed -> replicated
    assert R.serve_param_spec(Axes(lx.HEADS, lx.HEAD_DIM, lx.EMBED)) == P()
    # no head axis at all -> replicated even when HEAD_DIM-suffixed
    assert R.serve_param_spec(Axes(lx.EMBED, lx.HEAD_DIM)) == P()
    assert R.serve_param_spec(Axes(lx.EMBED, lx.MLP)) == P()
    assert R.serve_param_spec(Axes()) == P()
    assert R.serve_param_spec(Axes(lx.EMBED, lx.HEADS, lx.HEAD_DIM),
                              tp_axis="tp") == P(None, "tp")


def test_pool_spec_and_shard_bytes():
    """The arena spec shards only the KV-head dim; per-device bytes come
    out to 1/tp of the footprint for any divisible head count."""

    class _M:
        axis_names = ("model",)

        class devices:
            shape = (2,)

    spec = R.serve_pool_spec()
    assert spec == P(None, None, None, "model")
    full = R.shard_bytes((4, 8, 8, 2, 16), P(), _M, 4)
    half = R.shard_bytes((4, 8, 8, 2, 16), spec, _M, 4)
    assert full == 4 * 8 * 8 * 2 * 16 * 4
    # the leading (L, P, page) dims never split: pages stay device-invariant
    assert half * 2 == full
