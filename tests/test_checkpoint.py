"""Checkpoint roundtrip, atomicity, bf16, async, elastic resharding."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint


def tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (4, 8), jnp.float32),
        "nested": {"b": jax.random.normal(k, (3,), jnp.bfloat16),
                   "c": jnp.arange(5, dtype=jnp.int32)},
    }


def assert_tree_equal(x, y):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip(tmp_path):
    t = tree()
    checkpoint.save(tmp_path, t, step=7)
    got, step, meta = checkpoint.restore(tmp_path, t)
    assert step == 7
    assert_tree_equal(t, got)
    assert got["nested"]["b"].dtype == jnp.bfloat16


def test_latest_step_and_multiple(tmp_path):
    t = tree()
    checkpoint.save(tmp_path, t, step=1)
    checkpoint.save(tmp_path, t, step=5)
    assert checkpoint.latest_step(tmp_path) == 5


def test_async_save(tmp_path):
    t = tree()
    h = checkpoint.save(tmp_path, t, step=3, async_=True)
    h.join()
    got, step, _ = checkpoint.restore(tmp_path, t)
    assert step == 3
    assert_tree_equal(t, got)


def test_structure_mismatch_raises(tmp_path):
    checkpoint.save(tmp_path, tree(), step=1)
    with pytest.raises(ValueError, match="mismatch"):
        checkpoint.restore(tmp_path, {"different": jnp.zeros(3)})


def test_no_partial_checkpoint_visible(tmp_path):
    # simulate crash: .tmp dir left behind must not count as a checkpoint
    t = tree()
    checkpoint.save(tmp_path, t, step=2)
    (tmp_path / ".tmp_step_000000009").mkdir()
    assert checkpoint.latest_step(tmp_path) == 2


def test_metadata_roundtrip(tmp_path):
    checkpoint.save(tmp_path, tree(), step=1, metadata={"arch": "granite"})
    _, _, meta = checkpoint.restore(tmp_path, tree())
    assert meta["arch"] == "granite"


@pytest.mark.slow   # subprocess with 8 host devices
def test_elastic_resharding_across_meshes(subproc, tmp_path):
    """Save sharded on a (2,4) mesh, restore onto (4,2) and (1,1)."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt import checkpoint
from repro.launch.mesh import make_mesh

t = {{"w": jnp.arange(8*16, dtype=jnp.float32).reshape(8, 16)}}
mesh_a = make_mesh((2, 4), ("data", "model"))
sh_a = {{"w": NamedSharding(mesh_a, P("data", "model"))}}
t_a = jax.tree.map(jax.device_put, t, sh_a)
checkpoint.save(r"{tmp_path}", t_a, step=1)

mesh_b = make_mesh((4, 2), ("data", "model"))
sh_b = {{"w": NamedSharding(mesh_b, P("model", "data"))}}
got, step, _ = checkpoint.restore(r"{tmp_path}", t, shardings=sh_b)
np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
got1, _, _ = checkpoint.restore(r"{tmp_path}", t)
np.testing.assert_array_equal(np.asarray(got1["w"]), np.asarray(t["w"]))
print("ELASTIC_OK")
"""
    out = subproc(code, devices=8)
    assert "ELASTIC_OK" in out


# ---------------------------------------------------------------------------
# Partial-write / corruption detection (the engine-rebuild restore path)
# ---------------------------------------------------------------------------


def _leaf_file(tmp_path, step, name):
    return tmp_path / f"step_{step:09d}" / f"{name}.npy"


def test_truncated_leaf_detected(tmp_path):
    t = tree()
    checkpoint.save(tmp_path, t, step=1)
    f = _leaf_file(tmp_path, 1, "a")
    f.write_bytes(f.read_bytes()[:-40])    # torn write: tail lost
    with pytest.raises(ValueError, match="corrupt"):
        checkpoint.restore(tmp_path, t)


def test_flipped_bytes_detected_by_crc(tmp_path):
    t = tree()
    checkpoint.save(tmp_path, t, step=1)
    f = _leaf_file(tmp_path, 1, "nested__c")
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF                        # same size/shape, wrong bits
    f.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="CRC mismatch"):
        checkpoint.restore(tmp_path, t)


def test_missing_leaf_file_detected(tmp_path):
    t = tree()
    checkpoint.save(tmp_path, t, step=1)
    _leaf_file(tmp_path, 1, "nested__b").unlink()
    with pytest.raises(ValueError, match="partial write"):
        checkpoint.restore(tmp_path, t)


def test_manifest_backcompat_without_integrity_fields(tmp_path):
    """Checkpoints written before nbytes/crc32 existed still restore —
    the integrity checks are keyed on field presence, shape always runs."""
    t = tree()
    checkpoint.save(tmp_path, t, step=1)
    mf = tmp_path / "step_000000001" / "manifest.json"
    manifest = json.loads(mf.read_text())
    for ent in manifest["leaves"]:
        del ent["nbytes"], ent["crc32"]
    mf.write_text(json.dumps(manifest))
    got, step, _ = checkpoint.restore(tmp_path, t)
    assert step == 1
    assert_tree_equal(t, got)
    # shape verification is unconditional even without the new fields
    bad = np.zeros((9, 9), np.float32)
    np.save(_leaf_file(tmp_path, 1, "a"), bad)
    with pytest.raises(ValueError, match="shape"):
        checkpoint.restore(tmp_path, t)


def test_overwrite_same_step_is_atomic(tmp_path):
    """Re-saving a step swaps via rename-aside: the second tree restores,
    and no .old_* scaffolding survives the swap."""
    t1, t2 = tree(seed=0), tree(seed=1)
    checkpoint.save(tmp_path, t1, step=4)
    checkpoint.save(tmp_path, t2, step=4)
    got, step, _ = checkpoint.restore(tmp_path, t1)
    assert step == 4
    assert_tree_equal(t2, got)
    assert not list(tmp_path.glob(".old_step_*"))
    assert not list(tmp_path.glob(".tmp_step_*"))
    # a stale rename-aside from a crashed earlier swap is cleaned up too
    (tmp_path / ".old_step_000000004").mkdir()
    checkpoint.save(tmp_path, t1, step=4)
    assert not list(tmp_path.glob(".old_step_*"))
    got, _, _ = checkpoint.restore(tmp_path, t1)
    assert_tree_equal(t1, got)
