"""Continuous-batching engine under the deterministic simulation harness.

Every test drives the scheduler step-by-step on CPU with tiny models and a
fake clock (see ``engine_sim.py``): invariants (no slot leaks, FIFO
fairness, monotone counters), bit-identical outputs vs single-request
serving, interrupt/power-gating behaviour, preemption replay, and the
headline property — continuous batching beats one-request-at-a-time
throughput on a staggered arrival trace.
"""

import jax
import jax.numpy as jnp
import pytest

from engine_sim import (FakeClock, Request, Simulator, burst_trace,
                        make_engine, make_requests, run_trace,
                        shared_prefix_requests, smoke_params,
                        staggered_trace)
from repro.core.power import PowerState
from repro.models import registry
from repro.serve.engine import ADMIT_LINE, COMPLETE_LINE


from engine_sim import tokens_of as _tokens  # shared across the suites


# -- the headline acceptance property -----------------------------------------


def test_continuous_batching_beats_sequential_and_is_bit_identical():
    """Staggered arrivals: higher tokens/s on the fake clock than serving
    one request at a time, with per-request outputs bit-identical."""
    trace_a = staggered_trace(make_requests(6), gap=2.0)
    trace_b = staggered_trace(make_requests(6), gap=2.0)
    _, cont = run_trace("granite_3_2b", trace_a, slots=3)
    _, seq = run_trace("granite_3_2b", trace_b, slots=3, sequential=True)
    assert cont.tokens_generated == seq.tokens_generated == 6 * 4
    assert cont.throughput > seq.throughput
    assert cont.elapsed < seq.elapsed
    assert _tokens(cont) == _tokens(seq)


@pytest.mark.parametrize(
    "arch", ["granite_3_2b",
             pytest.param("mamba2_370m", marks=pytest.mark.slow),
             pytest.param("recurrentgemma_2b", marks=pytest.mark.slow)])
def test_outputs_bit_identical_across_cache_families(arch):
    """The per-slot page is bit-independent of the other lanes for every
    cache family (KV ring, SSM state, Griffin hybrid)."""
    _, cont = run_trace(arch, staggered_trace(make_requests(5), gap=1.0),
                        slots=2)
    _, seq = run_trace(arch, staggered_trace(make_requests(5), gap=1.0),
                       slots=2, sequential=True)
    assert _tokens(cont) == _tokens(seq)


def test_engine_matches_raw_batch1_decode():
    """Engine greedy output == a hand-rolled batch-1 decode_step loop."""
    cfg, params = smoke_params("granite_3_2b")
    prompt, new = [5, 9, 13], 4
    step = jax.jit(lambda p, c, t: registry.decode_step(p, cfg, c, t))
    cache = registry.cache_init(cfg, 1, 32)
    tok = jnp.asarray([[prompt[0]]], jnp.int32)
    raw, fed = [], 0
    while len(raw) < new:
        logits, cache = step(params, cache, tok)
        fed += 1
        if fed < len(prompt):
            tok = jnp.asarray([[prompt[fed]]], jnp.int32)
        else:
            t = int(jnp.argmax(logits, -1)[0])
            raw.append(t)
            tok = jnp.asarray([[t]], jnp.int32)

    eng, _ = make_engine("granite_3_2b", slots=3)
    eng.submit(Request(id="x", prompt=prompt, max_new_tokens=new))
    eng.run_until_idle()
    assert eng.completed[0].tokens == raw


def test_chunked_prefill_bit_identical_and_fewer_steps():
    """``prefill_chunk > 1`` consumes long prompts in fewer scheduling
    steps without perturbing a single output token."""
    trace = lambda: staggered_trace(make_requests(5, prompt_len=9), gap=1.0)
    base_eng, base = run_trace("granite_3_2b", trace(), slots=2)
    chunk_eng, chunked = run_trace("granite_3_2b", trace(), slots=2,
                                   prefill_chunk=4)
    assert _tokens(chunk_eng) == _tokens(base_eng)
    assert chunked.steps < base.steps
    assert chunked.tokens_generated == base.tokens_generated


def test_sharing_and_chunked_prefill_bit_identical_to_sequential():
    """The full tentpole configuration — prefix sharing + chunked prefill —
    against the one-request-at-a-time no-sharing baseline: outputs must be
    bit-identical, sim-clock throughput strictly higher."""
    trace = lambda: staggered_trace(
        shared_prefix_requests(6, prefix_len=16, tail_len=3, new_tokens=4),
        gap=1.0)
    seq_eng, seq = run_trace("granite_3_2b", trace(), slots=2, max_len=40,
                             sequential=True)
    eng, rep = run_trace("granite_3_2b", trace(), slots=2, max_len=40,
                         page_size=8, prefill_chunk=4)
    assert _tokens(eng) == _tokens(seq_eng)
    assert rep.throughput > seq.throughput
    assert eng.stats()["pages"]["tokens_reused"] > 0


def test_decode_cadence_survives_chunked_prefill():
    """A decoding lane still emits exactly one token per step while another
    lane chunk-prefills a long prompt next to it."""
    eng, _ = make_engine(slots=2, prefill_chunk=4)
    first = Request(id="first", prompt=[3, 1], max_new_tokens=10)
    eng.submit(first)
    eng.step()                                 # past the 2-token prompt
    eng.submit(Request(id="big", prompt=list(range(1, 13)),
                       max_new_tokens=2))
    produced = []
    for _ in range(9):
        eng.step()
        produced.append(len(first.tokens))
    assert [b - a for a, b in zip(produced, produced[1:])] == [1] * 8


# -- scheduler invariants ------------------------------------------------------


def test_no_slot_leaks_and_engine_reusable():
    eng, clock = make_engine(slots=2)
    sim = Simulator(eng, burst_trace(make_requests(5)), clock)
    sim.run()
    assert eng.active == 0 and not eng.queue
    assert all(s is None for s in eng.slots)
    assert all(load == 0 for load in eng._bank_load.values())
    # the drained engine admits fresh work (slot pages reset correctly)
    more = Simulator(eng, burst_trace(make_requests(3, prefix="s")), clock)
    more.run()
    assert len(eng.completed) == 8


def test_fifo_fairness_under_saturation():
    """More requests than slots: admission and completion follow arrival
    order (equal-length requests cannot overtake each other)."""
    eng, clock = make_engine(slots=2)
    admitted = []
    eng.platform.interrupts.connect(ADMIT_LINE, lambda r: admitted.append(r.id))
    report = Simulator(eng, burst_trace(make_requests(7)), clock).run()
    want = [f"r{i}" for i in range(7)]
    assert admitted == want
    assert [r.id for r in report.completed] == want
    admit_times = [r.admit_time for r in report.completed]
    assert admit_times == sorted(admit_times)


def test_throughput_counters_monotone():
    eng, _ = make_engine(slots=2)
    for r in make_requests(4):
        eng.submit(r)
    seen = []
    while eng.busy:
        eng.step()
        seen.append((eng.steps, eng.tokens_generated,
                     eng.prompt_tokens_processed, len(eng.completed)))
    for a, b in zip(seen, seen[1:]):
        assert all(x <= y for x, y in zip(a, b))
    assert eng.tokens_generated == sum(len(r.tokens) for r in eng.completed)
    assert eng.prompt_tokens_processed == 4 * 3


def test_in_flight_decodes_never_stop_for_admissions():
    """A long request admitted first keeps producing a token every single
    engine step while later arrivals prefill into other lanes."""
    eng, clock = make_engine(slots=3)
    long = Request(id="long", prompt=[3, 1], max_new_tokens=12)
    eng.submit(long)
    produced = []
    late = make_requests(4, prefix="late")
    for step in range(14):
        if step in (3, 5, 7, 9):
            eng.submit(late[(step - 3) // 2])
        eng.step()
        produced.append(len(long.tokens))
    # after the 2-token prompt, every step emits exactly one token for `long`
    deltas = [b - a for a, b in zip(produced, produced[1:])]
    assert deltas[1:11] == [1] * 10


# -- admission control ---------------------------------------------------------


def test_queue_backpressure_rejects_when_full():
    eng, _ = make_engine(slots=2, queue_capacity=2)
    results = [eng.submit(r) for r in make_requests(5)]
    assert results == [True, True, False, False, False]
    assert eng.rejected == 3
    eng.run_until_idle()
    assert len(eng.completed) == 2


def test_oversized_request_raises():
    eng, _ = make_engine(slots=1, max_len=8)
    with pytest.raises(ValueError):
        eng.submit(Request(id="big", prompt=[1] * 6, max_new_tokens=6))


def test_duplicate_request_id_rejected():
    eng, _ = make_engine(slots=2)
    eng.submit(Request(id="dup", prompt=[1, 2, 3], max_new_tokens=2))
    with pytest.raises(ValueError, match="duplicate request id"):
        eng.submit(Request(id="dup", prompt=[9, 8, 7], max_new_tokens=2))
    eng.run_until_idle()
    with pytest.raises(ValueError, match="duplicate request id"):
        eng.submit(Request(id="dup", prompt=[4], max_new_tokens=1))


# -- XAIF interrupts + power gating -------------------------------------------


def test_completion_interrupts_and_callbacks():
    eng, clock = make_engine(slots=2)
    done = []
    eng.platform.interrupts.connect(COMPLETE_LINE, lambda r: done.append(r.id))
    reqs = make_requests(4)
    reqs[0].on_complete = lambda r: done.append(f"cb:{r.id}")
    Simulator(eng, burst_trace(reqs), clock).run()
    assert eng.platform.interrupts.count(COMPLETE_LINE) == 4
    assert eng.platform.interrupts.count(ADMIT_LINE) == 4
    assert "cb:r0" in done and done.count("r0") == 1


def test_bank_power_gating_follows_slot_occupancy():
    # 3 slots over 2 banks: slots 0,2 share bank0; slot 1 owns bank1
    eng, _ = make_engine(slots=3, n_banks=2)
    pm = eng.platform.power
    assert pm.state("bank0") is PowerState.CLOCK_GATED
    assert pm.state("bank1") is PowerState.CLOCK_GATED

    short = Request(id="short", prompt=[1, 2], max_new_tokens=1)
    long0 = Request(id="long0", prompt=[3, 4], max_new_tokens=6)
    long1 = Request(id="long1", prompt=[5, 6], max_new_tokens=6)
    for r in (long0, short, long1):   # slots 0, 1, 2 in submission order
        eng.submit(r)
    eng.step()
    assert pm.state("bank0") is PowerState.ON
    assert pm.state("bank1") is PowerState.ON
    while not short.tokens:
        eng.step()
    # `short` (slot 1, bank1) is done -> bank1 gated; bank0 still hosts both
    # long requests (slots 0 and 2) and must stay on
    assert pm.state("bank1") is PowerState.CLOCK_GATED
    assert pm.state("bank0") is PowerState.ON
    eng.run_until_idle()
    assert pm.state("bank0") is PowerState.CLOCK_GATED
    assert pm.state("bank1") is PowerState.CLOCK_GATED


# -- preemption-safe slot state ------------------------------------------------


def test_preemption_replay_is_bit_identical():
    baseline, rep = run_trace("granite_3_2b",
                              burst_trace(make_requests(5)), slots=2)
    eng, _ = make_engine(slots=2)
    for r in make_requests(5):
        eng.submit(r)
    for _ in range(4):
        eng.step()                      # mid-flight: slots hold partial state
    requeued = eng.preempt()
    assert requeued and eng.active == 0
    assert all(load == 0 for load in eng._bank_load.values())
    eng.run_until_idle()
    assert _tokens(rep) == {r.id: tuple(r.tokens) for r in eng.completed}


def test_journal_tracks_in_flight_requests():
    eng, _ = make_engine(slots=2)
    for r in make_requests(4):
        eng.submit(r)
    for _ in range(3):
        eng.step()
    inflight = {rec.request_id for rec in eng.journal.incomplete()}
    assert inflight == {"r0", "r1"}     # admitted but unfinished
    eng.run_until_idle()
    assert not eng.journal.incomplete()
    assert [rec.request_id for rec in eng.journal.completed()] == \
        [f"r{i}" for i in range(4)]
    rec = eng.journal.get("r2")
    assert list(rec.generated) == eng.completed[2].tokens


def test_drain_completed_releases_history_and_ids():
    eng, _ = make_engine(slots=2)
    eng.submit(Request(id="a", prompt=[1, 2], max_new_tokens=2))
    eng.run_until_idle()
    done = eng.drain_completed()
    assert [r.id for r in done] == ["a"]
    assert eng.completed == []
    with pytest.raises(KeyError):
        eng.journal.get("a")
    # the drained id is reusable (fresh request, fresh record)
    assert eng.submit(Request(id="a", prompt=[3, 4], max_new_tokens=2))
    eng.run_until_idle()
    assert len(eng.completed) == 1


def test_shared_platform_power_state_not_clobbered():
    """Two engines on one platform: neither construction nor one engine's
    eviction may gate a bank where the other still has live slot state."""
    from repro.core.platform import Platform, XHeepConfig

    platform = Platform(XHeepConfig(n_banks=8))
    eng1, _ = make_engine(slots=2, platform=platform)
    eng1.submit(Request(id="live", prompt=[1, 2], max_new_tokens=8))
    eng1.step()
    assert platform.power.state("bank0") is PowerState.ON
    # second engine, same platform: construction must not gate bank0
    eng2, _ = make_engine(slots=1, platform=platform)
    assert platform.power.state("bank0") is PowerState.ON
    # eng2 runs a short request through ITS bank0 slot and finishes; the
    # shared refcount keeps bank0 on because eng1 is still decoding there
    eng2.submit(Request(id="short", prompt=[5], max_new_tokens=1))
    eng2.run_until_idle()
    assert platform.power.state("bank0") is PowerState.ON
    eng1.run_until_idle()   # last holder leaves -> gated
    assert platform.power.state("bank0") is PowerState.CLOCK_GATED


def test_paged_backend_bit_identical_to_lane_backend():
    """The tentpole invariant: the paged pool + block-table decode emits
    exactly the tokens the PR 2 per-lane cache emits."""
    _, paged = run_trace("granite_3_2b",
                         staggered_trace(make_requests(5), gap=1.0), slots=2)
    lane_eng, lane = run_trace("granite_3_2b",
                               staggered_trace(make_requests(5), gap=1.0),
                               slots=2, paged=False)
    assert lane_eng.stats()["backend"] == "lanes"
    assert _tokens(paged) == _tokens(lane)


def test_async_dispatch_bit_identical_and_overlaps_on_sim_clock():
    """Async double-buffered dispatch: same tokens as synchronous stepping,
    strictly less fake time once host dispatch has a nonzero cost."""
    def run(async_on):
        eng, clock = make_engine(slots=3, async_dispatch=async_on)
        sim = Simulator(eng, staggered_trace(make_requests(6), gap=1.0),
                        clock, dispatch_time=1.0)
        return eng, sim.run()

    eng_a, rep_a = run(True)
    eng_s, rep_s = run(False)
    assert {r.id: tuple(r.tokens) for r in eng_a.completed} == \
        {r.id: tuple(r.tokens) for r in eng_s.completed}
    assert rep_a.tokens_generated == rep_s.tokens_generated
    assert rep_a.elapsed < rep_s.elapsed
    assert rep_a.throughput > 1.5 * rep_s.throughput


def test_async_dispatch_preempt_flushes_and_replays_bit_identical():
    """preempt() with a step in flight retires it first; replay reproduces
    the pre-preemption tokens bit-for-bit (journal cross-checked)."""
    base_eng, _ = run_trace("granite_3_2b", burst_trace(make_requests(5)),
                            slots=2)
    eng, _ = make_engine(slots=2, async_dispatch=True)
    for r in make_requests(5):
        eng.submit(r)
    for _ in range(4):
        eng.step()                 # leaves one dispatched, unretired step
    assert eng.busy
    requeued = eng.preempt()
    assert requeued and eng.active == 0
    eng.run_until_idle()
    assert _tokens(base_eng) == {r.id: tuple(r.tokens) for r in eng.completed}


def test_dedup_concurrent_identical_cold_prefills():
    """Two cold same-prefix requests: the second stalls on the first's
    in-flight pages and adopts them instead of recomputing the shared
    extent — and the outputs still match no-sharing sequential serving."""
    reqs = lambda: shared_prefix_requests(2, prefix_len=16, tail_len=3,
                                          new_tokens=4)
    eng, _ = run_trace("granite_3_2b", burst_trace(reqs()), slots=2,
                       max_len=40, page_size=8)
    seq_eng, _ = run_trace("granite_3_2b", burst_trace(reqs()), slots=2,
                           max_len=40, sequential=True)
    assert _tokens(eng) == _tokens(seq_eng)
    st = eng.stats()
    assert st["stalls"] > 0                    # the waiter actually waited
    assert st["rematches"] > 0                 # ... then adopted the pages
    total_prompt = sum(len(r.prompt) for r in eng.completed)
    # the shared extent ran once: everything else was reused, not recomputed
    assert st["prompt_tokens_processed"] + st["prompt_tokens_reused"] \
        == total_prompt
    assert st["prompt_tokens_reused"] >= 16    # at least the shared pages


def test_midflight_rematch_adopts_sibling_pages():
    """A slot admitted on a cold table re-checks at page boundaries and
    adopts a sibling's freshly published pages (ROADMAP open item)."""
    reqs = shared_prefix_requests(3, prefix_len=16, tail_len=3, new_tokens=4)
    # staggered by one step: the second request is admitted before the
    # first has published anything, so only mid-flight re-match can help it
    eng, _ = run_trace("granite_3_2b", staggered_trace(reqs, gap=1.0),
                       slots=3, max_len=40, page_size=8)
    st = eng.stats()
    assert st["rematches"] > 0
    assert eng.rematched_tokens > 0
    assert eng.journal.get(reqs[1].id).rematched > 0
    seq_eng, _ = run_trace(
        "granite_3_2b",
        staggered_trace(shared_prefix_requests(3, prefix_len=16, tail_len=3,
                                               new_tokens=4), gap=1.0),
        slots=3, max_len=40, sequential=True)
    assert _tokens(eng) == _tokens(seq_eng)


def test_async_paged_sharing_full_stack_bit_identical():
    """Everything at once — paged pool, prefix sharing, chunked prefill,
    dedup, re-match, async dispatch — against plain sequential serving."""
    trace = lambda: staggered_trace(
        shared_prefix_requests(6, prefix_len=16, tail_len=3, new_tokens=4),
        gap=1.0)
    eng, _ = run_trace("granite_3_2b", trace(), slots=2, max_len=40,
                       page_size=8, prefill_chunk=4, async_dispatch=True)
    seq_eng, _ = run_trace("granite_3_2b", trace(), slots=2, max_len=40,
                           sequential=True)
    assert _tokens(eng) == _tokens(seq_eng)
    assert eng.stats()["pages"]["tokens_reused"] > 0


def test_pool_refcounts_drain_to_free_list():
    """Every pool page returns to the free list once slots and the table
    release it — the page-level bank_release discipline."""
    eng, clock = make_engine(slots=2, max_len=40, page_size=8)
    Simulator(eng, burst_trace(shared_prefix_requests(
        4, prefix_len=16, tail_len=3, new_tokens=4)), clock).run()
    pool = eng._pool
    # only table-resident pages may stay referenced, exactly once each
    assert pool.in_use == eng.pages.resident
    assert all(r == 1 for r in pool.refcounts().values())
    eng.pages.clear()
    assert pool.in_use == 0


def test_stats_report_pool_occupancy_and_free_list():
    """stats()["pool"] is the scheduler's source of truth: occupancy and
    free-list length are present and consistent with the pool at every
    step, and occupancy() mirrors the same numbers."""
    eng, _ = make_engine(slots=2, max_len=40, page_size=8)
    for r in shared_prefix_requests(3, prefix_len=16, tail_len=3,
                                    new_tokens=4, id_prefix="st"):
        eng.submit(r)
    while eng.busy:
        eng.step()
        pool = eng.stats()["pool"]
        assert pool["free"] + pool["in_use"] == pool["pages"]
        assert pool["free"] == eng._pool.free_count
        assert pool["occupancy"] == round(pool["in_use"] / pool["pages"], 4)
        assert pool["held_by_engine"] <= pool["in_use"]
        assert pool["shared"] is False           # engine-private pool
        occ = eng.occupancy()
        assert occ["pool_free"] == pool["free"]
        assert occ["active"] + occ["slots_free"] == occ["slots"]
        assert eng.step_cost() <= eng.active * eng.prefill_chunk
    assert eng.stats()["admission_stalls"] == 0  # no scheduler attached


def test_journal_detects_replay_divergence():
    """The determinism canary: a replay emitting a different token than the
    pre-preemption run must fail loudly, not silently diverge."""
    from repro.runtime.ft import RequestJournal

    j = RequestJournal()
    j.open("r", [1, 2], max_new_tokens=3)
    j.record_token("r", 10)
    j.record_token("r", 11)
    j.open("r", [1, 2], max_new_tokens=3)      # preempted -> replay
    j.record_token("r", 10)                    # matches original: fine
    with pytest.raises(RuntimeError, match="replay divergence"):
        j.record_token("r", 99)                # diverges from original 11
