"""Calibrated energy model vs every measured number in the paper."""

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the in-repo seeded-random subset
    from repro.testing.hypo import given, settings, strategies as st

from repro.core import energy as E
from repro.core.power import PowerDomain, PowerManager, PowerState


def approx(value, target, tol=0.025):
    assert abs(value - target) / target < tol, (value, target)


# -- §IV-C silicon envelope --------------------------------------------------

def test_sleep_32khz_270uw():
    approx(E.power_sleep_32khz(), 270.0, 0.02)


def test_max_corner_48mw():
    approx(E.power_max_470mhz_1v2() / 1000, 48.0, 0.02)


def test_processing_ladder():
    approx(E.power_processing(False) / 1000, 8.17, 0.02)   # all on
    approx(E.power_processing(True) / 1000, 7.68, 0.02)    # -6 %
    saving = 1 - E.power_processing(True) / E.power_processing(False)
    assert 0.05 < saving < 0.07


def test_acquisition_ladder():
    approx(E.power_acquisition(0), 384.0, 0.02)
    approx(E.power_acquisition(1), 310.0, 0.02)
    approx(E.power_acquisition(2), 286.0, 0.02)
    s1 = 1 - E.power_acquisition(1) / E.power_acquisition(0)
    assert 0.17 < s1 < 0.21  # paper: 19 %
    s2 = 1 - E.power_acquisition(2) / E.power_acquisition(1)
    assert 0.06 < s2 < 0.10  # paper: 8 %


def test_cgra_cnn_4mw():
    approx(E.power_cgra_cnn() / 1000, 4.01, 0.02)


# -- §IV-D DVFS ---------------------------------------------------------------

def test_dvfs_ratios():
    power, perf, en = E.dvfs_ratios()
    approx(power, 5.9, 0.02)
    approx(perf, 2.8, 0.02)
    approx(en, 2.1, 0.03)


# -- Fig. 6 CGRA benefit -------------------------------------------------------

def test_cgra_benefit_4_9x():
    approx(E.cgra_benefit(), 4.9, 0.02)


# -- §VI peripheral trim -------------------------------------------------------

def test_gp_peripheral_trim():
    assert abs(E.gp_trim_saving(E.HEARTBEAT) - 0.27) < 0.015
    assert abs(E.gp_trim_saving(E.SEIZURE) - 0.03) < 0.015


# -- Fig. 5 orderings ----------------------------------------------------------

def test_fig5_heartbeat_ordering():
    m = E.mcu_models()
    tot = {k: sum(v.app_energy_mj(E.HEARTBEAT)) for k, v in m.items()}
    assert tot["apollo3_blue"] < tot["heepocrates"] < tot["gap9"]


def test_fig5_seizure_ordering():
    m = E.mcu_models()
    tot = {k: sum(v.app_energy_mj(E.SEIZURE)) for k, v in m.items()}
    assert tot["gap9"] < tot["heepocrates"] < tot["apollo3_blue"]
    # processing-phase ordering (paper §VI text)
    proc = {k: v.app_energy_mj(E.SEIZURE)[1] for k, v in m.items()}
    assert proc["gap9"] < proc["heepocrates"] < proc["apollo3_blue"]


def test_always_on_leakage_split_35_65():
    pm = E.build_heepocrates_pm()
    ess = pm.domains["ao_essential"].leak_uw
    gp = pm.domains["ao_gp_periph"].leak_uw
    total = ess + gp
    approx(ess / total, 0.35, 0.02)
    approx(gp / total, 0.65, 0.02)


def test_retention_saves_42_5_percent():
    d = PowerDomain("bank", leak_uw=10.0, retainable=True)
    on = d.power_uw(PowerState.CLOCK_GATED, 0, 0)
    ret = d.power_uw(PowerState.RETENTION, 0, 0)
    approx(1 - ret / on, 0.425, 0.01)


# -- power-manager semantics (property) ----------------------------------------

@settings(max_examples=100, deadline=None)
@given(leak=st.floats(0.1, 100), idle=st.floats(0, 10), act=st.floats(0, 100),
       duty=st.floats(0, 1), freq=st.floats(0.01, 500))
def test_power_state_monotonicity(leak, idle, act, duty, freq):
    act = max(act, idle)  # active switching >= idle clock tree
    d = PowerDomain("x", leak_uw=leak, idle_dyn_uw_mhz=idle,
                    active_dyn_uw_mhz=act, retainable=True)
    p_off = d.power_uw(PowerState.OFF, duty, freq)
    p_ret = d.power_uw(PowerState.RETENTION, duty, freq)
    p_cg = d.power_uw(PowerState.CLOCK_GATED, duty, freq)
    p_on = d.power_uw(PowerState.ON, duty, freq)
    assert p_off <= p_ret <= p_cg <= p_on + 1e-9


def test_power_manager_rejects_invalid_retention():
    pm = PowerManager([PowerDomain("cpu", leak_uw=1.0)])
    with pytest.raises(ValueError):
        pm.set_state("cpu", PowerState.RETENTION)


def test_xaif_power_port_attach():
    pm = E.build_heepocrates_pm()
    before = pm.leakage_uw()
    pm.add_domain(PowerDomain("my_accel", leak_uw=7.0))
    assert pm.leakage_uw() == pytest.approx(before + 7.0)
    pm.set_state("my_accel", PowerState.OFF)
    assert pm.leakage_uw() == pytest.approx(before)
