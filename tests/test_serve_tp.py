"""Tensor-parallel + replica-group serving: multi-device subprocess tests.

The sharded-serving acceptance gates, each in a fresh process with forced
host devices (the main test process keeps 1 device):

* tp=2 head-sharded paged decode is bit-identical to the single-device
  engine for **every** paged-capable config — greedy and sampled, sync
  and async-dispatch, windowed (h2o danube) and not, modality stubs
  included. Sharding is a memory/latency move, never an output move.
* the sharded engine refuses configs the rule engine rejects (MoE here;
  the full rejection matrix lives in ``test_serve_tp_rules.py``).
* a 2x tp=2 replica group behind one ``ServeCluster`` target serves a
  seeded trace bit-identically to a standalone engine, with prefix
  affinity co-locating shared-prefix requests and the pool arenas split
  across all 4 devices — and the whole thing passes the PR 6 gate:
  driving the same seeded open-loop trace twice is bit-identical.
* draining a replica mid-flight migrates its journal records and queue
  onto siblings and the migrated requests complete bit-identically.

Mirrors the ``test_registry_serve.py`` tiering: granite stays in the
fast tier, the long tail and the 4-device tests run ``slow``.
"""

import pathlib

import pytest

from repro import configs
from repro.models import registry

TESTS = str(pathlib.Path(__file__).resolve().parent)

PAGED = [a for a in configs.names()
         if registry.supports_paged(configs.smoke(a))]
_FAST = {"granite_3_2b"}


def _tiered(names):
    return [a if a in _FAST else pytest.param(a, marks=pytest.mark.slow)
            for a in names]


_TP_BITID = """
import sys; sys.path.insert(0, {tests!r})
import jax
assert len(jax.devices()) == 2, jax.devices()
import engine_sim as es
from repro.launch.mesh import serve_tp_mesh
from repro.serve.sampling import SamplingParams

def reqs():
    rs = es.make_requests(4, prompt_len=5, new_tokens=4)
    rs[1].sampling = SamplingParams(temperature=0.8, top_p=0.9, seed=7)
    rs[3].sampling = SamplingParams(temperature=1.1, top_k=5, seed=11)
    return rs

for async_dispatch in (False, True):
    kw = dict(slots=2, max_len=32, async_dispatch=async_dispatch)
    ref = es.standalone_tokens({arch!r}, reqs(), **kw)
    got = es.standalone_tokens({arch!r}, reqs(), mesh=serve_tp_mesh(2), **kw)
    assert set(ref) == {{"r0", "r1", "r2", "r3"}}, ref
    assert got == ref, ("tp2 diverged", async_dispatch,
                        {{k: (got.get(k), ref[k]) for k in ref
                          if got.get(k) != ref[k]}})
print("TP_BITID_OK")
"""


@pytest.mark.parametrize("arch", _tiered(PAGED))
def test_tp2_bit_identical_to_single_device(arch, subproc):
    """Greedy + two sampled streams, sync and async dispatch: the
    head-sharded decode on a forced 2-device mesh reproduces the
    single-device engine token for token."""
    code = _TP_BITID.format(tests=TESTS, arch=arch)
    assert "TP_BITID_OK" in subproc(code, devices=2)


@pytest.mark.slow
def test_tp_mesh_rejects_lane_fallback_config(subproc):
    """The engine refuses to build a sharded MoE engine — the rule
    engine's rejection surfaces at construction, not as a silent lane
    fallback that ignores the mesh."""
    code = """
import sys; sys.path.insert(0, {tests!r})
import engine_sim as es
from repro.launch.mesh import serve_tp_mesh
from repro.serve.engine import ContinuousBatchingEngine

cfg, params = es.smoke_params("grok_1_314b")
try:
    ContinuousBatchingEngine(cfg, params, slots=2, max_len=32,
                             mesh=serve_tp_mesh(2))
except ValueError as e:
    assert "cannot serve tensor-parallel" in str(e), e
    print("TP_REJECT_OK")
else:
    raise AssertionError("sharded MoE engine built silently")
""".format(tests=TESTS)
    assert "TP_REJECT_OK" in subproc(code, devices=2)


_REPLICA_COMMON = """
import sys; sys.path.insert(0, {tests!r})
import jax
assert len(jax.devices()) == 4, jax.devices()
import engine_sim as es
from repro.launch.mesh import replica_meshes
from repro.serve.sampling import SamplingParams
from repro.serve.sim import ClusterSimulator, burst_trace, tag_engine

ARCH = "granite_3_2b"
cfg, params = es.smoke_params(ARCH)

def reqs():
    shared = es.shared_prefix_reqs("s", 6, prefix_len=16, tail_len=3,
                                   new_tokens=5)
    distinct = es.make_requests(6, prompt_len=5, new_tokens=5, prefix="d")
    for r in distinct[::2]:
        r.sampling = SamplingParams(temperature=0.9, top_k=7)
    return shared + distinct

ref = es.standalone_tokens(ARCH, reqs(), slots=3, max_len=40, page_size=8)
"""


@pytest.mark.slow
def test_replica_group_bit_identical_and_split(subproc):
    """2x tp=2 replicas behind one group name: bit-identical to the
    standalone engine, both replicas served, shared-prefix requests
    co-located by affinity, arenas resident on all 4 devices — and the
    same trace driven twice (PR 6 open-loop determinism gate) lands
    every request on the same replica with the same tokens."""
    code = _REPLICA_COMMON.format(tests=TESTS) + """
def drive():
    cluster, clock = es.make_cluster(pool_pages=96, page_size=8)
    members = cluster.add_replica_group(cfg, params, name="gran", slots=3,
                                        max_len=40,
                                        meshes=replica_meshes(2, 2),
                                        lane_batch=4, device_len=48)
    trace = tag_engine(burst_trace(reqs()), "gran")
    ClusterSimulator(cluster, trace, clock).run()
    toks = {}
    for n in members:
        toks.update(es.tokens_of(cluster.engines[n]))
    by_member = {n: sorted(r.id for r in cluster.engines[n].completed)
                 for n in members}
    return cluster, members, toks, by_member

cluster, members, got, by_member = drive()
assert got == ref, {k: (got.get(k), ref[k]) for k in ref
                    if got.get(k) != ref[k]}
assert all(by_member.values()), by_member
# prefix affinity: every shared-prefix request lands on one home replica
homes = [n for n, ids in by_member.items()
         if any(i.startswith("s") for i in ids)]
assert len(homes) == 1, by_member
by_dev = cluster.pool.bytes_by_device()
assert len(by_dev) == 4 and len(set(by_dev.values())) == 1, by_dev

# PR 6 determinism gate: a second fresh drive is bit-identical, same homes
_, _, got2, by_member2 = drive()
assert got2 == got and by_member2 == by_member
print("REPLICA_OK")
"""
    assert "REPLICA_OK" in subproc(code, devices=4)


@pytest.mark.slow
def test_drain_replica_migrates_bit_identically(subproc):
    """Mid-flight drain: the victim's journal records and queue move to
    the sibling, every migrated request finishes with the reference
    tokens, and the victim's page namespace is fully evicted."""
    code = _REPLICA_COMMON.format(tests=TESTS) + """
cluster, clock = es.make_cluster(pool_pages=96, page_size=8)
members = cluster.add_replica_group(cfg, params, name="g2", slots=2,
                                    max_len=40, meshes=replica_meshes(2, 2),
                                    lane_batch=4, device_len=48)
rs = reqs()
for r in rs:
    r.arrival_time = clock.t
    assert cluster.submit("g2", r)
for _ in range(4):                      # tokens in flight on both members
    cluster.step()
victim = members[0]
pre_done = {r.id for r in cluster.engines[victim].completed}
moved = cluster.drain_replica("g2", victim)
assert victim not in cluster.engines
assert victim not in cluster.stats()["groups"]["g2"]
assert cluster.migrations > 0, "drain migrated nothing in-flight"
# the victim only owned its routed share; all of it must have moved
assert sum(len(v) for v in moved.values()) > 0, moved
cluster.run_until_idle()
got = {}
for n in members[1:]:
    got.update(es.tokens_of(cluster.engines[n]))
for rid in pre_done:                    # finished-before-drain stay put
    got.setdefault(rid, ref[rid])
assert got == ref, {k: (got.get(k), ref[k]) for k in ref
                    if got.get(k) != ref[k]}
assert not any(ns.endswith("@r0") for ns in cluster.table.resident_by_ns())
print("MIGRATE_OK")
"""
    assert "MIGRATE_OK" in subproc(code, devices=4)
