"""Energy-metered serving: joule attribution, conservation, and policies.

The tentpole invariants the meter is held to, each driven through the
real engine/cluster stack:

* **Conservation** — over any trace, the platform energy integral splits
  exactly: ``total_uj == attributed_uj + overhead_uj`` and
  ``attributed_uj == Σ Request.energy_uj`` over every submitted request
  (property-tested over randomised op sequences, hypothesis or the
  seeded in-repo fallback).
* **Observability only** — metering, DVFS points, and idle-bank gating
  change *when* energy is charged, never *what* the engine computes:
  completed tokens are bit-identical to an unmetered run across the
  paged/lanes/async/windowed backends.
* **Attribution is physical** — non-negative, monotone per step,
  shared-prefix holding costs split ``1/refcount``, replay energy after
  a preemption or crash is charged on top (like latency), and
  accumulated joules survive a crash rebuild.
* **Policies act on the meter** — the DVFS throttle admits by dropping
  the operating point instead of stalling; energy-aware admission sheds
  heads whose projected joules/token busts their cap.
"""

import dataclasses
import pathlib

import pytest

from engine_sim import (CANONICAL, ClusterSimulator, FakeClock, PowerBudget,
                        Request, Simulator, add_smoke_engine, burst_trace,
                        make_cluster, make_engine, make_requests,
                        shared_prefix_reqs, smoke_params, standalone_tokens,
                        staggered_trace, tag_engine, tokens_of)
from repro.core import energy
from repro.runtime.ft import FTConfig
from repro.serve.cluster import SchedPolicy
from repro.serve.energy_meter import EnergyMeter
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.loadgen import TenantSpec
from repro.serve.metrics import SLO, ServeMetrics
from repro.serve.sampling import SamplingParams

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover - env dependent
    from repro.testing.hypo import given, settings, strategies as st

TESTS = str(pathlib.Path(__file__).resolve().parent)

SAMPLED = SamplingParams(temperature=0.9, top_k=20, top_p=0.9, seed=5)

# engine.stats() keys that are lifetime counters (monotone by contract);
# gauges like `queued`/`active`/`journal` are deliberately absent
COUNTER_KEYS = ("steps", "tokens_generated", "prompt_tokens_processed",
                "prompt_tokens_reused", "pages_recycled", "stalls",
                "admission_stalls", "rematches", "rematched_tokens",
                "completed", "sampled_requests", "rejected", "shed",
                "token_faults", "replays")
ENERGY_COUNTER_KEYS = ("total_uj", "attributed_uj", "overhead_uj",
                       "prefill_uj", "decode_uj", "pages_uj", "retention_uj",
                       "host_uj", "idle_uj", "dvfs_switches")


def _counters(eng) -> list:
    stats = eng.stats()
    vals = [stats[k] for k in COUNTER_KEYS]
    if "energy" in stats:
        vals += [stats["energy"][k] for k in ENERGY_COUNTER_KEYS]
    return vals


def _assert_conserved(eng, requests) -> None:
    """The meter's double-entry bookkeeping balances exactly."""
    stats = eng.stats()["energy"]
    assert stats["total_uj"] == pytest.approx(
        stats["attributed_uj"] + stats["overhead_uj"], rel=1e-12)
    assert stats["attributed_uj"] == pytest.approx(
        stats["prefill_uj"] + stats["decode_uj"] + stats["pages_uj"]
        + stats["retention_uj"], rel=1e-12)
    assert stats["attributed_uj"] == pytest.approx(
        sum(r.energy_uj for r in requests), rel=1e-9)
    assert all(r.energy_uj >= 0.0 for r in requests)


# ---------------------------------------------------------------------------
# Operating points and the meter in isolation
# ---------------------------------------------------------------------------


def test_operating_point_registry_and_validation():
    nominal = energy.operating_point("nominal")
    assert nominal.voltage == 0.8
    # 0.8 V is the calibration anchor: both scales are exactly 1 there
    assert nominal.leak_scale == pytest.approx(1.0)
    assert nominal.dyn_scale == pytest.approx(1.0)
    top = energy.operating_point("max")
    assert top.freq_mhz > nominal.freq_mhz and top.voltage > nominal.voltage
    with pytest.raises(ValueError, match="unknown operating point"):
        energy.operating_point("turbo")
    with pytest.raises(ValueError, match="unknown operating point"):
        EnergyMeter(point="turbo")


def test_meter_projection_matches_calibrated_dvfs_ratio():
    """Marginal joules/token at max vs nominal must land on the paper's
    §IV-D energy-per-work ratio (dvfs_ratios()[2], ~2.1x) — the meter
    derives it from the same leak/dyn scaling laws, so a drift here means
    the meter and the calibrated model diverged."""
    meter = EnergyMeter(point="max")
    at_max = meter.projected_uj_per_token()
    meter.set_point("nominal")
    at_nominal = meter.projected_uj_per_token()
    assert meter.dvfs_switches == 1
    meter.set_point("nominal")             # no-op: same point, no switch
    assert meter.dvfs_switches == 1
    _, _, energy_ratio = energy.dvfs_ratios()
    assert at_max / at_nominal == pytest.approx(energy_ratio, rel=0.02)


def test_unmetered_engine_has_no_energy_surface():
    eng, clock = make_engine(metered=False)
    reqs = make_requests(2, prompt_len=3, new_tokens=3)
    Simulator(eng, burst_trace(reqs), clock).run()
    assert "energy" not in eng.stats()
    assert all(r.energy_uj == 0.0 for r in reqs)
    with pytest.raises(ValueError, match="metered=False"):
        eng.set_operating_point("nominal")


# ---------------------------------------------------------------------------
# Conservation and attribution properties
# ---------------------------------------------------------------------------


@pytest.mark.properties
@settings(max_examples=8, deadline=None)
@given(n=st.integers(min_value=1, max_value=5),
       prompt_len=st.integers(min_value=2, max_value=6),
       new_tokens=st.integers(min_value=1, max_value=6),
       gap=st.sampled_from([0.0, 1.0, 2.5]),
       point=st.sampled_from(["max", "nominal"]),
       gate=st.booleans(), paged=st.booleans())
def test_energy_conservation_over_random_traces(n, prompt_len, new_tokens,
                                                gap, point, gate, paged):
    """Σ per-request joules + accounted overhead ≡ total platform energy,
    over randomised trace shapes, operating points, gating modes, and
    backends — and the simulator report agrees with the meter."""
    eng, clock = make_engine(slots=3, max_len=32, paged=paged,
                             page_size=8 if paged else None,
                             operating_point=point, gate_idle_banks=gate)
    reqs = make_requests(n, prompt_len=prompt_len, new_tokens=new_tokens)
    report = Simulator(eng, staggered_trace(reqs, gap=gap), clock).run()
    assert len(report.completed) == n
    _assert_conserved(eng, reqs)
    stats = eng.stats()["energy"]
    assert stats["point"] == point
    # fresh engine: the report's delta is the meter's lifetime total
    assert report.energy_uj == pytest.approx(stats["total_uj"], rel=1e-12)
    assert report.tokens_per_joule > 0
    # every request that produced tokens carries a positive attribution
    assert all(r.energy_uj > 0.0 for r in report.completed)


@pytest.mark.parametrize("backend", ["paged-async", "paged-sync", "lanes",
                                     "windowed"])
def test_metering_never_changes_tokens(backend):
    """Bit-identity across the meter's entire configuration space: off,
    default, DVFS-throttled, and ungated idle banks must all produce the
    same tokens on every backend."""
    kwargs = {"paged-async": dict(page_size=8, async_dispatch=True),
              "paged-sync": dict(page_size=8),
              "lanes": dict(paged=False)}

    def drive(**meter_kw):
        if backend == "windowed":
            cfg0, params = smoke_params()
            cfg = dataclasses.replace(cfg0, name=f"{cfg0.name}-swa8",
                                      sliding_window=8)
            clock = FakeClock()
            eng = ContinuousBatchingEngine(
                cfg, params, slots=2, max_len=40, clock=clock, page_size=8,
                lane_batch=CANONICAL["lane_batch"],
                device_len=CANONICAL["device_len"], **meter_kw)
            reqs = [Request(id=f"w{i}",
                            prompt=[(3 * i + j) % 150 + 1 for j in range(12)],
                            max_new_tokens=16)
                    for i in range(2)]
        else:
            eng, clock = make_engine(slots=3, max_len=32, **kwargs[backend],
                                     **meter_kw)
            reqs = make_requests(5, prompt_len=4, new_tokens=6)
        Simulator(eng, staggered_trace(reqs), clock).run()
        return tokens_of(eng)

    want = drive(metered=False)
    assert drive() == want
    assert drive(operating_point="nominal") == want
    assert drive(gate_idle_banks=False) == want


def test_energy_monotone_per_step():
    """All energy buckets — and every request's attribution — only ever
    grow as the engine steps."""
    eng, clock = make_engine(slots=2, max_len=32, page_size=8)
    reqs = make_requests(4, prompt_len=4, new_tokens=6)
    for r in reqs:
        r.arrival_time = clock.t
        assert eng.submit(r)
    prev = _counters(eng)
    prev_req = [r.energy_uj for r in reqs]
    while eng.busy:
        eng.step()
        clock.advance(0.5)
        cur = _counters(eng)
        cur_req = [r.energy_uj for r in reqs]
        assert all(b >= a for a, b in zip(prev, cur)), (prev, cur)
        assert all(b >= a for a, b in zip(prev_req, cur_req))
        prev, prev_req = cur, cur_req
    _assert_conserved(eng, reqs)


def test_retention_accrues_on_the_fake_clock():
    """Idle-retention is clock-time energy: a simulated run whose steps
    take time charges resident slots (and their held pages) between
    launches; the default frozen clock charges none."""
    eng, clock = make_engine(slots=2, max_len=32, page_size=8)
    reqs = make_requests(3, prompt_len=4, new_tokens=6)
    Simulator(eng, staggered_trace(reqs), clock, step_time=1.0).run()
    stats = eng.stats()["energy"]
    assert stats["retention_uj"] > 0.0
    assert stats["pages_uj"] > 0.0
    _assert_conserved(eng, reqs)

    frozen, _ = make_engine(slots=2, max_len=32, page_size=8)
    for r in make_requests(3, prompt_len=4, new_tokens=6):
        frozen.submit(r)
    frozen.run_until_idle()
    assert frozen.stats()["energy"]["retention_uj"] == 0.0


def test_shared_prefix_adopters_pay_less_than_the_payer():
    """Prefix sharing shows up in the attribution: the request that
    prefills the shared pages pays their compute; adopters skip it and
    split the holding cost 1/refcount, so each adopter's total is
    strictly below the payer's."""
    eng, clock = make_engine(slots=3, max_len=40, page_size=8,
                             prefill_chunk=4)
    reqs = shared_prefix_reqs("s", 4, prefix_len=16, tail_len=3,
                              new_tokens=4)
    Simulator(eng, staggered_trace(reqs), clock).run()
    _assert_conserved(eng, reqs)
    assert eng.prompt_tokens_reused > 0, "workload never shared"
    payer, *adopters = reqs
    assert all(payer.energy_uj > a.energy_uj for a in adopters), (
        [r.energy_uj for r in reqs])


def test_gating_and_dvfs_reduce_energy_not_tokens():
    """The benchmark's policy ordering, asserted at test scale: host-only
    burns more than clock-gated, nominal burns less than max — on
    bit-identical outputs."""
    def drive(**meter_kw):
        eng, clock = make_engine(slots=2, max_len=32, page_size=8,
                                 n_banks=4, **meter_kw)
        reqs = make_requests(4, prompt_len=4, new_tokens=6)
        report = Simulator(eng, staggered_trace(reqs, gap=2.0), clock).run()
        return tokens_of(eng), report.energy_uj

    gated_toks, gated = drive()
    host_toks, host_only = drive(gate_idle_banks=False)
    dvfs_toks, throttled = drive(operating_point="nominal")
    assert gated_toks == host_toks == dvfs_toks
    assert host_only > gated > throttled > 0.0


# ---------------------------------------------------------------------------
# Metrics and report plumbing
# ---------------------------------------------------------------------------


def test_serve_metrics_energy_summary():
    eng, clock = make_engine(slots=2, max_len=32)
    reqs = make_requests(4, prompt_len=3, new_tokens=5)
    report = Simulator(eng, staggered_trace(reqs), clock).run()
    m = ServeMetrics()
    m.observe_all(report.completed)
    out = m.summary(elapsed=report.elapsed)
    attributed = sum(r.energy_uj for r in report.completed)
    assert out["energy_uj_total"] == pytest.approx(attributed, rel=1e-12)
    assert out["energy_uj_p50"] <= out["energy_uj_p99"]
    assert out["uj_per_token"] == pytest.approx(
        attributed / out["total_tokens"], rel=1e-12)
    assert out["tokens_per_joule"] == pytest.approx(
        out["total_tokens"] / (attributed * 1e-6), rel=1e-12)

    unmetered = ServeMetrics()
    eng2, clock2 = make_engine(slots=2, max_len=32, metered=False)
    reqs2 = make_requests(4, prompt_len=3, new_tokens=5)
    rep2 = Simulator(eng2, staggered_trace(reqs2), clock2).run()
    unmetered.observe_all(rep2.completed)
    assert "energy_uj_p50" not in unmetered.summary()
    assert "tokens_per_joule" not in unmetered.summary()


def test_cluster_report_sums_engine_meters():
    cluster, clock = make_cluster(pool_pages=48, page_size=8)
    add_smoke_engine(cluster, name="a", namespace="granite")
    add_smoke_engine(cluster, name="b", namespace="granite",
                     metered=False)
    trace = (tag_engine(burst_trace(
                 make_requests(3, prompt_len=3, new_tokens=4,
                               prefix="a")), "a")
             + tag_engine(burst_trace(
                 make_requests(3, prompt_len=3, new_tokens=4,
                               prefix="b")), "b"))
    report = ClusterSimulator(cluster, trace, clock).run()
    meter = cluster.engines["a"]._meter
    assert report.energy_uj == pytest.approx(meter.total_uj, rel=1e-12)
    assert report.tokens_per_joule > 0
    agg = cluster.stats()["energy"]
    assert agg["metered_engines"] == 1
    assert agg["total_uj"] == pytest.approx(meter.total_uj, rel=1e-12)


def test_tenant_spec_stamps_energy_cap_without_perturbing_the_stream():
    """The cap rides on generated requests and costs zero RNG draws, so a
    capped trace is otherwise byte-identical to the uncapped one."""
    from repro.serve.loadgen import open_loop_trace

    with pytest.raises(ValueError, match="energy_cap"):
        TenantSpec(engine="e", energy_cap_uj_per_token=0.0)
    plain = TenantSpec(engine="e")
    capped = dataclasses.replace(plain, energy_cap_uj_per_token=3.0)
    a = list(open_loop_trace([plain], n_requests=50, rate=10.0, seed=7))
    b = list(open_loop_trace([capped], n_requests=50, rate=10.0, seed=7))
    assert all(x.request.energy_cap_uj_per_token is None for x in a)
    assert all(x.request.energy_cap_uj_per_token == 3.0 for x in b)
    assert [(x.time, x.request.prompt, x.request.max_new_tokens)
            for x in a] == [(x.time, x.request.prompt,
                             x.request.max_new_tokens) for x in b]


# ---------------------------------------------------------------------------
# Energy-aware policies
# ---------------------------------------------------------------------------


def _shed_drive(budget=None, request_cap=None, **eng_kw):
    cluster, clock = make_cluster(pool_pages=48, page_size=8,
                                  power_budget=budget)
    eng = add_smoke_engine(cluster, name="e", namespace="granite", **eng_kw)
    reqs = make_requests(3, prompt_len=3, new_tokens=4)
    if request_cap is not None:
        for r in reqs:
            r.energy_cap_uj_per_token = request_cap
    ClusterSimulator(cluster, tag_engine(burst_trace(reqs), "e"), clock).run()
    return cluster, eng


def test_energy_cap_sheds_above_projection_admits_below():
    # projected ~4.4 uJ/token at "max" busts a 3.0 cap: every head shed
    cluster, eng = _shed_drive(budget=PowerBudget(max_uj_per_token=3.0))
    assert cluster.energy_sheds == 3 and eng.shed == 3
    assert not eng.completed
    # the same cap at "nominal" (~2.1 uJ/token) admits everything
    cluster, eng = _shed_drive(budget=PowerBudget(max_uj_per_token=3.0),
                               operating_point="nominal")
    assert cluster.energy_sheds == 0 and len(eng.completed) == 3
    # an unmetered engine has no projection to compare: cap never binds
    cluster, eng = _shed_drive(budget=PowerBudget(max_uj_per_token=3.0),
                               metered=False)
    assert cluster.energy_sheds == 0 and len(eng.completed) == 3


def test_per_request_energy_cap_overrides_cluster_default():
    # a loose per-request cap wins over a busting cluster-wide default
    cluster, eng = _shed_drive(budget=PowerBudget(max_uj_per_token=3.0),
                               request_cap=10.0)
    assert cluster.energy_sheds == 0 and len(eng.completed) == 3
    # and a tight per-request cap sheds even without any cluster budget
    cluster, eng = _shed_drive(request_cap=1.0)
    assert cluster.energy_sheds == 3 and not eng.completed


def test_power_budget_dvfs_throttle_admits_instead_of_stalling():
    """With a throttle point, the first budget violation drops the engine
    to the lower DVFS point and admits; outputs stay bit-identical and
    the throttle is observable end to end (cluster counter, meter point,
    meter switch count)."""
    def reqs(prefix):
        return make_requests(4, prompt_len=3, new_tokens=4, prefix=prefix)

    want_a = standalone_tokens("granite_3_2b", reqs("a"))
    want_b = standalone_tokens("granite_3_2b", reqs("b"))
    cluster, clock = make_cluster(
        power_budget=PowerBudget(max_awake_banks=1,
                                 throttle_point="nominal"))
    ea = add_smoke_engine(cluster, name="x", namespace="granite")
    eb = add_smoke_engine(cluster, name="y", namespace="granite")
    sim = ClusterSimulator(
        cluster,
        tag_engine(burst_trace(reqs("a")), "x")
        + tag_engine(burst_trace(reqs("b")), "y"),
        clock)
    sim.run()
    assert cluster.dvfs_throttles >= 1
    switches = (ea._meter.dvfs_switches + eb._meter.dvfs_switches)
    assert switches == cluster.dvfs_throttles
    assert {"nominal"} >= {e._meter.point.name for e in (ea, eb)
                           if e._meter.dvfs_switches}
    assert tokens_of(ea) == want_a and tokens_of(eb) == want_b


def test_throttled_admission_is_exempt_without_a_throttle_point():
    """Without a throttle point the budget stalls exactly as before — the
    PR 10 levers must not change the default envelope semantics."""
    cluster, clock = make_cluster(
        power_budget=PowerBudget(max_awake_banks=1))
    add_smoke_engine(cluster, name="x", namespace="granite")
    add_smoke_engine(cluster, name="y", namespace="granite")
    sim = ClusterSimulator(
        cluster,
        tag_engine(burst_trace(make_requests(4, prefix="a")), "x")
        + tag_engine(burst_trace(make_requests(4, prefix="b")), "y"),
        clock)
    sim.run()
    assert cluster.power_stalls > 0
    assert cluster.dvfs_throttles == 0


# ---------------------------------------------------------------------------
# Attribution under preemption, replay, and crash recovery
# ---------------------------------------------------------------------------


def test_slo_preempt_replay_charges_energy_on_top():
    """A preempted-and-requeued request replays its prefix through the
    journal; the replayed device work is real work, so its attribution
    exceeds the undisturbed run's — while the tokens stay bit-identical
    and the journal records exactly one preemption."""
    def drive(policy):
        cluster, clock = make_cluster(pool_pages=48, page_size=8,
                                      policy=policy)
        eng = add_smoke_engine(cluster, name="g", namespace="granite",
                               slots=1, max_len=40)
        doomed = Request(id="long", prompt=[3, 4, 5], max_new_tokens=16,
                         slo=SLO(ttft=4.0, tpot=0.5))
        followers = make_requests(2, prompt_len=3, new_tokens=4, prefix="f")
        trace = tag_engine(burst_trace([doomed] + followers), "g")
        ClusterSimulator(cluster, trace, clock).run()
        _assert_conserved(eng, [doomed] + followers)
        return cluster, eng, doomed

    cluster, eng, doomed = drive(SchedPolicy(preempt_busted=True))
    assert doomed.slo_preempts == 1
    assert cluster.journal.journal("g").get("long").slo_preempts == 1
    _, plain_eng, undisturbed = drive(SchedPolicy())
    assert tokens_of(eng) == tokens_of(plain_eng)
    assert doomed.energy_uj > undisturbed.energy_uj


def test_crash_rebuild_carries_joules_and_counters_forward():
    """Kill engines with in-flight sampled and sliding-window requests:
    the rebuilt engines keep the same meter (accumulated joules and the
    operating point survive), every stats counter stays monotone across
    the crash, conservation holds over the replayed requests, and the
    recovered tokens are bit-identical to the fault-free run."""
    def build():
        # the watchdog keeps client request handles, so replay charges
        # land on the same objects the conservation sum ranges over
        cluster, clock = make_cluster(pool_pages=64, page_size=8,
                                      watchdog=FTConfig())
        add_smoke_engine(cluster, name="g", namespace="granite", slots=2,
                         max_len=40, prefill_chunk=2, page_size=8,
                         async_dispatch=True, operating_point="nominal")
        swa_cfg, swa_params = smoke_params()
        swa = dataclasses.replace(swa_cfg, name=f"{swa_cfg.name}-swa8",
                                  sliding_window=8)
        cluster.add_engine(swa, swa_params, name="w", namespace="swa",
                           slots=2, max_len=40,
                           lane_batch=CANONICAL["lane_batch"],
                           device_len=CANONICAL["device_len"])
        g = shared_prefix_reqs("s", 3, prefix_len=16, tail_len=3,
                               new_tokens=5)
        g += [Request(id=f"x{i}",
                      prompt=[(5 * i + j) % 200 + 1 for j in range(4)],
                      max_new_tokens=6,
                      sampling=dataclasses.replace(SAMPLED))
              for i in range(3)]
        w = [Request(id=f"w{i}",
                     prompt=[(3 * i + j) % 150 + 1 for j in range(12)],
                     max_new_tokens=16)
             for i in range(2)]
        trace = list(tag_engine(staggered_trace(g, gap=1.0), "g"))
        trace += list(tag_engine(staggered_trace(w, gap=1.0), "w"))
        trace.sort(key=lambda a: a.time)
        return cluster, clock, trace, {"g": g, "w": w}

    base, bclock, btrace, _ = build()
    ClusterSimulator(base, btrace, bclock).run()
    want = {n: tokens_of(e) for n, e in base.engines.items()}

    cluster, clock, trace, reqs = build()
    sim = ClusterSimulator(cluster, trace, clock)
    for _ in range(12):
        sim._deliver_due()
        if cluster.busy:
            cluster.step()
        clock.advance(1.0)
    assert cluster.engines["g"].active > 0
    assert cluster.engines["w"].active > 0
    pre = {n: _counters(e) for n, e in cluster.engines.items()}
    meters = {n: e._meter for n, e in cluster.engines.items()}
    cluster.crash_engine("g")
    cluster.crash_engine("w")
    for n, e in cluster.engines.items():
        assert e._meter is meters[n], "rebuild must keep the meter object"
        assert e._meter.point.name == ("nominal" if n == "g" else "max")
        post = _counters(e)
        assert all(b >= a for a, b in zip(pre[n], post)), (n, pre[n], post)
    sim.run()
    assert {n: tokens_of(e) for n, e in cluster.engines.items()} == want
    for n, e in cluster.engines.items():
        final = _counters(e)
        assert all(b >= a for a, b in zip(pre[n], final))
        _assert_conserved(e, reqs[n])


@pytest.mark.slow
def test_replica_member_crash_recovers_bit_identically(subproc):
    """PR 8 x PR 9 cross-feature: crash one tp=2 member of a 2-replica
    group mid-flight (4 forced host devices); the journal rebuild lands
    on the same sharded member, the recovered tokens match the standalone
    reference, no request double-completes, and each member's meter
    balances over its completed requests."""
    code = """
import sys; sys.path.insert(0, {tests!r})
import jax
assert len(jax.devices()) == 4, jax.devices()
import engine_sim as es
from repro.launch.mesh import replica_meshes
from repro.runtime.ft import FTConfig
from repro.serve.sampling import SamplingParams
from repro.serve.sim import ClusterSimulator, burst_trace, tag_engine

ARCH = "granite_3_2b"
cfg, params = es.smoke_params(ARCH)

def reqs():
    shared = es.shared_prefix_reqs("s", 6, prefix_len=16, tail_len=3,
                                   new_tokens=5)
    distinct = es.make_requests(6, prompt_len=5, new_tokens=5, prefix="d")
    for r in distinct[::2]:
        r.sampling = SamplingParams(temperature=0.9, top_k=7)
    return shared + distinct

ref = es.standalone_tokens(ARCH, reqs(), slots=3, max_len=40, page_size=8)

cluster, clock = es.make_cluster(pool_pages=96, page_size=8,
                                 watchdog=FTConfig())
members = cluster.add_replica_group(cfg, params, name="gran", slots=3,
                                    max_len=40, meshes=replica_meshes(2, 2),
                                    lane_batch=4, device_len=48)
sim = ClusterSimulator(cluster, tag_engine(burst_trace(reqs()), "gran"),
                       clock)
for _ in range(6):                      # run partway: work is in flight
    sim._deliver_due()
    if cluster.busy:
        cluster.step()
    clock.advance(1.0)
victim = max(members, key=lambda n: cluster.engines[n].active)
assert cluster.engines[victim].active > 0, "nothing in flight to recover"
pre_uj = {{n: cluster.engines[n]._meter.total_uj for n in members}}
cluster.crash_engine(victim)
assert cluster.crashes == cluster.rebuilds == 1
assert cluster.engines[victim]._meter.total_uj >= pre_uj[victim]
sim.run()                               # drain through the rebuilt member

got = {{}}
for n in members:
    got.update(es.tokens_of(cluster.engines[n]))
assert got == ref, {{k: (got.get(k), ref[k]) for k in ref
                     if got.get(k) != ref[k]}}
for n in members:
    eng = cluster.engines[n]
    ids = [r.id for r in eng.completed]
    assert len(ids) == len(set(ids)), "double completion"
    stats = eng.stats()["energy"]
    attributed = sum(r.energy_uj for r in eng.completed)
    assert abs(stats["attributed_uj"] - attributed) <= 1e-9 * max(
        stats["attributed_uj"], 1.0), (n, stats["attributed_uj"], attributed)
    assert stats["total_uj"] >= pre_uj[n]
print("CHAOS_TP_OK")
""".format(tests=TESTS)
    assert "CHAOS_TP_OK" in subproc(code, devices=4)
