"""Sliding-window configs on the paged backend (ring block tables).

The tentpole invariants of windowed paged serving, each driven through the
deterministic sim harness on tiny CPU models:

* ``stats()["backend"] == "paged"`` for SWA configs, and the outputs are
  **bit-identical to the lane ring cache** (``paged=False``) across window
  edge cases — window smaller than / equal to / not a multiple of
  ``page_size`` — including after ``preempt()`` + replay with ring
  recycling in flight.
* A long-running windowed slot holds **O(window/page_size)** device pages:
  the block table is a ring of ``ceil(window/page_size) + 1`` entries, and
  pages falling wholly outside the window are recycled (released, or
  disowned when they are adopted shared-prefix pages).
* Prefix sharing is **clamped to the window**: a shared prefix longer than
  the window still admits pre-consumed (no recompute), but only the pages
  the window can still see are pinned — sharing degrades gracefully, never
  wrongly.
* An SWA tenant participates in a :class:`~repro.serve.cluster.ServeCluster`
  on the shared pool under a :class:`PowerBudget`, bit-identically to the
  same engine running isolated.
"""

import dataclasses

import pytest

from engine_sim import (CANONICAL, FakeClock, PowerBudget, Request,
                        Simulator, add_smoke_engine, make_cluster,
                        make_engine, make_requests, smoke_params,
                        staggered_trace, tag_engine)
from repro import configs
from repro.models import registry
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.sim import ClusterSimulator, shared_prefix_requests


from engine_sim import tokens_of as _tokens  # shared across the suites


def swa_engine(window: int, *, slots: int = 2, max_len: int = 36,
               page_size: int = 8, **engine_kwargs):
    """An engine on the granite smoke model with ``sliding_window`` set.

    The replaced config reuses the cached granite smoke params (the window
    changes attention masking, never parameter shapes)."""
    cfg0, params = smoke_params("granite_3_2b")
    cfg = dataclasses.replace(cfg0, name=f"{cfg0.name}-swa{window}",
                              sliding_window=window)
    clock = FakeClock()
    eng = ContinuousBatchingEngine(
        cfg, params, slots=slots, max_len=max_len, clock=clock,
        page_size=page_size, lane_batch=CANONICAL["lane_batch"],
        device_len=CANONICAL["device_len"], **engine_kwargs)
    return eng, clock


def test_supports_paged_covers_sliding_window_but_not_moe():
    """SWA configs page (ring tables); MoE routing still forces lanes."""
    swa = configs.smoke("h2o_danube3_4b")
    assert swa.sliding_window and registry.supports_paged(swa)
    assert not registry.supports_paged(configs.smoke("grok_1_314b"))


@pytest.mark.parametrize("window", [4, 8, 12])
def test_swa_paged_bit_identical_to_lane_ring_cache(window):
    """Windowed paged decode (ring block tables) vs the lane ring cache:
    same tokens, token for token, with the window smaller than (4), equal
    to (8), and not a multiple of (12) the 8-token page size."""

    def run(paged):
        eng, clock = swa_engine(window, paged=paged)
        sim = Simulator(eng, staggered_trace(
            make_requests(4, prompt_len=14, new_tokens=12), gap=1.0), clock)
        sim.run()
        return eng

    paged_eng, lane_eng = run(None), run(False)
    assert paged_eng.stats()["backend"] == "paged"
    assert lane_eng.stats()["backend"] == "lanes"
    assert paged_eng.stats()["window"] == window
    assert _tokens(paged_eng) == _tokens(lane_eng)
    # 14 + 12 = 26 positions (4 blocks) cross every ring: must have recycled
    assert paged_eng.pages_recycled > 0


def test_swa_slot_holds_o_window_pages():
    """The per-slot page bound: a slot's block table is a ring of
    ``ceil(window/page_size) + 1`` entries, so resident pages per slot
    never exceed that — O(window), not O(seq) — and the engine provisions
    its private pool accordingly."""
    eng, _ = swa_engine(16, slots=1, max_len=44, page_size=8)
    bound = -(-16 // 8) + 1                       # ceil(window/ps) + 1 = 3
    assert eng._np_slot == bound
    assert eng.stats()["table_entries_per_slot"] == bound
    eng.submit(Request(id="long", prompt=list(range(1, 21)),
                       max_new_tokens=20))
    high = 0
    while eng.busy:
        eng.step()
        slot = eng.slots[0]
        if slot is not None:
            high = max(high, len(slot.pages_by_block))
    # 40 positions = 5 pages of history; the ring held at most 3
    assert high == bound
    assert eng.pages_recycled >= 2
    # full drain: every ring page went back to the pool or table residency
    assert eng._pool.in_use == eng.pages.resident


def test_swa_sharing_clamped_to_window():
    """A shared prefix longer than the window still admits pre-consumed,
    but the slot pins only the chain pages the window can still see; the
    out-of-window pages are dropped at admission (graceful degradation),
    and outputs stay bit-identical to no-sharing lane serving."""
    prefix = [(3 * j) % 97 + 1 for j in range(24)]     # 3 pages > window 16

    def reqs():
        return shared_prefix_requests(4, prefix_len=24, tail_len=3,
                                      new_tokens=6, prefix=prefix)

    eng, clock = swa_engine(16, max_len=40, page_size=8)
    Simulator(eng, staggered_trace(reqs(), gap=4.0), clock).run()
    lane, lclock = swa_engine(16, max_len=40, page_size=8, paged=False)
    Simulator(lane, staggered_trace(reqs(), gap=4.0), lclock).run()
    assert _tokens(eng) == _tokens(lane)
    assert eng.prompt_tokens_reused > 0

    # inspect one admission directly: match covers blocks 0-2 (24 tokens),
    # the window (16) can only ever see positions >= 24+1-16 = 9, so block
    # 0 is dropped and blocks 1-2 are pinned
    eng.submit(Request(id="probe", prompt=prefix + [7, 8, 9],
                       max_new_tokens=2))
    eng.step()
    slot = next(s for s in eng.slots if s is not None)
    assert slot.request.id == "probe"
    assert 0 not in slot.pages_by_block
    assert min(len(k) for k in slot.page_keys) > 8   # block-0 key disowned
    eng.run_until_idle()


def test_swa_recycling_survives_preempt_and_replay():
    """Ring recycling mid-flight, then ``preempt()``: replay reproduces
    every token bit-for-bit (the journal cross-checks), and the journal
    records the recycles of each run."""

    def trace():
        return staggered_trace(
            make_requests(3, prompt_len=12, new_tokens=14), gap=1.0)

    base, bclock = swa_engine(8, max_len=32)
    Simulator(base, trace(), bclock).run()

    eng, clock = swa_engine(8, max_len=32)
    sim = Simulator(eng, trace(), clock)
    for _ in range(20):                       # mid-flight, recycling begun
        sim._deliver_due()
        eng.step()
        clock.advance(1.0)
    assert eng.pages_recycled > 0
    requeued = eng.preempt()
    assert requeued                           # something was in flight
    sim.run()
    assert _tokens(eng) == _tokens(base)
    rec = eng.journal.get(eng.completed[-1].id)
    assert rec.completed and rec.recycled > 0


def test_swa_tenant_in_cluster_under_power_budget():
    """An SWA engine joins a multi-model ServeCluster (shared PagePool +
    PageTable) under a PowerBudget: it runs the paged backend, the budget
    is never exceeded, and its tokens match the same engine isolated."""
    cluster, clock = make_cluster(
        pool_pages=48, page_size=8,
        power_budget=PowerBudget(max_awake_banks=2))
    add_smoke_engine(cluster, "granite_3_2b", name="dense", slots=3,
                     max_len=40)
    swa = add_smoke_engine(cluster, "h2o_danube3_4b", name="swa", slots=3,
                           max_len=40)
    assert swa.stats()["backend"] == "paged"

    def reqs(prefix):
        # 10 + 16 = 26 positions: past the 16-token window, so the SWA
        # tenant recycles ring pages while sharing the cluster pool
        return make_requests(4, prompt_len=10, new_tokens=16, prefix=prefix)

    trace = (tag_engine(staggered_trace(reqs("d"), gap=1.0), "dense")
             + tag_engine(staggered_trace(reqs("s"), gap=1.0), "swa"))
    sim = ClusterSimulator(cluster, trace, clock)
    high_water_banks = 0
    # drive by hand so the budget is observable at every scheduling round
    for _ in range(10_000):
        sim._deliver_due()
        if cluster.busy:
            cluster.step()
            clock.advance(1.0)
        elif sim.pending:
            clock.advance_to(sim.pending[0].time)
        else:
            break
        high_water_banks = max(high_water_banks, cluster.awake_banks())
    assert high_water_banks <= 2
    assert swa.pages_recycled > 0             # 18 positions > window 16

    iso, iclock = make_engine("h2o_danube3_4b", slots=3, max_len=40,
                              page_size=8)
    Simulator(iso, staggered_trace(reqs("s"), gap=1.0), iclock).run()
    assert _tokens(cluster.engines["swa"]) == _tokens(iso)


def test_swa_window_larger_than_device_len_degenerates_to_global():
    """A window wider than the device cache clamps to it — the ring covers
    everything, nothing recycles, and outputs match the lane backend
    (which clamps its ring cache length identically)."""
    eng, clock = swa_engine(4096, max_len=24)
    lane, lclock = swa_engine(4096, max_len=24, paged=False)
    for e, c in ((eng, clock), (lane, lclock)):
        Simulator(e, staggered_trace(
            make_requests(3, prompt_len=6, new_tokens=6), gap=1.0), c).run()
    assert _tokens(eng) == _tokens(lane)
    assert eng.stats()["window"] == eng.device_len
    assert eng.pages_recycled == 0
