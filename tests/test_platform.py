"""Platform / XAIF behaviour: config validation, dispatch, plug-in attach."""

import jax.numpy as jnp
import pytest

from repro.core.platform import CORE_BACKEND, Platform, XHeepConfig
from repro.core.power import PowerDomain, PowerState
from repro.core.xaif import AcceleratorSpec, PortSpec, XaifRegistry
from repro.sharding.params import Axes


def test_config_validation():
    with pytest.raises(ValueError):
        XHeepConfig(core="cortex-m4")
    with pytest.raises(ValueError):
        XHeepConfig(bus="token-ring")
    with pytest.raises(ValueError):
        XHeepConfig(addressing="random")
    with pytest.raises(ValueError):
        XHeepConfig(n_banks=0)


def test_core_selects_backend():
    assert CORE_BACKEND["cv32e20"] == "ref"
    assert CORE_BACKEND["cv32e40x"] == "chunked"
    assert CORE_BACKEND["cv32e40p"] == "pallas"


def test_registry_dispatch_and_override():
    reg = XaifRegistry()
    spec = AcceleratorSpec(name="x", op="myop", impl="ref",
                           fn=lambda a: a + 1)
    reg.register(spec)
    assert reg.dispatch("myop", "ref", 41) == 42
    with pytest.raises(ValueError):
        reg.register(spec)                      # duplicate
    reg.register(spec, allow_override=True)     # explicit override ok
    with pytest.raises(KeyError):
        reg.get("myop", "pallas")


def test_platform_attach_joins_power_manager():
    platform = Platform(XHeepConfig(), registry=XaifRegistry())
    spec = AcceleratorSpec(
        name="keccak", op="hash", impl="pallas", fn=lambda x: x,
        master_ports=(PortSpec("data", Axes(None)),),
        power_domain=PowerDomain("keccak", leak_uw=3.0),
    )
    platform.attach(spec)
    assert "keccak" in platform.power.domains
    assert platform.accelerators[0].bus_width_bits == 32
    platform.power.set_state("keccak", PowerState.OFF)
    assert not platform.power.is_active("keccak")


def test_impl_for_prefers_override_then_core_then_ref():
    reg = XaifRegistry()
    reg.register(AcceleratorSpec(name="a", op="attention", impl="pallas",
                                 fn=lambda: None))
    p = Platform(XHeepConfig(core="cv32e40p"), registry=reg)
    assert p.impl_for("attention") == "pallas"
    p2 = Platform(XHeepConfig(core="cv32e20"), registry=reg)
    assert p2.impl_for("attention") == "ref"
    p3 = Platform(XHeepConfig(core="cv32e20", op_impls={"attention": "pallas"}),
                  registry=reg)
    assert p3.impl_for("attention") == "pallas"


def test_cgra_port_structure_matches_paper():
    """Paper §IV-A2: CGRA = 2 slave ports + 4 master ports = 128 bit/cycle."""
    import repro.kernels  # noqa: F401
    from repro.core.xaif import REGISTRY

    cgra = REGISTRY.get("conv1d", "pallas")
    assert len(cgra.slave_ports) == 2
    assert len(cgra.master_ports) == 4
    assert cgra.bus_width_bits == 128
    assert cgra.power_domain.name == "cgra"


def test_registry_duplicate_registration_rejected_per_op_impl():
    reg = XaifRegistry()
    reg.register(AcceleratorSpec(name="a", op="op1", impl="ref", fn=lambda: 1))
    # same op, different impl: fine
    reg.register(AcceleratorSpec(name="b", op="op1", impl="pallas", fn=lambda: 2))
    # different op, same impl name: fine
    reg.register(AcceleratorSpec(name="c", op="op2", impl="ref", fn=lambda: 3))
    with pytest.raises(ValueError, match="already registered"):
        reg.register(AcceleratorSpec(name="d", op="op1", impl="ref",
                                     fn=lambda: 4))
    assert reg.impls("op1") == ["pallas", "ref"]
    assert reg.ops() == ["op1", "op2"]


def test_impl_for_falls_back_to_ref_when_core_backend_missing():
    """cv32e40p wants pallas; an op with only ref/chunked must fall back."""
    reg = XaifRegistry()
    reg.register(AcceleratorSpec(name="r", op="rglru", impl="ref",
                                 fn=lambda x: x))
    reg.register(AcceleratorSpec(name="c", op="rglru", impl="chunked",
                                 fn=lambda x: x * 2))
    p = Platform(XHeepConfig(core="cv32e40p"), registry=reg)   # pallas core
    assert p.impl_for("rglru") == "ref"
    # chunked core finds its native impl
    p2 = Platform(XHeepConfig(core="cv32e40x"), registry=reg)
    assert p2.impl_for("rglru") == "chunked"
    assert p2.dispatch("rglru", 21) == 42
    # config override beats both
    p3 = Platform(XHeepConfig(core="cv32e40x", op_impls={"rglru": "ref"}),
                  registry=reg)
    assert p3.impl_for("rglru") == "ref"


def test_attach_joins_power_manager_exactly_once():
    platform = Platform(XHeepConfig(), registry=XaifRegistry())
    dom = PowerDomain("accel", leak_uw=5.0)
    spec = AcceleratorSpec(name="v1", op="myop", impl="pallas",
                           fn=lambda x: x, power_domain=dom)
    platform.attach(spec)
    leak_once = platform.power.leakage_uw()
    # re-attach (upgraded fn, same op/impl/domain): no duplicate domain, no
    # duplicate accelerator entry, no double leakage
    spec2 = AcceleratorSpec(name="v2", op="myop", impl="pallas",
                            fn=lambda x: x + 1, power_domain=dom)
    platform.attach(spec2)
    assert platform.power.leakage_uw() == leak_once
    assert [s.name for s in platform.accelerators] == ["v2"]
    assert platform.registry.get("myop", "pallas").fn(1) == 2


def test_reattach_with_new_domain_drops_the_orphan():
    platform = Platform(XHeepConfig(), registry=XaifRegistry())
    platform.attach(AcceleratorSpec(name="v1", op="myop", impl="pallas",
                                    fn=lambda x: x,
                                    power_domain=PowerDomain("a", leak_uw=5.0)))
    base = platform.power.leakage_uw() - 5.0
    platform.attach(AcceleratorSpec(name="v2", op="myop", impl="pallas",
                                    fn=lambda x: x,
                                    power_domain=PowerDomain("b", leak_uw=7.0)))
    # old domain "a" detached: leakage reflects only the live accelerator
    assert "a" not in platform.power.domains
    assert platform.power.leakage_uw() == pytest.approx(base + 7.0)


def test_reattach_never_removes_platform_builtin_domains():
    """A spec whose power_domain collides with a built-in ('bank0') must not
    delete that built-in when the spec is replaced."""
    platform = Platform(XHeepConfig(), registry=XaifRegistry())
    bank0 = platform.power.domains["bank0"]
    platform.attach(AcceleratorSpec(name="v1", op="myop", impl="pallas",
                                    fn=lambda x: x, power_domain=bank0))
    platform.attach(AcceleratorSpec(name="v2", op="myop", impl="pallas",
                                    fn=lambda x: x,
                                    power_domain=PowerDomain("fresh",
                                                             leak_uw=1.0)))
    assert "bank0" in platform.power.domains     # built-in survives
    platform.power.clock_gate("bank0")           # and is still controllable


def test_bank_refcounts_shared_across_holders():
    platform = Platform(XHeepConfig(), registry=XaifRegistry())
    platform.power.clock_gate("bank0")
    platform.bank_acquire("bank0")
    platform.bank_acquire("bank0")
    assert platform.power.state("bank0") is PowerState.ON
    platform.bank_release("bank0")
    assert platform.power.state("bank0") is PowerState.ON    # one holder left
    platform.bank_release("bank0")
    assert platform.power.state("bank0") is PowerState.CLOCK_GATED
    with pytest.raises(ValueError, match="released more than acquired"):
        platform.bank_release("bank0")


def test_interrupt_controller_counts_and_handlers():
    from repro.core.xaif import InterruptController

    irq = InterruptController()
    got = []
    irq.connect("acc.done", got.append)
    assert irq.fire("acc.done", 7) == 1
    assert got == [7]
    # unconnected line: counted, not an error (pending/masked semantics)
    assert irq.fire("other", None) == 0
    assert irq.count("other") == 1 and irq.count("acc.done") == 1
    irq.disconnect("acc.done", got.append)
    irq.fire("acc.done")
    assert got == [7] and irq.count("acc.done") == 2
    assert irq.lines() == ["acc.done", "other"]


def test_platform_has_interrupt_fabric():
    platform = Platform(XHeepConfig(), registry=XaifRegistry())
    seen = []
    platform.interrupts.connect("serve.complete", seen.append)
    platform.interrupts.fire("serve.complete", "req")
    assert seen == ["req"]


def test_bus_presets():
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    fc = Platform(XHeepConfig(bus="fully_connected")).rules(mesh)
    oat = Platform(XHeepConfig(bus="one_at_a_time")).rules(mesh)
    assert fc.lookup("mlp") == ("model",)
    assert oat.lookup("mlp") == ()
