"""Platform / XAIF behaviour: config validation, dispatch, plug-in attach."""

import jax.numpy as jnp
import pytest

from repro.core.platform import CORE_BACKEND, Platform, XHeepConfig
from repro.core.power import PowerDomain, PowerState
from repro.core.xaif import AcceleratorSpec, PortSpec, XaifRegistry
from repro.sharding.params import Axes


def test_config_validation():
    with pytest.raises(ValueError):
        XHeepConfig(core="cortex-m4")
    with pytest.raises(ValueError):
        XHeepConfig(bus="token-ring")
    with pytest.raises(ValueError):
        XHeepConfig(addressing="random")
    with pytest.raises(ValueError):
        XHeepConfig(n_banks=0)


def test_core_selects_backend():
    assert CORE_BACKEND["cv32e20"] == "ref"
    assert CORE_BACKEND["cv32e40x"] == "chunked"
    assert CORE_BACKEND["cv32e40p"] == "pallas"


def test_registry_dispatch_and_override():
    reg = XaifRegistry()
    spec = AcceleratorSpec(name="x", op="myop", impl="ref",
                           fn=lambda a: a + 1)
    reg.register(spec)
    assert reg.dispatch("myop", "ref", 41) == 42
    with pytest.raises(ValueError):
        reg.register(spec)                      # duplicate
    reg.register(spec, allow_override=True)     # explicit override ok
    with pytest.raises(KeyError):
        reg.get("myop", "pallas")


def test_platform_attach_joins_power_manager():
    platform = Platform(XHeepConfig(), registry=XaifRegistry())
    spec = AcceleratorSpec(
        name="keccak", op="hash", impl="pallas", fn=lambda x: x,
        master_ports=(PortSpec("data", Axes(None)),),
        power_domain=PowerDomain("keccak", leak_uw=3.0),
    )
    platform.attach(spec)
    assert "keccak" in platform.power.domains
    assert platform.accelerators[0].bus_width_bits == 32
    platform.power.set_state("keccak", PowerState.OFF)
    assert not platform.power.is_active("keccak")


def test_impl_for_prefers_override_then_core_then_ref():
    reg = XaifRegistry()
    reg.register(AcceleratorSpec(name="a", op="attention", impl="pallas",
                                 fn=lambda: None))
    p = Platform(XHeepConfig(core="cv32e40p"), registry=reg)
    assert p.impl_for("attention") == "pallas"
    p2 = Platform(XHeepConfig(core="cv32e20"), registry=reg)
    assert p2.impl_for("attention") == "ref"
    p3 = Platform(XHeepConfig(core="cv32e20", op_impls={"attention": "pallas"}),
                  registry=reg)
    assert p3.impl_for("attention") == "pallas"


def test_cgra_port_structure_matches_paper():
    """Paper §IV-A2: CGRA = 2 slave ports + 4 master ports = 128 bit/cycle."""
    import repro.kernels  # noqa: F401
    from repro.core.xaif import REGISTRY

    cgra = REGISTRY.get("conv1d", "pallas")
    assert len(cgra.slave_ports) == 2
    assert len(cgra.master_ports) == 4
    assert cgra.bus_width_bits == 128
    assert cgra.power_domain.name == "cgra"


def test_bus_presets():
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    fc = Platform(XHeepConfig(bus="fully_connected")).rules(mesh)
    oat = Platform(XHeepConfig(bus="one_at_a_time")).rules(mesh)
    assert fc.lookup("mlp") == ("model",)
    assert oat.lookup("mlp") == ()
