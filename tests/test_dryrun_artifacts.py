"""Gate on the dry-run deliverable: every (arch × shape × mesh) cell must
have a result artifact that either compiled OK or is a documented structural
skip (long_500k on pure full-attention archs)."""

import json
import pathlib

import pytest

from repro import configs

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
MESHES = ("single", "multi")

# gate on actual artifacts, not bare directory existence (an empty dir left
# by `dryrun --list` must not un-skip the whole matrix)
pytestmark = pytest.mark.skipif(
    not any(RESULTS.glob("*.json")) if RESULTS.exists() else True,
    reason="run `python -m repro.launch.dryrun --all` first")


def _cell(arch_id, shape, mesh):
    f = RESULTS / f"{arch_id}__{shape}__{mesh}.json"
    assert f.exists(), f"missing dry-run cell {f.name}"
    return json.loads(f.read_text())


@pytest.mark.parametrize("mesh", MESHES)
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("arch", configs.names())
def test_cell_compiled_or_documented_skip(arch, shape, mesh):
    cfg = configs.get(arch)
    d = _cell(cfg.name, shape, mesh)
    if shape == "long_500k" and not cfg.is_subquadratic:
        assert d["status"] == "skipped"
        assert "full attention" in d["reason"]
        return
    assert d["status"] == "ok", d.get("error", "")[:500]
    # roofline terms present and physical
    assert d["compute_s"] >= 0 and d["memory_s"] > 0
    assert d["flops_per_device"] > 0
    assert d["chips"] == (512 if mesh == "multi" else 256)


def test_multi_pod_shards_the_pod_axis():
    """Per-device compute must not grow when adding the second pod."""
    grew = []
    for arch in configs.names():
        cfg = configs.get(arch)
        a = _cell(cfg.name, "train_4k", "single")
        b = _cell(cfg.name, "train_4k", "multi")
        if a["status"] == b["status"] == "ok":
            grew.append(b["flops_per_device"] <= a["flops_per_device"] * 1.05)
    assert all(grew)


def test_long_context_decode_is_cheap_for_subquadratic_archs():
    """The architectural claim: 500k-context decode costs no more than a
    few× short-context decode for SSM/hybrid/SWA archs."""
    for arch in ("mamba2_370m", "recurrentgemma_2b", "h2o_danube3_4b"):
        cfg = configs.get(arch)
        short = _cell(cfg.name, "decode_32k", "single")
        long = _cell(cfg.name, "long_500k", "single")
        assert long["memory_s"] <= short["memory_s"], (arch, long["memory_s"])
