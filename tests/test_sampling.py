"""Deterministic stochastic sampling on the serving stack.

The tentpole contract of :mod:`repro.serve.sampling`: per-request seeded
sampling (temperature / top-k / top-p, greedy as the zero-temperature
degenerate case) rides on ``Request.sampling``, is journaled at admission,
and advances a per-slot PRNG chain **by produced token**, so the sampled
stream is a pure function of ``(params, prompt, SamplingParams)`` —
invariant to backend (paged vs lanes), dispatch mode (sync vs async
double-buffered), prefill chunking, batch composition, preemption +
replay, and cluster scheduling. Every test here is a bit-identity
assertion between two of those execution paths, plus unit properties of
the sampling math itself.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from engine_sim import (CANONICAL, FakeClock, Request, Simulator,
                        add_smoke_engine, burst_trace, make_cluster,
                        make_engine, make_requests, smoke_params,
                        staggered_trace, tag_engine, tokens_of)
from repro.runtime.ft import RequestJournal
from repro.serve.cluster import SchedPolicy
from repro.serve.engine import ContinuousBatchingEngine
from repro.serve.loadgen import TenantSpec, open_loop_trace
from repro.serve.metrics import SLO
from repro.serve.sampling import (GREEDY, SamplingParams, sample, seed_key,
                                  split_keys, zero_keys)
from repro.serve.sim import ClusterSimulator


# ---------------------------------------------------------------------------
# sampling math


def _logits(n: int = 32, seed: int = 0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(n,)),
                       jnp.float32)


def test_zero_temperature_is_exact_argmax():
    """Greedy is the degenerate case, not an approximation: temperature 0
    returns ``argmax`` bit-for-bit whatever the key or truncation knobs."""
    logits = _logits()
    want = int(jnp.argmax(logits))
    for seed in (0, 1, 12345):
        got = sample(logits, jax.random.PRNGKey(seed), 0.0, 5, 0.5)
        assert int(got) == want


def test_top_k_one_is_argmax_at_any_temperature():
    logits = _logits(seed=3)
    want = int(jnp.argmax(logits))
    for seed in range(8):
        assert int(sample(logits, jax.random.PRNGKey(seed),
                          5.0, 1, 1.0)) == want


def test_tiny_top_p_keeps_only_the_top_token():
    """The nucleus always contains the most probable token, so a top_p
    below its probability mass degenerates to argmax."""
    logits = _logits(seed=4)
    want = int(jnp.argmax(logits))
    for seed in range(8):
        assert int(sample(logits, jax.random.PRNGKey(seed),
                          2.0, 0, 1e-6)) == want


def test_top_k_restricts_support():
    """With top_k = 4, every draw lands in the 4 highest-logit tokens even
    at a temperature flat enough to otherwise visit the whole vocab."""
    logits = _logits(seed=5)
    allowed = set(np.argsort(np.asarray(logits))[-4:].tolist())
    for seed in range(24):
        tok = int(sample(logits, jax.random.PRNGKey(seed), 8.0, 4, 1.0))
        assert tok in allowed


def test_top_p_restricts_support_to_the_nucleus():
    """One dominant token (softmax mass > 0.9): top_p = 0.5 must never
    sample outside it, however hot the pre-truncation distribution."""
    logits = jnp.zeros((16,), jnp.float32).at[7].set(8.0)
    for seed in range(16):
        assert int(sample(logits, jax.random.PRNGKey(seed),
                          1.0, 0, 0.5)) == 7


def test_same_key_reproduces_different_keys_vary():
    logits = _logits(seed=6)
    key = jax.random.PRNGKey(11)
    a = int(sample(logits, key, 2.0, 0, 1.0))
    assert int(sample(logits, key, 2.0, 0, 1.0)) == a
    draws = {int(sample(logits, jax.random.PRNGKey(s), 2.0, 0, 1.0))
             for s in range(24)}
    assert len(draws) > 1


def test_split_keys_matches_scalar_split_convention():
    """The batched helper and the scalar lane path must walk the *same*
    chain: row 0 of ``jax.random.split`` carries, row 1 is consumed."""
    keys = jnp.stack([jnp.asarray(seed_key(s)) for s in (1, 2, 3)])
    carry, use = split_keys(keys)
    for i in range(3):
        parts = jax.random.split(keys[i])
        assert jnp.array_equal(carry[i], parts[0])
        assert jnp.array_equal(use[i], parts[1])
    assert zero_keys(3).shape == keys.shape


def test_sampling_params_validation():
    assert GREEDY.greedy and GREEDY.astuple() == (0.0, 0, 1.0, 0)
    assert not SamplingParams(temperature=0.5).greedy
    for bad in (dict(temperature=-0.1), dict(top_k=-1), dict(top_p=0.0),
                dict(top_p=1.5), dict(seed=-1)):
        with pytest.raises(ValueError):
            SamplingParams(**bad)


# ---------------------------------------------------------------------------
# engine bit-identity matrix


def sampled_reqs(n: int = 4, *, prompt_len: int = 6, new_tokens: int = 6,
                 prefix: str = "s", temperature: float = 0.8,
                 top_k: int = 0, top_p: float = 0.9, seed0: int = 100):
    """``n`` requests with per-request seeds ``seed0..`` — the journaled
    identity each replay test reproduces."""
    return [
        Request(id=f"{prefix}{i}",
                prompt=[(7 * i + j) % 251 + 1 for j in range(prompt_len)],
                max_new_tokens=new_tokens,
                sampling=SamplingParams(temperature=temperature, top_k=top_k,
                                        top_p=top_p, seed=seed0 + i))
        for i in range(n)
    ]


def _run(reqs, *, gap: float = 1.0, **engine_kwargs):
    eng, clock = make_engine(slots=3, max_len=32, **engine_kwargs)
    Simulator(eng, staggered_trace(reqs, gap=gap), clock).run()
    return eng


def test_sampled_parity_backends_async_and_chunking():
    """One sampled trace through five engine variants — paged/lanes,
    sync/async, chunked/unchunked prefill — produces one token stream,
    and that stream differs from greedy decoding of the same prompts."""
    variants = [dict(async_dispatch=True), {}, dict(paged=False),
                dict(prefill_chunk=4, async_dispatch=True),
                dict(paged=False, prefill_chunk=4)]
    runs = [_run(sampled_reqs(), **kw) for kw in variants]
    want = tokens_of(runs[0])
    for eng in runs[1:]:
        assert tokens_of(eng) == want
    assert runs[0].stats()["backend"] == "paged"
    assert runs[2].stats()["backend"] == "lanes"
    assert runs[0].stats()["sampled_requests"] == 4
    greedy = tokens_of(_run(make_requests(4, prompt_len=6, new_tokens=6,
                                          prefix="s")))
    assert want != greedy                     # temperature 0.8 really sampled


def test_per_request_seed_controls_the_stream():
    """Same seeds ⇒ bit-identical across fresh engines; different seeds ⇒
    different tokens. The seed is the whole identity of the stream."""
    a = tokens_of(_run(sampled_reqs()))
    assert tokens_of(_run(sampled_reqs())) == a
    assert tokens_of(_run(sampled_reqs(seed0=900))) != a


def test_mixed_batch_leaves_greedy_lanes_untouched():
    """Greedy and sampled requests interleaved in one batch: the greedy
    streams are bit-identical to an all-greedy engine — a neighbour's PRNG
    never leaks across lanes."""
    def greedy_reqs():
        return make_requests(3, prompt_len=5, new_tokens=6, prefix="g")

    mixed = [r for pair in zip(greedy_reqs(), sampled_reqs(3)) for r in pair]
    eng = _run(mixed, async_dispatch=True)
    solo = _run(greedy_reqs())
    got = tokens_of(eng)
    assert {k: v for k, v in got.items()
            if k.startswith("g")} == tokens_of(solo)
    assert eng.stats()["sampled_requests"] == 3


def test_preempt_and_replay_reproduce_sampled_tokens():
    """Full preempt() mid-decode, twice, with chunked prefill and async
    dispatch: replay re-seeds each journaled chain and the final streams
    are bit-identical to an undisturbed run (journal cross-checks every
    replayed token on the way)."""
    base = _run(sampled_reqs(6, new_tokens=8))
    eng, clock = make_engine(slots=3, max_len=32, async_dispatch=True,
                             prefill_chunk=4)
    sim = Simulator(eng, staggered_trace(sampled_reqs(6, new_tokens=8),
                                         gap=1.0), clock)
    for cut in (5, 11):
        for _ in range(cut):
            sim._deliver_due()
            if eng.busy:
                eng.step()
            clock.advance(1.0)
        assert eng.preempt()                  # something was in flight
    sim.run()
    assert tokens_of(eng) == tokens_of(base)


def test_slot_preempt_to_back_of_queue_replays_the_chain():
    """Single-slot preempt-and-requeue (the SLO demotion move): the victim
    replays after the queue drains, re-seeded, bit-identical."""
    base = _run(sampled_reqs(4, new_tokens=8))
    eng, clock = make_engine(slots=3, max_len=32)
    sim = Simulator(eng, staggered_trace(sampled_reqs(4, new_tokens=8),
                                         gap=1.0), clock)
    for _ in range(7):
        sim._deliver_due()
        if eng.busy:
            eng.step()
        clock.advance(1.0)
    assert eng.preempt_slot(0, front=False) is not None
    sim.run()
    assert tokens_of(eng) == tokens_of(base)


def test_journal_records_sampling_and_rejects_conflicting_reopen():
    """The journal pins each request's SamplingParams at first admission;
    a replay that re-opens under different params is a correctness bug and
    must raise, not silently fork the stream."""
    j = RequestJournal()
    sp = SamplingParams(temperature=0.8, top_p=0.9, seed=42).astuple()
    rec = j.open("r", [1, 2], 4, sampling=sp)
    assert rec.sampling == sp
    assert j.open("r", [1, 2], 4, sampling=sp) is rec      # replay: same id
    with pytest.raises(ValueError):
        j.open("r", [1, 2], 4, sampling=None)
    assert j.open("g", [1, 2], 4).sampling is None


def test_windowed_sampled_parity_paged_ring_vs_lane_ring():
    """Sampling composes with sliding-window serving: ring block tables
    (paged) and the lane ring cache emit the same sampled stream while
    recycling pages past the window."""
    cfg0, params = smoke_params("granite_3_2b")
    cfg = dataclasses.replace(cfg0, name=f"{cfg0.name}-swa8",
                              sliding_window=8)

    def run(paged):
        clock = FakeClock()
        eng = ContinuousBatchingEngine(
            cfg, params, slots=2, max_len=36, clock=clock, page_size=8,
            paged=paged, lane_batch=CANONICAL["lane_batch"],
            device_len=CANONICAL["device_len"])
        Simulator(eng, staggered_trace(
            sampled_reqs(4, prompt_len=14, new_tokens=12, seed0=300),
            gap=1.0), clock).run()
        return eng

    paged_eng, lane_eng = run(None), run(False)
    assert paged_eng.stats()["backend"] == "paged"
    assert tokens_of(paged_eng) == tokens_of(lane_eng)
    assert paged_eng.pages_recycled > 0


def test_cluster_slo_preempt_and_requeue_replays_sampled_chain():
    """The PR 6 SLO demotion under sampling: a deadline-busted *sampled*
    decode is preempted, requeued behind the followers, replayed from its
    journaled seed — and still emits exactly the solo-run stream."""
    sp = SamplingParams(temperature=0.9, top_p=0.9, seed=77)

    def doomed():
        return Request(id="long", prompt=[3, 4, 5], max_new_tokens=16,
                       sampling=dataclasses.replace(sp))

    cluster, clock = make_cluster(pool_pages=48, page_size=8,
                                  policy=SchedPolicy(preempt_busted=True))
    eng = add_smoke_engine(cluster, name="g", namespace="granite", slots=1,
                           max_len=40)
    first = doomed()
    first.slo = SLO(ttft=4.0, tpot=0.5)
    trace = tag_engine(burst_trace(
        [first] + make_requests(2, prompt_len=3, new_tokens=4, prefix="f")),
        "g")
    ClusterSimulator(cluster, trace, clock).run()
    assert cluster.slo_preempts == 1
    assert cluster.journal.journal("g").get("long").sampling == sp.astuple()

    iso, iclock = make_engine(slots=1, max_len=40)
    Simulator(iso, burst_trace(
        [doomed()] + make_requests(2, prompt_len=3, new_tokens=4,
                                   prefix="f")), iclock).run()
    assert tokens_of(eng) == tokens_of(iso)

    greedy, gclock = make_engine(slots=1, max_len=40)
    Simulator(greedy, burst_trace(
        [Request(id="long", prompt=[3, 4, 5], max_new_tokens=16)]),
        gclock).run()
    assert tokens_of(iso)["long"] != tokens_of(greedy)["long"]


# ---------------------------------------------------------------------------
# load generation


def test_open_loop_sampling_seeds_deterministic_and_gated():
    """Sampling tenants draw a fresh per-request seed from the mix RNG —
    deterministically (same trace seed ⇒ same seeds) and *only* for
    sampling tenants, so greedy traces consume the exact pre-sampling RNG
    stream."""
    spec = TenantSpec(engine="e", sampling=SamplingParams(temperature=0.7))
    a = list(open_loop_trace([spec], n_requests=24, rate=5.0, seed=3))
    b = list(open_loop_trace([spec], n_requests=24, rate=5.0, seed=3))
    assert ([x.request.sampling for x in a]
            == [x.request.sampling for x in b])
    seeds = {x.request.sampling.seed for x in a}
    assert len(seeds) == 24                   # distinct per request
    assert all(x.request.sampling.temperature == 0.7 for x in a)

    g = list(open_loop_trace([TenantSpec(engine="e")], n_requests=24,
                             rate=5.0, seed=3))
    assert all(x.request.sampling is None for x in g)
    # arrival times come from the arrival-process RNG, which the seed draws
    # never touch — and the first request predates any seed draw entirely
    assert [x.time for x in g] == [x.time for x in a]
    assert g[0].request.prompt == a[0].request.prompt


def test_open_loop_sampled_cluster_runs_bit_identical():
    """End to end at small scale: a bursty open-loop mix with a sampled
    tenant, driven twice through fresh clusters, emits bit-identical
    token streams."""
    tenants = [
        TenantSpec(engine="g", share=1.0, prompt_len=(4, 10),
                   new_tokens=(3, 8), slo=SLO(ttft=25.0, tpot=4.0),
                   sampling=SamplingParams(temperature=0.8, top_k=40,
                                           top_p=0.95)),
        TenantSpec(engine="g", share=0.5, prompt_len=(4, 10),
                   new_tokens=(3, 8)),
    ]

    def drive():
        cluster, clock = make_cluster(
            pool_pages=48, page_size=8,
            policy=SchedPolicy(scheduler="drr", shed_busted=True,
                               preempt_busted=True))
        eng = add_smoke_engine(cluster, name="g", namespace="granite",
                               slots=2, max_len=40, queue_capacity=16)
        trace = open_loop_trace(tenants, n_requests=60, rate=8.0, seed=5,
                                process="bursty")
        rep = ClusterSimulator(cluster, trace, clock).run(max_steps=100_000)
        return rep, tokens_of(eng)

    rep1, tok1 = drive()
    rep2, tok2 = drive()
    assert tok1 and tok1 == tok2
    assert (rep1.elapsed, rep1.steps, rep1.tokens_generated,
            rep1.rejected) == (rep2.elapsed, rep2.steps,
                               rep2.tokens_generated, rep2.rejected)
