"""Paged prefix cache: refcount lifecycle, eviction safety, engine reuse.

Two layers of tests:

* Pure :class:`repro.serve.pages.PageTable` unit tests — snapshots are
  opaque objects, no jax involved — pinning discipline, LRU eviction that
  never frees a referenced page, negative-refcount errors, bank wiring.
* Engine integration under the deterministic sim harness — shared-prefix
  admission skips prefill work, outputs stay bit-identical to the
  no-sharing sequential baseline, and evict/complete/preempt release every
  pinned page exactly once (replay after ``preempt()`` is bit-identical
  with sharing enabled).
"""

import pytest

from engine_sim import (FakeClock, Request, Simulator, burst_trace,
                        make_engine, run_trace, shared_prefix_requests,
                        staggered_trace)
from repro.core.platform import Platform, XHeepConfig
from repro.core.power import PowerState
from repro.serve.pages import PageTable


from engine_sim import tokens_of as _tokens  # shared across the suites


# -- PageTable unit behaviour (snapshots are opaque; no jax) -------------------


def test_publish_acquire_roundtrip_longest_chain():
    t = PageTable(4)
    prompt = tuple(range(1, 14))           # 13 tokens -> 3 full pages
    assert t.acquire(prompt) is None       # empty table: miss
    assert t.publish(prompt[:4], "s4")
    assert t.publish(prompt[:8], "s8")
    assert not t.publish(prompt[:8], "other")   # already resident
    m = t.acquire(prompt)
    assert m.tokens_matched == 8 and m.snapshot == "s8"
    assert m.keys == (prompt[:4], prompt[:8])
    assert t.refcounts() == {prompt[:4]: 1, prompt[:8]: 1}
    t.release(m.keys)
    assert all(r == 0 for r in t.refcounts().values())
    assert t.stats["hits"] == 1 and t.stats["misses"] == 1
    assert t.stats["tokens_reused"] == 8


def test_acquire_always_leaves_final_token_to_feed():
    """A full-prompt match may not be consumed whole: the last prompt token
    must run through the model to produce the first output logits."""
    t = PageTable(4)
    t.publish((1, 2, 3, 4), "s")
    t.publish((1, 2, 3, 4, 5, 6, 7, 8), "s8")
    m = t.acquire((1, 2, 3, 4, 5, 6, 7, 8))   # prompt == resident chain
    assert m.tokens_matched == 4               # capped at len(prompt) - 1
    t.release(m.keys)


def test_chain_must_be_contiguous():
    t = PageTable(4)
    assert not t.publish((1, 2, 3, 4, 5, 6, 7, 8), "orphan")  # no parent
    assert t.resident == 0
    with pytest.raises(ValueError, match="multiple of page_size"):
        t.publish((1, 2, 3), "short")


def test_release_more_than_acquired_raises():
    t = PageTable(2)
    t.publish((1, 2), "s")
    m = t.acquire((1, 2, 3))
    t.release(m.keys)
    with pytest.raises(ValueError, match="released more than acquired"):
        t.release(m.keys)                     # refcounts never go negative


def test_lru_eviction_never_frees_pinned_or_parent_pages():
    t = PageTable(2, capacity_pages=2)
    t.publish((1, 2), "a")
    t.publish((1, 2, 3, 4), "b")              # chain a->b, at capacity
    m = t.acquire((1, 2, 3, 4, 9))            # pin both pages
    t.publish((7, 8), "c")                    # over capacity, but a/b pinned
    assert t.resident == 3                    # overflow rather than free
    assert (1, 2) in t and (1, 2, 3, 4) in t
    t.release(m.keys)
    t.publish((5, 6), "d")                    # now unpinned leaves can go
    assert t.resident <= 2
    # a parent with a resident child is never the eviction victim
    assert ((1, 2, 3, 4) in t) <= ((1, 2) in t)


def test_lru_prefers_oldest_unpinned_leaf():
    t = PageTable(2, capacity_pages=2)
    t.publish((1, 2), "a")
    t.publish((3, 4), "b")
    t.acquire((3, 4, 5))                      # touch + pin b
    t.publish((5, 6), "c")                    # evicts a (oldest unpinned)
    assert (1, 2) not in t and (3, 4) in t and (5, 6) in t
    assert t.stats["evicted"] == 1


def test_resident_pages_hold_bank_refcounts():
    platform = Platform(XHeepConfig(n_banks=2))
    for i in range(2):
        platform.power.clock_gate(f"bank{i}")
    t = PageTable(2, capacity_pages=2, platform=platform)
    t.publish((1, 2), "a")                    # bank0 wakes for the page
    assert platform.power.state("bank0") is PowerState.ON
    assert platform.power.state("bank1") is PowerState.CLOCK_GATED
    t.publish((1, 2, 3, 4), "b")              # round-robin -> bank1
    assert platform.power.state("bank1") is PowerState.ON
    t.publish((5, 6), "c")                    # evicts LRU leaf -> releases
    assert t.resident == 2
    t.clear()                                 # drop everything unpinned
    assert t.resident == 0
    assert platform.power.state("bank0") is PowerState.CLOCK_GATED
    assert platform.power.state("bank1") is PowerState.CLOCK_GATED


def test_clear_keeps_pinned_chains():
    t = PageTable(2)
    t.publish((1, 2), "a")
    t.publish((3, 4), "b")
    m = t.acquire((1, 2, 9))
    t.clear()
    assert (1, 2) in t and (3, 4) not in t
    t.release(m.keys)


# -- namespaces: one table, several isolated models ----------------------------


def test_namespaces_isolate_identical_token_keys():
    """The same token prefix under two namespaces is two distinct pages:
    the same tokens under different model weights are different states and
    must never alias."""
    t = PageTable(2)
    t.publish((1, 2), "m0-state", ns="m0")
    assert t.lookup((1, 2, 3), ns="m1") == 0           # no cross-ns match
    assert t.acquire((1, 2, 3), ns="m1") is None
    t.publish((1, 2), "m1-state", ns="m1")
    assert t.resident == 2 and t.resident_by_ns() == {"m0": 1, "m1": 1}
    m0 = t.acquire((1, 2, 3), ns="m0")
    m1 = t.acquire((1, 2, 3), ns="m1")
    assert m0.snapshot == "m0-state" and m1.snapshot == "m1-state"
    assert t.has((1, 2), "m0") and not t.has((1, 2))   # default ns is ""
    t.release(m0.keys, ns="m0")
    t.release(m1.keys, ns="m1")
    with pytest.raises(ValueError, match="released more"):
        t.release(m0.keys, ns="m0")
    assert t.refcounts(ns=None) == {("m0", (1, 2)): 0, ("m1", (1, 2)): 0}


def test_evict_lru_is_namespace_scoped():
    t = PageTable(2)
    t.publish((1, 2), "a0", ns="a")
    t.publish((3, 4), "a1", ns="a")
    t.publish((1, 2), "b0", ns="b")
    m = t.acquire((3, 4, 9), ns="a")          # pin a1
    assert t.evict_lru(10, ns="a") == 1       # only the unpinned a-page
    assert not t.has((1, 2), "a") and t.has((3, 4), "a")
    assert t.has((1, 2), "b")                 # b untouched
    assert t.unpinned_by_ns() == {"b": 1}
    t.release(m.keys, ns="a")
    assert t.evict_lru(10) == 2               # ns=None: everything unpinned
    assert t.resident == 0


def test_on_evict_fires_after_table_fully_disowns_page():
    """The ordering contract: when on_evict runs, the page is out of the
    table and its bank reference is already released — the callback's
    pool release is the payload's final reference drop."""
    platform = Platform(XHeepConfig(n_banks=1))
    platform.power.clock_gate("bank0")
    seen = []

    def on_evict(payload):
        # by now the table holds nothing: not resident, bank released
        assert not t.has((1, 2))
        assert platform.power.state("bank0") is PowerState.CLOCK_GATED
        seen.append(payload)

    t = PageTable(2, capacity_pages=1, platform=platform, on_evict=on_evict)
    t.publish((1, 2), "payload-a")
    t.publish((3, 4), "payload-b")            # capacity 1: evicts (1, 2)
    assert seen == ["payload-a"]
    assert t.has((3, 4))


def test_on_evict_release_order_keeps_shared_pool_nonnegative():
    """Cross-tenant eviction against a real PagePool: the residency
    reference released inside on_evict is always the last one standing —
    the pool never sees a negative or transient double-held count."""
    from repro.serve.paged import PagePool

    pool = PagePool(4, 2)
    t = PageTable(2, capacity_pages=1, on_evict=pool.release)
    for ns in ("a", "b"):
        idx = pool.alloc(ns)                  # engine block-table reference
        pool.retain(idx)                      # residency reference
        t.publish((1, 2), idx, ns=ns)         # may evict the other tenant
        pool.release(idx)                     # slot completes, block ref gone
    # tenant a's page was evicted (capacity 1): its pool page fully drained
    assert not t.has((1, 2), "a") and t.has((1, 2), "b")
    assert pool.in_use == 1                   # only b's resident page lives
    assert all(c == 1 for c in pool.refcounts().values())


# -- engine integration: sharing is invisible in the outputs -------------------


def _shared_trace(n=6, prefix_len=16, tail_len=3, new_tokens=4):
    return burst_trace(shared_prefix_requests(
        n, prefix_len=prefix_len, tail_len=tail_len, new_tokens=new_tokens))


def test_shared_prefix_reuses_pages_and_stays_bit_identical():
    base_eng, base = run_trace("granite_3_2b", _shared_trace(), slots=2,
                               max_len=40, sequential=True)
    eng, rep = run_trace("granite_3_2b", _shared_trace(), slots=2,
                         max_len=40, page_size=8, prefill_chunk=4)
    assert _tokens(eng) == _tokens(base_eng)
    assert rep.steps < base.steps
    st = eng.stats()["pages"]
    assert st["hits"] >= 4 and st["tokens_reused"] >= 4 * 16
    # admission-time reuse (table-counted) + mid-flight re-match adoption
    # (engine-counted) together make up every skipped prompt token
    assert eng.prompt_tokens_reused == st["tokens_reused"] + eng.rematched_tokens
    # the reused tokens were genuinely not re-processed
    total_prompt = sum(len(r.prompt) for r in eng.completed)
    assert eng.prompt_tokens_processed == total_prompt - eng.prompt_tokens_reused


def test_refcounts_drain_on_complete_and_pages_stay_resident():
    eng, clock = make_engine(slots=2, max_len=40, page_size=8)
    Simulator(eng, _shared_trace(4), clock).run()
    assert eng.pages.pinned == 0               # every pin released
    assert all(r == 0 for r in eng.pages.refcounts().values())
    assert eng.pages.resident > 0              # pages survive for reuse
    hits0 = eng.pages.stats["hits"]
    # a second wave over the same prefix hits the warm table immediately
    Simulator(eng, burst_trace(shared_prefix_requests(
        3, prefix_len=16, tail_len=3, new_tokens=4, id_prefix="w2")),
        clock).run()
    assert eng.pages.stats["hits"] >= hits0 + 3
    assert eng.pages.pinned == 0


def test_preempt_releases_pages_and_replay_is_bit_identical():
    base_eng, _ = run_trace("granite_3_2b", _shared_trace(5), slots=2,
                            max_len=40, sequential=True)
    eng, _ = make_engine(slots=2, max_len=40, page_size=8, prefill_chunk=4)
    for r in shared_prefix_requests(5, prefix_len=16, tail_len=3,
                                    new_tokens=4):
        eng.submit(r)
    for _ in range(4):
        eng.step()                             # mid-flight, pages pinned
    requeued = eng.preempt()
    assert requeued and eng.active == 0
    assert eng.pages.pinned == 0               # preempt released every pin
    assert all(r == 0 for r in eng.pages.refcounts().values())
    eng.run_until_idle()                       # replay (journal cross-checks)
    assert _tokens(eng) == _tokens(base_eng)
    # the replayed admissions found the pre-preemption pages resident
    assert all(rec.prefix_reused == 16 for rec in eng.journal.completed())


def test_journal_records_page_table_state():
    eng, clock = make_engine(slots=2, max_len=40, page_size=8)
    Simulator(eng, _shared_trace(4), clock).run()
    recs = {r.request_id: r for r in eng.journal.completed()}
    assert recs["shared0"].prefix_reused == 0          # first: cold table
    assert recs["shared3"].prefix_reused == 16         # warm: two pages
    assert len(recs["shared3"].page_keys) == 2
    assert all(len(k) % 8 == 0 for k in recs["shared3"].page_keys)


def test_tiny_capacity_never_breaks_inflight_requests():
    """Even a one-page table (constant thrash) serves correct output and
    never underflows a refcount."""
    base_eng, _ = run_trace("granite_3_2b", _shared_trace(4), slots=2,
                            max_len=40, sequential=True)
    eng, _ = run_trace("granite_3_2b", _shared_trace(4), slots=2, max_len=40,
                       page_size=8, page_capacity=1)
    assert _tokens(eng) == _tokens(base_eng)
    assert eng.pages.pinned == 0


def test_shared_table_across_engines():
    """Two engines over one PageTable: the second engine's requests reuse
    pages the first engine published."""
    from engine_sim import smoke_params
    from repro.serve.engine import ContinuousBatchingEngine

    cfg, params = smoke_params("granite_3_2b")
    table = PageTable(8)
    reqs = lambda p: shared_prefix_requests(2, prefix_len=16, tail_len=3,
                                            new_tokens=4, id_prefix=p)
    e1 = ContinuousBatchingEngine(cfg, params, slots=1, max_len=40,
                                  clock=FakeClock(), page_table=table)
    for r in reqs("a"):
        e1.submit(r)
    e1.run_until_idle()
    assert table.resident > 0
    e2 = ContinuousBatchingEngine(cfg, params, slots=1, max_len=40,
                                  clock=FakeClock(), page_table=table)
    for r in reqs("b"):
        e2.submit(r)
    e2.run_until_idle()
    assert all(rec.prefix_reused == 16 for rec in e2.journal.completed())
    assert table.pinned == 0
