"""Registry-wide serving coverage: every config admits and completes.

Every architecture in :mod:`repro.configs` — dense transformers, SWA,
modality stubs (MusicGen / InternVL2), MoE (Grok / Llama-4), Mamba-2 and
Griffin — is driven through the continuous-batching engine at smoke
shapes on the backend :func:`repro.models.registry.supports_paged`
selects for it:

* admission + completion on the default backend, greedy and sampled,
  with the journal closing every record;
* paged vs lane bit-identity for every paged-capable config (the backends
  must be interchangeable, not merely both plausible);
* same-seed determinism on the lane fallbacks (SSM / hybrid / MoE state
  has no paged path — the lane backend alone carries the replay
  contract there).

Mirrors the ``test_models.py`` tiering: one arch per family stays in the
fast tier, the long tail runs in the full tier (``slow``).
"""

import pytest

from engine_sim import (Simulator, burst_trace, make_engine, make_requests,
                        tokens_of)
from repro import configs
from repro.models import registry
from repro.serve.sampling import SamplingParams

# one arch per family (+MoE) in the fast tier — same split as
# test_models._FAST_FORWARD, which keeps each family's compile warm
_FAST = {"granite_3_2b", "mamba2_370m", "recurrentgemma_2b", "grok_1_314b"}


def _tiered(names):
    return [a if a in _FAST else pytest.param(a, marks=pytest.mark.slow)
            for a in names]


def _reqs():
    """Two tiny requests: one greedy, one sampled — both contracts per
    arch in one engine run."""
    reqs = make_requests(2, prompt_len=4, new_tokens=3)
    reqs[1].sampling = SamplingParams(temperature=0.8, top_p=0.9, seed=7)
    return reqs


def _serve(arch, **engine_kwargs):
    eng, clock = make_engine(arch, slots=2, max_len=24, **engine_kwargs)
    Simulator(eng, burst_trace(_reqs()), clock).run()
    return eng


@pytest.mark.parametrize("arch", _tiered(configs.names()))
def test_every_config_admits_and_completes(arch):
    """The engine serves the config on its registry-selected backend:
    every request admits, decodes its full budget, and closes its journal
    record."""
    cfg = configs.smoke(arch)
    eng = _serve(arch)
    want = "paged" if registry.supports_paged(cfg) else "lanes"
    assert eng.stats()["backend"] == want
    toks = tokens_of(eng)
    assert set(toks) == {"r0", "r1"}
    assert all(len(t) == 3 for t in toks.values())
    assert all(1 <= int(tok) <= cfg.vocab for t in toks.values() for tok in t)
    for rid in toks:
        assert eng.journal.get(rid).completed
    assert eng.stats()["sampled_requests"] == 1


@pytest.mark.parametrize(
    "arch", _tiered(a for a in configs.names()
                    if registry.supports_paged(configs.smoke(a))))
def test_paged_and_lane_backends_agree(arch):
    """Paged-capable configs emit the same greedy *and* sampled streams on
    both backends — backend choice is a memory decision, never an output
    decision."""
    assert tokens_of(_serve(arch)) == tokens_of(_serve(arch, paged=False))


@pytest.mark.parametrize(
    "arch", _tiered(a for a in configs.names()
                    if not registry.supports_paged(configs.smoke(a))))
def test_lane_fallbacks_are_seed_deterministic(arch):
    """Mamba-2 / Griffin / MoE have no paged path; the lane backend alone
    must carry the replay contract: two fresh engines, same per-request
    seeds, bit-identical sampled streams."""
    a, b = tokens_of(_serve(arch)), tokens_of(_serve(arch))
    assert a == b
    assert len(a["r1"]) == 3
