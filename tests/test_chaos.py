"""Chaos-tolerant serving: deterministic fault injection + crash recovery.

The invariant every test here holds the stack to: under *any* injected
fault schedule — device-step failures, corrupted tokens, NaN logits,
allocation failures, engine crashes, bank power-faults, prefix-match
drops — every completed request's tokens are bit-identical to the
fault-free run, no request is lost, and none completes twice. Two
same-seed chaos runs must inject the identical schedule and produce
bit-identical everything (tokens, fault counters, watchdog events).
"""

import dataclasses

import pytest

from engine_sim import (CANONICAL, ClusterSimulator, Simulator,
                        add_smoke_engine, make_cluster, make_engine,
                        make_requests, shared_prefix_reqs, smoke_params,
                        staggered_trace, tag_engine, tokens_of)
from repro.runtime.ft import FTConfig
from repro.serve.chaos import DeviceStepFault, FaultPlan, FaultSpec
from repro.serve.cluster import BANK_FAULT_LINE, CRASH_LINE, SchedPolicy
from repro.serve.engine import Request
from repro.serve.metrics import SLO
from repro.serve.sampling import SamplingParams


# ---------------------------------------------------------------------------
# FaultPlan unit behaviour
# ---------------------------------------------------------------------------


def test_fault_spec_validates_probabilities():
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(step_fail=1.5)
    with pytest.raises(ValueError, match="probability"):
        FaultSpec(engine_crash=-0.1)


def test_fault_plan_streams_are_seeded_and_scoped():
    """Same seed => identical draw sequence per (kind, scope); distinct
    scopes draw from independent streams (adding an engine never perturbs
    a neighbour's schedule)."""
    spec = FaultSpec(step_fail=0.5, engine_crash=0.5)
    a, b = FaultPlan(11, spec), FaultPlan(11, spec)
    seq_a = [a.crash("e0") for _ in range(50)]
    seq_b = [b.crash("e0") for _ in range(50)]
    assert seq_a == seq_b
    assert a.counts == b.counts
    # a second scope's stream is independent of how much e0 consumed
    c = FaultPlan(11, spec)
    seq_c = [c.crash("e1") for _ in range(50)]
    assert [b.crash("e1") for _ in range(50)] == seq_c


def test_fault_plan_budget_caps_without_perturbing_streams():
    spec = FaultSpec(step_fail=1.0)
    capped = FaultPlan(0, spec, budget={"step_fail": 2})
    fired = 0
    for _ in range(10):
        try:
            capped.launch("e")
        except DeviceStepFault:
            fired += 1
    assert fired == 2 and capped.counts["step_fail"] == 2


def test_zero_probability_never_draws():
    plan = FaultPlan(0, FaultSpec())
    plan.launch("e")                       # no raise
    plan.alloc("e")
    assert plan.deliver_token("e", 7) == 7
    assert not plan.crash("e") and not plan.bank("e")
    assert not plan.drop_prefix("ns")
    assert plan._rngs == {}                # p == 0 never builds a stream


# ---------------------------------------------------------------------------
# The tentpole invariant, per fault kind and all at once
# ---------------------------------------------------------------------------


def _drive(chaos=None, n=8, **cluster_kwargs):
    cluster, clock = make_cluster(pool_pages=48, page_size=8, chaos=chaos,
                                  **cluster_kwargs)
    add_smoke_engine(cluster, name="e0", slots=2, max_len=40,
                     prefill_chunk=2, page_size=8, async_dispatch=True)
    reqs = make_requests(n, prompt_len=5, new_tokens=4)
    trace = list(tag_engine(staggered_trace(reqs, gap=1.0), "e0"))
    rep = ClusterSimulator(cluster, trace, clock).run()
    return cluster, rep


@pytest.fixture(scope="module")
def fault_free_tokens():
    cluster, _ = _drive()
    return tokens_of(cluster.engines["e0"])


@pytest.mark.parametrize("kind,p", [
    ("step_fail", 0.15), ("token_corrupt", 0.1), ("nan_logits", 0.1),
    ("alloc_fail", 0.35), ("engine_crash", 0.05), ("bank_fault", 0.08),
    ("prefix_drop", 0.3),
])
def test_each_fault_kind_keeps_outputs_bit_identical(kind, p,
                                                     fault_free_tokens):
    plan = FaultPlan(7, FaultSpec(**{kind: p}))
    cluster, _ = _drive(chaos=plan)
    assert plan.counts[kind] > 0, "the fault under test never fired"
    assert tokens_of(cluster.engines["e0"]) == fault_free_tokens
    faults = cluster.stats()["faults"]
    assert faults["injected"] == plan.counts
    if kind == "step_fail":
        assert faults["step_faults"] == plan.counts[kind]
        assert faults["retries"] > 0
    if kind == "alloc_fail":
        assert faults["alloc_faults"] == plan.counts[kind]
    if kind in ("token_corrupt", "nan_logits"):
        assert faults["token_faults"] == plan.counts[kind]
        assert faults["replays"] > 0
    if kind == "engine_crash":
        assert faults["crashes"] == faults["rebuilds"] == plan.counts[kind]
        ints = cluster.platform.interrupts
        assert ints.count(CRASH_LINE) == plan.counts[kind]
    if kind == "bank_fault":
        ints = cluster.platform.interrupts
        assert ints.count(BANK_FAULT_LINE) == faults["bank_faults"] > 0


def test_fault_storm_no_lost_no_double_completed(fault_free_tokens):
    """Every kind at once: outputs still bit-identical, every submitted
    request accounted exactly once."""
    plan = FaultPlan(3, FaultSpec(step_fail=0.05, token_corrupt=0.05,
                                  nan_logits=0.03, alloc_fail=0.05,
                                  engine_crash=0.02, bank_fault=0.04,
                                  prefix_drop=0.2))
    cluster, _ = _drive(chaos=plan)
    eng = cluster.engines["e0"]
    assert tokens_of(eng) == fault_free_tokens
    done_ids = [r.id for r in eng.completed]
    assert len(done_ids) == len(set(done_ids)) == 8   # none lost or doubled
    assert not eng.queue and eng.active == 0
    assert sum(plan.counts.values()) > 0


def test_same_seed_chaos_runs_are_bit_identical():
    """Satellite: chaos determinism end to end — two same-seed runs agree
    on the injected schedule, every token, every fault counter, and the
    watchdog's event log (the FTController rides the same injectable
    clock)."""
    spec = FaultSpec(step_fail=0.05, token_corrupt=0.05, engine_crash=0.02,
                     bank_fault=0.04)

    def once():
        cluster, _ = _drive(chaos=FaultPlan(3, spec))
        return (tokens_of(cluster.engines["e0"]), cluster.stats()["faults"],
                [msg for _, msg in cluster.watchdog.events])

    tok1, faults1, events1 = once()
    tok2, faults2, events2 = once()
    assert tok1 == tok2
    assert faults1 == faults2
    assert events1 == events2 and len(events1) > 0


def test_persistent_corruption_raises_instead_of_livelocking():
    """A token corrupted on *every* delivery is not transient — the
    replay-count guard must fail loudly instead of replaying forever."""
    plan = FaultPlan(0, FaultSpec(token_corrupt=1.0))
    eng, clock = make_engine(slots=1, max_len=16, chaos=plan)
    eng.submit(Request(id="r", prompt=[1, 2], max_new_tokens=2))
    with pytest.raises(RuntimeError, match="quarantined"):
        eng.run_until_idle()


def test_transient_fault_streak_past_budget_raises():
    """An engine whose launches fail every retry exhausts the cluster's
    transient-fault budget and raises rather than spinning silently."""
    plan = FaultPlan(0, FaultSpec(step_fail=1.0))
    cluster, clock = make_cluster(pool_pages=48, page_size=8, chaos=plan,
                                  max_fault_streak=3)
    add_smoke_engine(cluster, name="e0", slots=1, max_len=40)
    cluster.submit("e0", Request(id="r", prompt=[1, 2], max_new_tokens=2))
    with pytest.raises(RuntimeError, match="consecutive step faults"):
        cluster.run_until_idle()


# ---------------------------------------------------------------------------
# Engine-level corruption quarantine
# ---------------------------------------------------------------------------


def test_corrupted_token_never_journaled_then_replays():
    """The corruption gate: a bit-flipped token is refused before the
    journal sees it; the quarantined request replays bit-identically."""
    base, clock0 = make_engine(slots=2, max_len=16)
    reqs = lambda: make_requests(4, prompt_len=3, new_tokens=4)
    Simulator(base, staggered_trace(reqs(), gap=1.0), clock0).run()

    plan = FaultPlan(0, FaultSpec(token_corrupt=0.2))
    eng, clock = make_engine(slots=2, max_len=16, chaos=plan,
                             async_dispatch=True)
    Simulator(eng, staggered_trace(reqs(), gap=1.0), clock).run()
    assert plan.counts["token_corrupt"] > 0
    assert eng.token_faults == plan.counts["token_corrupt"]
    assert eng.replays > 0
    assert tokens_of(eng) == tokens_of(base)
    for rec in eng.journal.completed():
        vocab = eng.cfg.vocab
        assert all(0 <= t < vocab for t in rec.generated)


# ---------------------------------------------------------------------------
# Crash recovery (the satellite scenario) and watchdog escalation
# ---------------------------------------------------------------------------


def _swa_cfg_params():
    cfg0, params = smoke_params("granite_3_2b")
    cfg = dataclasses.replace(cfg0, name=f"{cfg0.name}-swa8",
                              sliding_window=8)
    return cfg, params


SAMPLED = SamplingParams(temperature=0.9, top_k=20, top_p=0.9, seed=5)


def _crash_workload():
    """Shared-prefix greedy + sampled requests for engine 'g', long
    past-the-window requests for the windowed engine 'w'."""
    g = shared_prefix_reqs("s", 3, prefix_len=16, tail_len=3, new_tokens=5)
    g += [Request(id=f"x{i}",
                  prompt=[(5 * i + j) % 200 + 1 for j in range(4)],
                  max_new_tokens=6, sampling=dataclasses.replace(SAMPLED))
          for i in range(3)]
    w = [Request(id=f"w{i}",
                 prompt=[(3 * i + j) % 150 + 1 for j in range(12)],
                 max_new_tokens=16)
         for i in range(2)]
    return g, w


def _crash_cluster():
    cluster, clock = make_cluster(pool_pages=64, page_size=8)
    add_smoke_engine(cluster, name="g", namespace="granite", slots=2,
                     max_len=40, prefill_chunk=2, page_size=8,
                     async_dispatch=True)
    swa_cfg, swa_params = _swa_cfg_params()
    cluster.add_engine(swa_cfg, swa_params, name="w", namespace="swa",
                       slots=2, max_len=40,
                       lane_batch=CANONICAL["lane_batch"],
                       device_len=CANONICAL["device_len"])
    g, w = _crash_workload()
    trace = list(tag_engine(staggered_trace(g, gap=1.0), "g"))
    trace += list(tag_engine(staggered_trace(w, gap=1.0), "w"))
    trace.sort(key=lambda a: a.time)
    return cluster, clock, trace


def test_cluster_journal_crash_restore_bit_identical_and_reconciled():
    """Kill an engine with in-flight sampled + windowed + shared-prefix
    requests; the journal rebuild must complete every request with the
    fault-free tokens and leave the shared pool's refcounts fully
    reconciled (no leaked, no double-freed pages)."""
    base, bclock, btrace = _crash_cluster()
    ClusterSimulator(base, btrace, bclock).run()
    want = {n: tokens_of(e) for n, e in base.engines.items()}
    assert len(want["g"]) == 6 and len(want["w"]) == 2

    cluster, clock, trace = _crash_cluster()
    sim = ClusterSimulator(cluster, trace, clock)
    for _ in range(12):                    # run partway: work is in flight
        sim._deliver_due()
        if cluster.busy:
            cluster.step()
        clock.advance(1.0)
    assert cluster.engines["g"].active > 0
    assert cluster.engines["w"].active > 0
    cluster.crash_engine("g")
    cluster.crash_engine("w")
    assert cluster.crashes == cluster.rebuilds == 2
    assert cluster.platform.interrupts.count(CRASH_LINE) == 2
    sim.run()                              # drain the rest

    got = {n: tokens_of(e) for n, e in cluster.engines.items()}
    assert got == want
    # the windowed tenant really exercised its ring (counter spans rebuild)
    assert cluster.engines["w"].pages_recycled > 0
    for eng in cluster.engines.values():
        ids = [r.id for r in eng.completed]
        assert len(ids) == len(set(ids))   # no double completion
    # refcount reconciliation: after the drain the only live references
    # are the table's residency; dropping it must empty the pool exactly
    assert cluster.pool.in_use == cluster.table.resident
    cluster.table.clear()
    assert cluster.pool.in_use == 0


def test_crash_with_delayed_rebuild_restarts_via_step_loop():
    """crash_engine(rebuild=False) leaves the tenant down; the cluster
    step loop waits out the watchdog's restart delay, rebuilds, and the
    drained outputs still match the fault-free run."""
    base, bclock, btrace = _crash_cluster()
    ClusterSimulator(base, btrace, bclock).run()
    want = {n: tokens_of(e) for n, e in base.engines.items()}

    cluster, clock, trace = _crash_cluster()
    sim = ClusterSimulator(cluster, trace, clock)
    for _ in range(10):
        sim._deliver_due()
        if cluster.busy:
            cluster.step()
        clock.advance(1.0)
    assert cluster.engines["g"].busy
    cluster.crash_engine("g", rebuild=False)
    assert "g" in cluster.stats()["faults"]["down"]
    assert cluster.busy                    # journaled work still owed
    sim.run()
    assert cluster.rebuilds == 1
    assert {n: tokens_of(e) for n, e in cluster.engines.items()} == want


def test_watchdog_heartbeat_timeout_escalates_to_crash():
    """A tenant that stops heartbeating (stuck in a long backoff while
    the clock advances) is declared dead by the watchdog and recovered
    through the same crash-rebuild path — and its outputs still match."""
    base, _ = _drive(n=4)
    want = tokens_of(base.engines["e0"])

    cluster, clock = make_cluster(
        pool_pages=48, page_size=8,
        watchdog=FTConfig(heartbeat_timeout_s=3.0, backoff_base_s=1.0))
    add_smoke_engine(cluster, name="e0", slots=2, max_len=40,
                     prefill_chunk=2, page_size=8, async_dispatch=True)
    reqs = make_requests(4, prompt_len=5, new_tokens=4)
    trace = list(tag_engine(staggered_trace(reqs, gap=1.0), "e0"))
    sim = ClusterSimulator(cluster, trace, clock)
    for _ in range(4):
        sim._deliver_due()
        cluster.step()
        clock.advance(1.0)
    assert cluster.engines["e0"].busy
    # wedge the engine: a manual backoff starves its heartbeat while the
    # driver keeps stepping and the clock keeps moving
    cluster._backoff["e0"] = 100
    dead_before = cluster.crashes
    for _ in range(6):
        sim._deliver_due()
        cluster.step()
        clock.advance(1.0)
    assert cluster.crashes == dead_before + 1
    assert any("heartbeat timeout" in msg
               for _, msg in cluster.watchdog.events)
    sim.run()
    assert tokens_of(cluster.engines["e0"]) == want


def test_degraded_engine_sheds_blown_heads_without_policy():
    """Graceful degradation: past ``degrade_streak`` consecutive faults,
    an engine sheds SLO-blown queue heads even under the default policy
    (recovery already charged their TTFT; serving them wastes post-fault
    capacity). Fresh, in-budget heads still admit."""
    cluster, clock = make_cluster(pool_pages=48, page_size=8,
                                  watchdog=FTConfig(), degrade_streak=3)
    add_smoke_engine(cluster, name="e0", slots=1, max_len=40)
    blown = Request(id="late", prompt=[1, 2, 3], max_new_tokens=2,
                    slo=SLO(ttft=2.0, tpot=None))
    blown.arrival_time = 0.0
    clock.t = 10.0                         # TTFT long gone
    cluster.submit("e0", blown)
    cluster._fault_streak["e0"] = 3        # sustained-fault regime
    cluster.step()
    assert cluster.sheds == 1
    assert cluster.engines["e0"].shed == 1
    assert not cluster.engines["e0"].queue


def test_replayed_request_exempt_from_shedding():
    """A head holding journal state (here: crash-recovered) must finish,
    not shed — shedding it would orphan an in-flight journal record that
    the next rebuild resurrects (double accounting)."""
    cluster, clock = make_cluster(
        pool_pages=48, page_size=8, watchdog=FTConfig(),
        policy=SchedPolicy(shed_busted=True))
    eng = add_smoke_engine(cluster, name="e0", slots=1, max_len=40)
    req = Request(id="r", prompt=[1, 2, 3], max_new_tokens=4,
                  slo=SLO(ttft=2.0, tpot=None))
    cluster.submit("e0", req)
    cluster.step()                         # admitted: journal record opened
    assert eng.journal.has("r")
    cluster.crash_engine("e0")
    clock.t = 50.0                         # far past the TTFT target
    cluster.run_until_idle()
    eng = cluster.engines["e0"]
    assert cluster.sheds == 0 and eng.shed == 0
    assert [r.id for r in eng.completed] == ["r"]


def test_bank_fault_requeues_fifo_and_gates_bank():
    """A bank power-fault preempts every slot on the faulted bank in FIFO
    order and fires the XAIF line; outputs are unchanged (covered by the
    parametrized kind test — here the mechanics)."""
    cluster, clock = make_cluster(pool_pages=48, page_size=8)
    eng = add_smoke_engine(cluster, name="e0", slots=2, max_len=40)
    for r in make_requests(2, prompt_len=3, new_tokens=4):
        cluster.submit("e0", r)
    cluster.step()                         # admit both onto their banks
    assert eng.active == 2
    banks = {eng._slot_bank[i] for i, s in enumerate(eng.slots)
             if s is not None}
    cluster._apply_bank_fault("e0")
    assert cluster.bank_faults == 1
    assert cluster.platform.interrupts.count(BANK_FAULT_LINE) == 1
    if len(banks) == 1:                    # both slots shared the bank
        assert [r.id for r in eng.queue] == ["r0", "r1"]   # FIFO restored
    else:
        assert [r.id for r in eng.queue] == ["r0"]
    cluster.run_until_idle()
    ids = [r.id for r in eng.completed]
    assert sorted(ids) == ["r0", "r1"] and len(set(ids)) == 2
